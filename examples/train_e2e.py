"""End-to-end training driver (deliverable (b)): trains a small LM for a
few hundred steps with the full substrate engaged — BASS shard placement,
sharded train step, AdamW, async checkpointing, restart-resume, heartbeat
supervision — and prints a decreasing loss.

Defaults are CPU-budget friendly (~2 M params, 300 steps on the synthetic
copy task).  ``--preset 100m`` selects the ~100 M-param config for real
hardware; any assigned architecture runs via ``--arch <id> --smoke``.

    PYTHONPATH=src python examples/train_e2e.py
    PYTHONPATH=src python examples/train_e2e.py --steps 500 --preset tiny
"""
import sys
from pathlib import Path

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [
    "--preset", "tiny", "--steps", "300", "--batch", "16",
    "--log-every", "25", "--ckpt-every", "100",
    "--ckpt-dir", str(Path(__file__).resolve().parent / ".ckpt_e2e"),
])

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    main()
