"""Quickstart — the paper in 60 seconds.

Reproduces Example 1 / Discussion 1 / Example 2 (the exact numbers from
§IV), shows the TS ledger state, then runs the same scheduler as the
training fleet's shard-placement control plane.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SCHEDULERS, replay
from repro.core.examples_fig import PAPER_MAKESPAN, example1_instance
from repro.core.topology import tpu_dcn_fabric
from repro.data import plan_epoch, uniform_shards


def main() -> None:
    print("=" * 64)
    print("BASS — Bandwidth-Aware Scheduling with SDN (Qin et al., 2014)")
    print("=" * 64)

    print("\n[1] Paper Example 1 / Fig. 4 — 9 tasks, 4 nodes, 100 Mbps:")
    for name, label in [("hds", "HDS"), ("bar", "BAR"), ("bass", "BASS"),
                        ("prebass", "Pre-BASS")]:
        inst = example1_instance()
        sched = SCHEDULERS[name](inst)
        ok = replay(inst, sched).ok
        print(f"    {label:9s} makespan {sched.makespan:5.1f} s "
              f"(paper: {PAPER_MAKESPAN[label]:.0f} s)  "
              f"LR {sched.locality_ratio:.0%}  replay={'OK' if ok else 'FAIL'}")

    inst = example1_instance()
    sched = SCHEDULERS["bass"](inst)
    a1 = next(a for a in sched.assignments if a.tid == 1)
    print(f"\n[2] TK1 detail: runs on {a1.node}, completes at {a1.finish:.0f} s,"
          f" transfer reserved slots TS{a1.transfer.slots[0]}..TS{a1.transfer.slots[-1]}"
          f" on {', '.join(sched.ledger.link_names(a1.transfer.links))}")
    print(f"    ledger utilization: {sched.ledger.utilization():.2%} of link-slots")

    print("\n[3] Same scheduler, TPU fleet: place 64 input shards on 16 hosts")
    fabric = tpu_dcn_fabric(n_pods=2, hosts_per_pod=8)
    hosts = [f"pod{p}/host{h}" for p in range(2) for h in range(8)]
    shards = uniform_shards(64, hosts, size_bytes=512e6, replication=3)
    assigns, plan = plan_epoch(fabric, hosts, {h: 0.0 for h in hosts}, shards)
    local = sum(1 for a in assigns if a.source is None)
    remote = len(assigns) - local
    print(f"    {local} local reads, {remote} bandwidth-reserved remote "
          f"fetches, epoch ingest makespan {plan.makespan:.2f} s")
    print("\nNext: examples/train_e2e.py, examples/serve_batch.py, "
          "examples/bass_cluster_demo.py")


if __name__ == "__main__":
    main()
