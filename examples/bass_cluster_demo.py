"""BASS as a fleet control plane — the paper's algorithm running every
DCN-visible traffic class of a 2-pod training job on one shared ledger:

  Q1  cross-pod gradient sync   (reserved a step ahead, Pre-BASS style)
  Q2  input-shard prefetches    (locality + bandwidth-aware placement)
  Q3  checkpoint pushes         (background; yields to Q1/Q2)

plus ProgressRate straggler detection (§V.A) triggering speculative
re-dispatch through Case 2.

    PYTHONPATH=src python examples/bass_cluster_demo.py
"""
import numpy as np

from repro.core.qos import Flow, QosPort, QueueSpec
from repro.core.topology import tpu_dcn_fabric
from repro.data import plan_epoch, prefetch_epoch, uniform_shards
from repro.distributed.dcn import CrossPodSync
from repro.runtime import ProgressTracker


def main() -> None:
    n_pods, hosts_per_pod = 2, 16
    fabric = tpu_dcn_fabric(n_pods, hosts_per_pod)
    hosts = [f"pod{p}/host{h}" for p in range(n_pods) for h in range(hosts_per_pod)]

    print("[1] Q1 — cross-pod grad sync (12 B-param model, bf16 grads/pod)")
    sync = CrossPodSync(fabric, n_pods, hosts_per_pod,
                        grad_bytes=12e9 * 2, compress=False)
    flow = sync.reserve_step(step=1, not_before=0.0)
    print(f"    uncompressed: {sync.wire_bytes()/1e9:6.1f} GB over DCN, "
          f"window {flow.plan.start:.2f}–{flow.plan.end:.2f} s")
    sync_c = CrossPodSync(fabric, n_pods, hosts_per_pod,
                          grad_bytes=12e9 * 2, compress=True)
    flow_c = sync_c.reserve_step(step=1, not_before=0.0)
    print(f"    int8+error-feedback: {sync_c.wire_bytes()/1e9:6.1f} GB, "
          f"window {flow_c.plan.start:.2f}–{flow_c.plan.end:.2f} s  (4× less wire)")

    print("\n[2] Q2 — epoch shard placement on the same fabric")
    shards = uniform_shards(96, hosts, size_bytes=512e6, replication=3, seed=7)
    backlog = {h: float(np.random.default_rng(0).uniform(0, 0.5)) for h in hosts}
    assigns, plan = plan_epoch(fabric, hosts, backlog, shards)
    local = sum(1 for a in assigns if a.source is None)
    print(f"    BASS:     {local}/{len(assigns)} local, ingest makespan "
          f"{plan.makespan:.2f} s")
    assigns_p, plan_p = prefetch_epoch(fabric, hosts, backlog, shards)
    print(f"    Pre-BASS: ingest makespan {plan_p.makespan:.2f} s "
          f"(prefetched into reserved slots)")

    print("\n[3] Q3 — checkpoint pushes behind grad sync (QoS port model)")
    port = QosPort(400.0, [QueueSpec("grad", 300.0, 0),
                           QueueSpec("data", 80.0, 1),
                           QueueSpec("ckpt", 20.0, 2)])
    done = port.simulate([
        Flow("grad_sync", 100 * 8, "grad"),
        Flow("ckpt_push", 400 * 8, "ckpt"),
    ])
    print(f"    grad sync finishes {done['grad_sync']:.2f} s; checkpoint "
          f"drains at {done['ckpt_push']:.2f} s without delaying it")

    print("\n[4] ProgressRate straggler detection (§V.A)")
    tr = ProgressTracker(straggler_factor=2.0)
    for i, score in enumerate([0.6, 0.55, 0.62, 0.58, 0.07]):
        tr.start(i, hosts[i], now=0.0)
        tr.update(i, score, now=30.0)
    stragglers = tr.stragglers(now=30.0)
    idle = tr.worker_idle_times(now=30.0)
    worst = max(idle, key=idle.get)
    print(f"    straggler tasks: {stragglers} on {worst} "
          f"(ΥI={idle[worst]:.0f} s vs median ~20 s) → speculative "
          f"re-dispatch via BASS Case 2")


if __name__ == "__main__":
    main()
