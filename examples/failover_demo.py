"""Failure-aware rerouting, end to end — the paper's SDN story made runnable.

A Table-I Sort workload (600 MB, 64 MB blocks, 100 Mbps links, background
cross-traffic) is scheduled by multipath BASS on a 2-leaf/2-spine Clos —
the same worker set as the paper's testbed, but with real path diversity.
Mid-run one spine link is killed: the controller releases every affected
transfer's unconsumed time slots, replans the remaining bytes on the best
surviving candidate path, rewrites the flow tables, and retimes the node
queues.  The reroute log below is the whole story.

    PYTHONPATH=src python examples/failover_demo.py
"""
from repro.core.controller import BassPolicy, ClusterController
from repro.core.workloads import SORT, make_instance
from repro.net import oversubscribed_leaf_spine


def main() -> None:
    # Table-I Sort @ 600 MB → 10 map tasks over workers H0..H5 with
    # background flows; re-homed onto a 2-spine Clos (same host names).
    inst, _reduce, _sz = make_instance(SORT, 600.0, seed=5)
    fabric = oversubscribed_leaf_spine(
        n_leaves=2, n_spines=2, hosts_per_leaf=3,
        host_mbps=100.0, spine_mbps=100.0,
    )
    ctrl = ClusterController(
        fabric, inst.workers, BassPolicy(multipath=True),
        idle=inst.idle, background=inst.background,
    )
    ctrl.submit(inst.tasks, at=0.0)
    ctrl.run_until(0.0)

    moved = [a for a in ctrl.jobs[0].assignments if a.transfer is not None
             and a.transfer.slot_fracs]
    print(f"[1] placed {len(inst.tasks)} Sort map tasks "
          f"({len(moved)} with TS-reserved transfers)")
    for a in moved:
        links = ctrl.state.ledger.link_names(a.transfer.links)
        print(f"    TK{a.tid}: {a.source} -> {a.node}  "
              f"window {a.transfer.start:.1f}-{a.transfer.end:.1f} s  "
              f"via {'/'.join(links)}")
    print(f"    flow rules installed: {ctrl.dataplane.tables.n_rules()}")

    # Kill a spine link carried by an in-flight transfer (cross-leaf
    # transfers traverse ls/L<leaf>S<spine> hops).
    victim, t_fail = "ls/L0S0", 5.0
    for a in moved:
        spine_hops = [n for n in ctrl.state.ledger.link_names(a.transfer.links)
                      if n.startswith("ls/")]
        if spine_hops:
            victim = spine_hops[0]
            t_fail = (a.transfer.start + a.transfer.end) / 2.0
            break
    print(f"\n[2] spine link {victim} fails at t={t_fail:.1f} s")
    ctrl.fail_link(victim, at=t_fail)
    ctrl.recover_link(victim, at=t_fail + 60.0)
    ctrl.run()

    print(f"\n[3] reroute log ({len(ctrl.reroute_log)} entries)")
    for rec in ctrl.reroute_log:
        print(f"    {rec}")
    if not ctrl.reroute_log:
        print("    (no transfer was crossing the dead link — rerun with "
              "another seed)")

    m = ctrl.job_metrics(0)
    print(f"\n[4] job completed: JT={m.jt:.1f} s  MT={m.mt:.1f} s  "
          f"LR={m.lr:.2f}  rerouted transfers={m.rerouted}")
    assert (ctrl.state.ledger.reserved <= 1.0 + 1e-6).all()


if __name__ == "__main__":
    main()
