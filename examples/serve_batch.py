"""Batched serving example (deliverable (b)): two in-process replicas of a
small model behind the BASS router — warm prefixes stick to their home
replica, overload triggers bandwidth-checked migration (Algorithm 1 Case
1.2), cold requests go to the least-loaded replica (Case 2).

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [
    "--replicas", "2", "--slots", "4", "--requests", "10",
    "--prompt-len", "24", "--max-new", "12", "--s-max", "96",
])

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    main()
