"""Benchmark: control-plane crash-recovery (DESIGN.md §11).

A k-ary fat-tree runs the bench_faults host-crash/straggler storm under a
write-ahead-journaled controller while the *control plane itself* is
killed and recovered.  Four legs per config:

* ``uncrashed``  — the journaled baseline storm (also the never-crashed
  twin every recovery below must match byte-for-byte);
* ``crashed``    — same storm with a mid-storm controller kill: headless
  window, mailbox drain at recovery; asserts the makespan overhead of
  the crash is bounded by the outage (plus a retry-backoff slack);
* ``headless``   — crash with no concurrent host faults: 100% of the
  transfers in flight at the kill complete on their booked slots (the
  data plane needs no controller to finish what was installed), and a
  burst of submissions against a tiny mailbox sheds the overflow;
* ``recovery``   — wall-time of ``recover_from(snapshot, journal)``
  (restore + replay of the post-checkpoint suffix) vs a cold replay of
  the whole journal from genesis; both must reproduce the live
  controller exactly, and snapshot+suffix must be ≥5× faster than
  genesis replay on the full config.

CSV: ``name,us_per_call,derived`` (us_per_call = storm wall time per
task for the leg rows; derived = makespan / ratio / count / ms).
``--smoke`` runs the k=4 config only; ``--json PATH`` appends rows to
the shared benchmark artifact.
"""
from __future__ import annotations

import argparse
import time

from repro.core.controller import BassPolicy, ClusterController, RetryPolicy
from repro.core.faults import FaultPlan
from repro.core.journal import ControllerSnapshot, Journal

try:
    from benchmarks.bench_faults import SEED, T0, T1, MTTR, SLOW, storm_setup
except ImportError:
    from bench_faults import SEED, T0, T1, MTTR, SLOW, storm_setup

# (fat-tree arity, tasks, host crashes, stragglers)
CONFIGS = [
    (4, 16, 2, 4),        # 16 hosts — smoke config
    (8, 128, 6, 16),      # 128 hosts — the acceptance config
]

CRASH_AT = 1.2            # controller kill: inside the fault window
OUTAGE = 1.0              # headless window length (sim seconds)
BATCHES = 8               # journaled submit/run_until checkpoints
SPEEDUP_FLOOR = 5.0       # acceptance: snapshot+replay vs genesis replay


def _build(fab, workers, **kw):
    kw.setdefault("slot_duration", 0.1)
    kw.setdefault("retry", RetryPolicy(max_attempts=4, backoff_s=0.5))
    return ClusterController(fab, workers, BassPolicy(multipath=True), **kw)


def _plan(workers, n_crashes, n_stragglers, n_ctrl=0):
    return FaultPlan.generate(
        SEED, workers, T0, T1,
        n_crashes=n_crashes, mttr=MTTR,
        n_stragglers=n_stragglers, slow_factor=SLOW,
        n_ctrl_crashes=n_ctrl,
    )


def _canon(ctrl):
    """The replay-equivalence canon (same exclusions as DESIGN.md §11):
    schedules, reroutes, ledger bytes and every behavioral counter —
    wavefront cache hit/miss artifacts and recovery meta-counters out."""
    sched = []
    for a in ctrl.schedule().assignments:
        t = a.transfer
        sched.append((
            a.tid, a.node, a.source, a.start.hex(), a.finish.hex(),
            None if t is None else (t.links, t.start.hex(), t.end.hex(),
                                    tuple((s, f.hex()) for s, f in
                                          t.slot_fracs)),
        ))
    led = ctrl.state.ledger
    counters = {
        k: v
        for k, v in sorted(ctrl.obs.snapshot(trace_tail=0)["counters"].items())
        if not k.startswith(("wavefront.", "recovery."))
    }
    return (sched, len(ctrl.reroute_log), counters,
            led.reserved.tobytes(), led.base_slot, led.retired_slots)


def _storm(ctrl, tasks, plan):
    """Submit the stream in journaled batches with run_until checkpoints
    (the operating pattern a periodic snapshotter rides on)."""
    per = max(1, len(tasks) // BATCHES)
    batches = [tasks[i:i + per] for i in range(0, len(tasks), per)]
    plan.apply(ctrl)
    for i, batch in enumerate(batches):
        at = i * (T1 / len(batches))
        ctrl.submit(batch, at=at)
        ctrl.run_until(at)
    ctrl.run()


def _makespan(ctrl):
    return max(rec.makespan for rec in ctrl.jobs.values() if rec.placed)


def run_config(k, n_tasks, n_crashes, n_stragglers, full):
    n_hosts = k ** 3 // 4
    tag = f"recovery_{n_hosts}h_{n_tasks}t"
    rows = []

    # -- leg 1: journaled, never-crashed baseline ---------------------------
    fab, workers, tasks = storm_setup(k, n_tasks)
    base = _build(fab, workers)
    base.attach_journal()
    base.attach_telemetry(estimator="window")
    t0 = time.perf_counter()
    _storm(base, tasks, _plan(workers, n_crashes, n_stragglers))
    dt_base = time.perf_counter() - t0
    mk_base = _makespan(base)
    rows.append((f"{tag}_uncrashed", dt_base / n_tasks * 1e6,
                 round(mk_base, 3)))

    # -- leg 2: same storm + mid-storm controller kill ----------------------
    fab2, workers2, tasks2 = storm_setup(k, n_tasks)
    crashed = _build(fab2, workers2)
    crashed.attach_telemetry(estimator="window")
    crashed.fail_controller(at=CRASH_AT)
    crashed.recover_controller(at=CRASH_AT + OUTAGE)
    t0 = time.perf_counter()
    _storm(crashed, tasks2, _plan(workers2, n_crashes, n_stragglers))
    dt_crash = time.perf_counter() - t0
    mk_crash = _makespan(crashed)
    assert crashed.ha_stats["ctrl_down"] == 1
    assert crashed.ha_stats["ctrl_up"] == 1
    # Bounded degradation: a crash may defer work across the headless
    # window, but never cascade.  Everything queued during the outage
    # lands at the drain, so fault handling shifts by at most the outage
    # — and a host kill shifted to the drain defers its victims'
    # re-execution by up to that host's MTTR re-admission on top.
    overhead = mk_crash - mk_base
    bound = OUTAGE + MTTR
    assert overhead <= bound, (
        f"{tag}: crash overhead {overhead:.2f}s exceeds outage+MTTR {bound}"
    )
    rows.append((f"{tag}_crashed", dt_crash / n_tasks * 1e6,
                 round(mk_crash, 3)))
    rows.append((f"{tag}_crash_overhead_s", 0.0, round(overhead, 3)))

    # -- leg 3: headless completion + bounded mailbox -----------------------
    fab3, workers3, tasks3 = storm_setup(k, n_tasks)
    ref = _build(fab3, workers3)
    ref.submit(tasks3, at=0.0)
    ref.run()
    want = _canon(ref)[0]

    head = _build(fab3, workers3)
    head.submit(tasks3, at=0.0)
    head.run_until(0.0)
    inflight = sum(
        1 for a in head.schedule().assignments
        if a.transfer is not None and a.transfer.end > 0.05
    )
    end = max(a.transfer.end for a in head.schedule().assignments
              if a.transfer is not None)
    head.fail_controller(at=0.05)
    head.recover_controller(at=end + 0.5)
    head.run()
    # Every path stayed alive, so every in-flight transfer completed on
    # its booked slots: the schedule is byte-identical to the no-crash
    # twin — completion ratio 1.0 by construction.
    assert _canon(head)[0] == want, f"{tag}: headless run altered transfers"
    rows.append((f"{tag}_headless_inflight", 0.0, inflight))
    rows.append((f"{tag}_headless_completion", 0.0, 1.0))

    box = _build(fab3, workers3, mailbox_limit=4)
    box.fail_controller(at=0.0)
    for i, t in enumerate(tasks3[:12]):
        box.submit([t], at=0.2 + 0.01 * i)
    box.recover_controller(at=1.0)
    box.run()
    assert box.ha_stats["mailbox_queued"] == 4
    assert box.ha_stats["mailbox_shed"] == 8
    rows.append((f"{tag}_mailbox_shed", 0.0, int(box.ha_stats["mailbox_shed"])))

    # -- leg 4: snapshot+replay recovery vs replay-from-genesis -------------
    # Checkpoint after the storm, then a late batch arrives before the
    # kill: recovery replays only the post-checkpoint suffix.
    t0 = time.perf_counter()
    snap_bytes = base.snapshot().to_bytes()
    dt_snap = time.perf_counter() - t0
    late = storm_setup(k, max(4, n_tasks // 16))[2]
    base.submit(late, at=base.now)
    base.run()
    want = _canon(base)
    journal_bytes = base.journal.to_bytes()

    t0 = time.perf_counter()
    rec = ClusterController.recover_from(
        fab, ControllerSnapshot.from_bytes(snap_bytes),
        Journal.from_bytes(journal_bytes),
    )
    dt_rec = time.perf_counter() - t0
    assert _canon(rec) == want, f"{tag}: snapshot+replay diverged"

    t0 = time.perf_counter()
    cold = _build(fab, workers)
    cold.replay_journal(Journal.from_bytes(journal_bytes))
    dt_cold = time.perf_counter() - t0
    assert _canon(cold) == want, f"{tag}: genesis replay diverged"

    speedup = dt_cold / dt_rec
    if full:
        assert speedup >= SPEEDUP_FLOOR, (
            f"{tag}: snapshot+replay only {speedup:.1f}x faster than "
            f"genesis replay (floor {SPEEDUP_FLOOR}x)"
        )
    rows.append((f"{tag}_snapshot_ms", 0.0, round(dt_snap * 1e3, 2)))
    rows.append((f"{tag}_cold_replay_ms", 0.0, round(dt_cold * 1e3, 2)))
    rows.append((f"{tag}_recover_ms", 0.0, round(dt_rec * 1e3, 2)))
    rows.append((f"{tag}_recovery_speedup", 0.0, round(speedup, 1)))
    return rows


def run(configs=None, full=True) -> list:
    rows = []
    for k, n_tasks, n_crashes, n_stragglers in (
            configs if configs is not None else CONFIGS):
        is_full = full and (k, n_tasks) == (8, 128)
        rows.extend(run_config(k, n_tasks, n_crashes, n_stragglers, is_full))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="k=4 config only (all equivalence asserts still run)")
    ap.add_argument("--json", metavar="PATH",
                    help="append machine-readable rows (JSON)")
    args = ap.parse_args()
    configs = CONFIGS[:1] if args.smoke else CONFIGS
    rows = run(configs, full=not args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        try:
            from benchmarks.bench_sched_scale import append_json
        except ImportError:
            from bench_sched_scale import append_json
        append_json(rows, args.json)


if __name__ == "__main__":
    main()
