"""Benchmark: single-path vs multipath BASS under link failures.

A k-ary fat-tree carries a multi-job stream while ~10 % of its switch-layer
links (edge→agg and agg→core — host uplinks are spared so every endpoint
stays reachable) fail at random times mid-run.  Three regimes:

* ``multipath_bass_k<k>_nofail``   — failure-free baseline makespan;
* ``singlepath_bass_k<k>_fail10`` — strict single-path BASS: in-flight
  transfers on dead links are rerouted onto the shortest surviving path
  (or the run raises ``UnroutableError`` — never a silent stall);
* ``multipath_bass_k<k>_fail10``  — ``BassPolicy(multipath=True)``: every
  placement scores all surviving (replica, path) candidates, so transfers
  dodge both failures and each other.

Derived value = stream makespan (``unroutable`` when the strict run had no
surviving path), plus ``*_reroutes`` rows counting replanned transfers.
Schedules are verified causally consistent by ``replay_online`` in
``tests/test_net.py``; note that a failure run can finish *earlier* than
its no-failure baseline — rerouting replans queued transfers with fresher
ledger knowledge, so churn doubles as a late re-balancing pass for flows
the greedy first-come booking had clumped onto one path.  The headline
number is multipath vs single-path: completion-time-scored ECMP beats the
one-cached-path controller by ~5× on a loaded k=8 tree.

CSV: ``name,us_per_call,derived``.  ``--smoke`` shrinks the tree to k=4
for CI.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.controller import BassPolicy, ClusterController
from repro.core.tasks import Task
from repro.core.topology import UnroutableError, storage_hosts
from repro.net import fat_tree_fabric


def _jobs(storage, rng, n_jobs, tasks_per_job):
    """Replicas live in the storage pod only (pod0) — placements on the
    rest of the fleet must move data across the core, which is where
    multipath and failure rerouting actually matter."""
    jobs, tid = [], 1
    for j in range(n_jobs):
        tasks = []
        for _ in range(tasks_per_job):
            reps = tuple(rng.choice(storage, size=2, replace=False))
            tasks.append(Task(tid=tid, size=float(rng.uniform(400, 1600)),
                              compute=float(rng.uniform(2, 10)), replicas=reps))
            tid += 1
        jobs.append((j * 10.0, tasks))
    return jobs


def _failures(fabric, rng, fail_frac=0.10, window=(2.0, 30.0)):
    """~``fail_frac`` of the switch-tier links, each with a failure time."""
    switch_links = sorted(
        n for n in fabric.links if n.startswith(("ea/", "ac/"))
    )
    n_fail = max(1, int(round(fail_frac * len(switch_links))))
    picks = rng.choice(len(switch_links), size=n_fail, replace=False)
    return [(switch_links[i], float(rng.uniform(*window))) for i in picks]


def _run_stream(k, multipath, failures, seed=0):
    fabric = fat_tree_fabric(k, link_mbps=100.0)
    hosts = storage_hosts(fabric)
    storage = [h for h in hosts if h.startswith("pod0/")]
    rng = np.random.default_rng(seed)
    n_jobs, per_job = (3, 16) if k <= 4 else (4, 48)
    jobs = _jobs(storage, rng, n_jobs, per_job)
    ctrl = ClusterController(fabric, hosts, BassPolicy(multipath=multipath))
    for at, tasks in jobs:
        ctrl.submit(tasks, at=at)
    for link, at in failures:
        ctrl.fail_link(link, at=at)
    n = sum(len(t) for _, t in jobs)
    t0 = time.perf_counter()
    try:
        ctrl.run()
    except UnroutableError:
        return (time.perf_counter() - t0) / n * 1e6, "unroutable", None
    dt = time.perf_counter() - t0
    assert all(rec.placed for rec in ctrl.jobs.values())
    mk = max(rec.makespan for rec in ctrl.jobs.values())
    return dt / n * 1e6, round(mk, 2), len(ctrl.reroute_log)


def run(smoke: bool = False) -> list:
    k = 4 if smoke else 8
    fabric = fat_tree_fabric(k)
    fails = _failures(fabric, np.random.default_rng(7))
    rows = []
    us, mk, _ = _run_stream(k, multipath=True, failures=[])
    rows.append((f"multipath_bass_k{k}_nofail", us, mk))
    us, mk, nr = _run_stream(k, multipath=False, failures=fails)
    rows.append((f"singlepath_bass_k{k}_fail10", us, mk))
    rows.append((f"singlepath_bass_k{k}_reroutes", 0.0,
                 nr if nr is not None else "unroutable"))
    us, mk, nr = _run_stream(k, multipath=True, failures=fails)
    rows.append((f"multipath_bass_k{k}_fail10", us, mk))
    rows.append((f"multipath_bass_k{k}_reroutes", 0.0, nr))
    # Multipath must complete every job under churn — the acceptance bar.
    assert rows[-2][2] != "unroutable"
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    for name, us, derived in run(smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
