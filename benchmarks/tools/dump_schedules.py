"""Schedule-dump tool: byte-exact dumps of the paper + fleet workloads.

Run before and after a scheduler change; an empty diff proves the change
is byte-identical (floats serialized via ``float.hex``).  Used to verify
the wavefront placement engine (DESIGN.md §5) emits the same bytes as
the sequential greedy loop on the Fig. 2, Table-I and fleet workloads,
and the batched reroute engine (DESIGN.md §6) on a failure-storm fleet
workload (schedules **and** reroute log; the storm section is emitted
per reroute engine, so the two blocks must be byte-identical to each
other within one dump as well as across code changes).

The ``compaction_*`` / ``failstorm_compacted`` sections run the same
arrival stream through an aggressively-compacting controller
(``retire_stride = 4``) and a never-compacted twin
(``retire_stride = None``): the paired blocks must be byte-identical
within one dump — the rolling-horizon origin shift (DESIGN.md §7) is
invisible in every emitted coordinate.

The ``faultstorm_*`` sections run a seeded host-kill + straggler storm
(``FaultPlan``, DESIGN.md §10) with retries and LATE speculation on,
once per reroute engine: the paired blocks must be byte-identical to
each other within one dump as well as across code changes, and every
section *above* them runs fault-free and must stay byte-identical to
main.

The ``backend_*`` sections emit the same workloads under the numpy
reference and the forced device ``ts_plan`` backend (DESIGN.md §8):
paired blocks must be byte-identical within one dump, pinning the device
pipeline's bit-exactness end to end.

    PYTHONPATH=src python benchmarks/tools/dump_schedules.py OUTFILE
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2]))

from benchmarks.bench_sched_scale import CONFIGS, fleet_instance  # noqa: E402
from repro.core import SCHEDULERS  # noqa: E402
from repro.core.examples_fig import example1_instance  # noqa: E402
from repro.core.workloads import SORT, WORDCOUNT, make_instance  # noqa: E402


def fx(v):
    if v is None:
        return "None"
    return float(v).hex()


def dump_schedule(out, label, sched):
    out.write(f"== {label}\n")
    for a in sorted(sched.assignments, key=lambda a: a.tid):
        t = a.transfer
        if t is None:
            tr = "-"
        else:
            fr = ";".join(f"{s}:{fx(f)}" for s, f in t.slot_fracs)
            tr = f"links={','.join(map(str, t.links))} start={fx(t.start)} end={fx(t.end)} fracs={fr}"
        out.write(
            f"{a.tid} node={a.node} src={a.source} start={fx(a.start)} "
            f"finish={fx(a.finish)} bw={fx(a.bw_needed)} {tr}\n"
        )


def main() -> None:
    path = sys.argv[1]
    with open(path, "w") as out:
        fig2 = example1_instance()
        for name in ("bass", "prebass", "hds", "bar"):
            dump_schedule(out, f"fig2_{name}", SCHEDULERS[name](fig2))
        for jobname, job in (("wordcount", WORDCOUNT), ("sort", SORT)):
            for mb in (150, 600):
                for seed in (0, 1):
                    inst, _, _ = make_instance(job, mb, seed=seed)
                    for name in ("bass", "prebass", "hds", "bar"):
                        dump_schedule(
                            out,
                            f"table1_{jobname}_{mb}_{seed}_{name}",
                            SCHEDULERS[name](inst),
                        )
        for pods, hosts, n in CONFIGS[:3]:  # fleet configs up to 4 096 hosts
            inst = fleet_instance(pods, hosts, n)
            dump_schedule(out, f"fleet_{pods * hosts}h_{n}t_bass",
                          SCHEDULERS["bass"](inst))
        for engine in ("batched", "sequential"):
            dump_failure_storm(out, engine)
        dump_compaction(out)
        # Same storm under aggressive vs no compaction: the two blocks
        # (and the default-stride ``failstorm_batched`` one above) must
        # be byte-identical to each other.
        dump_failure_storm(out, "batched", stride=4,
                           label="failstorm_compacted")
        dump_failure_storm(out, "batched", stride=None,
                           label="failstorm_uncompacted")
        dump_backend_parity(out)
        # Seeded fault storm (DESIGN.md §10) under both reroute engines:
        # the paired blocks must be byte-identical to each other within
        # one dump (host kills, retries, blacklisting and LATE
        # speculation are engine-invariant) as well as across code
        # changes.  Everything above this line runs fault-free and must
        # stay byte-identical to main.
        for engine in ("batched", "sequential"):
            dump_fault_storm(out, engine)
        # Crash-recovery equivalence (DESIGN.md §11): the same fault storm
        # dumped from a never-killed journaled controller and from a twin
        # rebuilt via snapshot bytes + journal replay — the paired blocks
        # are asserted byte-identical before they are written.
        dump_recovery(out)
        # Flat vs sharded control plane (DESIGN.md §12): the same arrival
        # streams through the flat ClusterController and the exact-mode
        # HierarchicalController — the paired ``hierarchy_*`` blocks are
        # asserted byte-identical before they are written (single-pod AND
        # cross-pod workloads, rebalancer off).
        dump_hierarchy(out)


def dump_recovery(out):
    """Mid-storm checkpoint + kill: the ``recovery_uncrashed`` twin runs
    the journaled storm straight through; the ``recovery_crashed`` twin is
    rebuilt from the checkpoint's snapshot bytes plus a replay of the
    journal suffix.  Schedules, fault counters and ha counters must match
    byte-for-byte (asserted here, not just diffed across runs)."""
    import io  # noqa: E402

    from benchmarks.bench_faults import (  # noqa: E402
        MTTR, SEED, SLOW, T0, T1, storm_setup,
    )
    from repro.core.controller import (  # noqa: E402
        BassPolicy, ClusterController, RetryPolicy,
    )
    from repro.core.faults import FaultPlan  # noqa: E402
    from repro.core.journal import ControllerSnapshot, Journal  # noqa: E402

    fab, workers, tasks = storm_setup(4, 16)
    ctrl = ClusterController(
        fab, workers, BassPolicy(multipath=True), slot_duration=0.1,
        retry=RetryPolicy(max_attempts=4, backoff_s=0.5),
        speculation=True,
    )
    ctrl.attach_journal()
    ctrl.submit(tasks, at=0.0)
    ctrl.run_until(0.0)
    # The bench_faults storm plus one in-sim controller crash, so the
    # dumped bytes also cover the headless window + mailbox drain path.
    FaultPlan.generate(
        SEED, workers, T0, T1, n_crashes=2, mttr=MTTR,
        n_stragglers=4, slow_factor=SLOW,
        n_ctrl_crashes=1, ctrl_mttr=1.0,
    ).apply(ctrl)
    ctrl.run_until(1.5)          # mid-storm checkpoint: the kill point
    snap = ctrl.snapshot()
    ctrl.run()                   # never-killed twin finishes the storm

    rec = ClusterController.recover_from(
        fab, ControllerSnapshot.from_bytes(snap.to_bytes()),
        Journal.from_bytes(ctrl.journal.to_bytes()),
    )

    bodies = []
    for c in (ctrl, rec):
        buf = io.StringIO()
        dump_schedule(buf, "x", c.schedule())
        body = buf.getvalue().split("\n", 1)[1]
        for key in sorted(c.fault_stats):
            body += f"{key}={fx(c.fault_stats[key])}\n"
        for key in sorted(c.ha_stats):
            body += f"{key}={fx(c.ha_stats[key])}\n"
        bodies.append(body)
    assert bodies[0] == bodies[1], (
        "recovery dump pair diverged: snapshot+replay is not equivalent"
    )
    for label, body in (("recovery_uncrashed", bodies[0]),
                        ("recovery_crashed", bodies[1])):
        out.write(f"== {label}\n")
        out.write(body)


def dump_hierarchy(out):
    """Flat vs pod-sharded controller on identical arrival streams: the
    paired ``hierarchy_<case>_flat`` / ``hierarchy_<case>_sharded`` blocks
    must be byte-identical within one dump — the exact-mode parity
    contract of ``core.hierarchy`` (lazy minnow, per-pod ledger shards and
    the boundary shard are all invisible in every emitted coordinate)."""
    import io  # noqa: E402
    import random  # noqa: E402

    from repro.core.controller import ClusterController  # noqa: E402
    from repro.core.hierarchy import HierarchicalController  # noqa: E402
    from repro.core.tasks import Task  # noqa: E402
    from repro.core.topology import storage_hosts, tpu_dcn_fabric  # noqa: E402
    from repro.net.fattree import fat_tree_fabric  # noqa: E402

    def stream(hosts, seed, pod=None):
        rng = random.Random(seed)
        pool = [h for h in hosts if pod is None or h.startswith(pod + "/")]
        jobs = []
        for j in range(8):
            jobs.append((
                [
                    Task(
                        j * 100 + i,
                        size=rng.uniform(40, 400),
                        compute=rng.uniform(1, 20),
                        replicas=tuple(rng.sample(pool, 3)),
                    )
                    for i in range(rng.randint(1, 10))
                ],
                j * 2.5,
            ))
        return jobs

    cases = [
        ("fattree_cross_pod", fat_tree_fabric(4), None, 11),
        ("fattree_single_pod", fat_tree_fabric(4), "pod2", 23),
        ("tpu_dcn_cross_pod", tpu_dcn_fabric(n_pods=4, hosts_per_pod=8),
         None, 7),
    ]
    for case, fab, pod, seed in cases:
        hosts = storage_hosts(fab)
        jobs = stream(hosts, seed, pod)
        bodies = []
        for ctl in (ClusterController(fab, hosts, "bass"),
                    HierarchicalController(fab, hosts)):
            for tasks, at in jobs:
                ctl.submit(tasks, at=at)
            ctl.run()
            buf = io.StringIO()
            dump_schedule(buf, "x", ctl.schedule())
            bodies.append(buf.getvalue().split("\n", 1)[1])
        assert bodies[0] == bodies[1], (
            f"hierarchy dump pair diverged on {case}: sharded control "
            "plane is not byte-identical to flat"
        )
        for mode, body in (("flat", bodies[0]), ("sharded", bodies[1])):
            out.write(f"== hierarchy_{case}_{mode}\n")
            out.write(body)


def dump_fault_storm(out, engine):
    """Seeded host-kill + straggler storm: schedule + fault counters
    under one reroute engine, speculation on."""
    from benchmarks.bench_faults import (  # noqa: E402
        MTTR, SEED, SLOW, T0, T1, storm_setup,
    )
    from repro.core.controller import (  # noqa: E402
        BassPolicy, ClusterController, RetryPolicy,
    )
    from repro.core.faults import FaultPlan  # noqa: E402

    fab, workers, tasks = storm_setup(4, 16)
    ctrl = ClusterController(
        fab, workers, BassPolicy(multipath=True), slot_duration=0.1,
        retry=RetryPolicy(max_attempts=4, backoff_s=0.5),
        speculation=True,
    )
    ctrl.reroute_engine = engine
    ctrl.submit(tasks, at=0.0)
    ctrl.run_until(0.0)
    FaultPlan.generate(
        SEED, workers, T0, T1, n_crashes=2, mttr=MTTR,
        n_stragglers=4, slow_factor=SLOW,
    ).apply(ctrl)
    ctrl.run()
    label = f"faultstorm_{engine}"
    dump_schedule(out, label, ctrl.schedule())
    out.write(f"== {label}_counters\n")
    for key in sorted(ctrl.fault_stats):
        out.write(f"{key}={fx(ctrl.fault_stats[key])}\n")


def dump_backend_parity(out):
    """The same workloads under the numpy reference and the forced device
    ``ts_plan`` backend (fused f64 pipeline + ledger mirror): paired
    ``backend_*`` blocks must be byte-identical within one dump — the
    device pipeline's bit-exactness contract, end to end through the
    scheduler.  Skipped (with a marker block) when jax is unavailable."""
    from repro.kernels import ts_plan  # noqa: E402

    try:
        from repro.kernels import ts_plan_device  # noqa: E402

        have = ts_plan_device.available()
    except Exception:  # noqa: BLE001
        have = False
    if not have:
        out.write("== backend_parity_skipped_no_jax\n")
        return
    pods, hosts, n = CONFIGS[0]
    prev = ts_plan.get_backend()
    try:
        for be in ("numpy", "pallas"):
            ts_plan.set_backend(be)
            if be == "pallas":
                ts_plan_device.set_mirror(True)  # exercise the mirror too
            dump_schedule(
                out, f"backend_{be}_fig2_bass",
                SCHEDULERS["bass"](example1_instance()),
            )
            dump_schedule(
                out, f"backend_{be}_fleet_{pods * hosts}h_{n}t",
                SCHEDULERS["bass"](fleet_instance(pods, hosts, n)),
            )
    finally:
        ts_plan.set_backend(prev)
        ts_plan_device.set_mirror(None)


def dump_compaction(out):
    """Fig-2 and Table-I streams through a live controller, compacted
    (retire_stride=4) vs never-compacted: paired blocks byte-identical."""
    from dataclasses import replace  # noqa: E402

    from repro.core.controller import ClusterController  # noqa: E402

    cases = [("fig2", example1_instance())]
    inst, _, _ = make_instance(SORT, 150, seed=0)
    cases.append(("table1_sort_150_0", inst))
    for label, inst in cases:
        for mode, stride in (("compacted", 4), ("uncompacted", None)):
            ctrl = ClusterController.from_instance(inst)
            ctrl.state.ledger.retire_stride = stride
            half = len(inst.tasks) // 2
            ctrl.submit(inst.tasks[:half], at=0.0)
            # The second half arrives a compaction-stride later, so the
            # compacting controller has already shifted its origin.
            ctrl.submit(
                [replace(t, tid=t.tid + 10_000) for t in inst.tasks[half:]],
                at=40.0,
            )
            ctrl.run()
            dump_schedule(out, f"compaction_{label}_{mode}",
                          ctrl.schedule())


def dump_failure_storm(out, engine, stride=256, label=None):
    """Spine-kill fleet storm: schedule + reroute log under one engine."""
    from benchmarks.bench_failover_scale import (  # noqa: E402
        DEAD_CORE, T_KILL, _controller, storm_setup,
    )

    fab, workers, tasks, idle = storm_setup(4, 600)
    ctrl = _controller(fab, workers, idle, engine)
    ctrl.state.ledger.retire_stride = stride
    ctrl.submit(tasks, at=0.0)
    ctrl.fail_switch(DEAD_CORE, at=T_KILL)
    ctrl.fail_link("ea/p3e0a0", at=1.0)
    ctrl.run_until(2.0)
    label = label or f"failstorm_{engine}"
    dump_schedule(out, label, ctrl.schedule())
    out.write(f"== {label}_reroute_log\n")
    for r in ctrl.reroute_log:
        out.write(
            f"{r.flow} at={fx(r.at)} dead={','.join(r.dead_links)} "
            f"{r.src}->{r.dst} old={'/'.join(r.old_path)} "
            f"new={'/'.join(r.new_path)} delivered={fx(r.delivered)} "
            f"remaining={fx(r.remaining)} old_end={fx(r.old_end)} "
            f"new_end={fx(r.new_end)}\n"
        )


if __name__ == "__main__":
    main()
