"""Schedule-dump tool: byte-exact dumps of the paper + fleet workloads.

Run before and after a scheduler change; an empty diff proves the change
is byte-identical (floats serialized via ``float.hex``).  Used to verify
the wavefront placement engine (DESIGN.md §5) emits the same bytes as
the sequential greedy loop on the Fig. 2, Table-I and fleet workloads.

    PYTHONPATH=src python benchmarks/tools/dump_schedules.py OUTFILE
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2]))

from benchmarks.bench_sched_scale import CONFIGS, fleet_instance  # noqa: E402
from repro.core import SCHEDULERS  # noqa: E402
from repro.core.examples_fig import example1_instance  # noqa: E402
from repro.core.workloads import SORT, WORDCOUNT, make_instance  # noqa: E402


def fx(v):
    if v is None:
        return "None"
    return float(v).hex()


def dump_schedule(out, label, sched):
    out.write(f"== {label}\n")
    for a in sorted(sched.assignments, key=lambda a: a.tid):
        t = a.transfer
        if t is None:
            tr = "-"
        else:
            fr = ";".join(f"{s}:{fx(f)}" for s, f in t.slot_fracs)
            tr = f"links={','.join(map(str, t.links))} start={fx(t.start)} end={fx(t.end)} fracs={fr}"
        out.write(
            f"{a.tid} node={a.node} src={a.source} start={fx(a.start)} "
            f"finish={fx(a.finish)} bw={fx(a.bw_needed)} {tr}\n"
        )


def main() -> None:
    path = sys.argv[1]
    with open(path, "w") as out:
        fig2 = example1_instance()
        for name in ("bass", "prebass", "hds", "bar"):
            dump_schedule(out, f"fig2_{name}", SCHEDULERS[name](fig2))
        for jobname, job in (("wordcount", WORDCOUNT), ("sort", SORT)):
            for mb in (150, 600):
                for seed in (0, 1):
                    inst, _, _ = make_instance(job, mb, seed=seed)
                    for name in ("bass", "prebass", "hds", "bar"):
                        dump_schedule(
                            out,
                            f"table1_{jobname}_{mb}_{seed}_{name}",
                            SCHEDULERS[name](inst),
                        )
        for pods, hosts, n in CONFIGS[:3]:  # fleet configs up to 4 096 hosts
            inst = fleet_instance(pods, hosts, n)
            dump_schedule(out, f"fleet_{pods * hosts}h_{n}t_bass",
                          SCHEDULERS["bass"](inst))


if __name__ == "__main__":
    main()
