"""Recompile a cell and print the top collectives by trip-multiplied wire
bytes — the §Perf profiling tool (our 'profile' is the partitioned HLO)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
from collections import defaultdict

from repro.launch.dryrun import build_cell, ACT_RULES_TRAIN, ACT_RULES_DECODE
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import parse_collectives
from repro.configs import get_config, shapes_for
from repro.distributed.actctx import activation_sharding

arch, shape_name = sys.argv[1], sys.argv[2]
policy = sys.argv[3] if len(sys.argv) > 3 else "baseline"
accum = int(sys.argv[4]) if len(sys.argv) > 4 else 8
shape = next(s for s in shapes_for(arch) if s.name == shape_name)
mesh = make_production_mesh()
from repro.launch.dryrun import policy_rules
fn, args, trips, cfg = build_cell(arch, shape, mesh, accum=accum, policy=policy)
_c, _p, rules = policy_rules(arch, shape, mesh, policy)
with mesh, activation_sharding(mesh, rules):
    comp = fn.lower(*args).compile()
rep = parse_collectives(comp.as_text(), trips, world=256)
rows = sorted(rep.ops, key=lambda c: -c.wire_bytes * c.trips)[:25]
total = sum(c.wire_bytes * c.trips for c in rep.ops)
print(f"total wire bytes/dev: {total/1e9:.1f} GB over {len(rep.ops)} collective ops")
for c in rows:
    print(f"{c.kind:20s} res={c.result_bytes/1e6:9.2f}MB g={c.group:3d} trips={c.trips:6d} "
          f"wire*trips={c.wire_bytes*c.trips/1e9:8.2f}GB  {c.path[-110:]}")
