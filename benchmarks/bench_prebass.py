"""Benchmark: Example 2 / Fig. 4 right — Pre-BASS prefetching gain.

On Example 1 the paper reports 35 s → 34 s; we additionally sweep random
Table-I-style instances and report the mean prefetch improvement (Pre-BASS
is never worse by construction — the controller adopts the prefetch plan
only when it helps).  CSV: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SCHEDULERS
from repro.core.examples_fig import example1_instance
from repro.core.workloads import SORT, WORDCOUNT, make_instance


def run() -> list:
    rows = []
    t0 = time.perf_counter()
    base = SCHEDULERS["bass"](example1_instance()).makespan
    pre = SCHEDULERS["prebass"](example1_instance()).makespan
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("prebass_example2_bass", us / 2, base))
    rows.append(("prebass_example2_prebass", us / 2, pre))

    for jobname, job, mb in [("wordcount", WORDCOUNT, 600), ("sort", SORT, 600)]:
        gains = []
        t0 = time.perf_counter()
        n = 8
        for seed in range(n):
            inst = make_instance(job, mb, seed=seed)[0]
            b = SCHEDULERS["bass"](inst).makespan
            p = SCHEDULERS["prebass"](inst).makespan
            gains.append((b - p) / b * 100.0)
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"prebass_gain_pct_{jobname}_600M", us, round(float(np.mean(gains)), 2)))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
