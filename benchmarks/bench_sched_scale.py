"""Benchmark: scheduler scalability (beyond-paper; §VI's "much larger
network cluster" future work, delivered).

BASS as a central controller for a TPU fleet: tasks = input-shard fetches
over the DCN fabric.  Derived value = scheduled tasks/second.  The 1000+
node requirement means the controller must place tens of thousands of
flows per epoch in seconds — O(m·(log n + R)) with the lazy minnow heap +
LCA routing + vectorized TS ledger.  CSV: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.bass import schedule_bass
from repro.core.tasks import Instance, Task
from repro.core.topology import tpu_dcn_fabric


def run() -> list:
    rows = []
    for pods, hosts, n_tasks in [(2, 128, 4000), (4, 256, 10000), (16, 256, 40000)]:
        n_hosts = pods * hosts
        fab = tpu_dcn_fabric(n_pods=pods, hosts_per_pod=hosts)
        workers = [f"pod{p}/host{h}" for p in range(pods) for h in range(hosts)]
        rng = np.random.default_rng(0)
        idx = rng.integers(0, n_hosts, size=(n_tasks, 3))
        tasks = [
            Task(
                tid=i,
                size=float(256e6 + (i % 7) * 64e6),     # 256–640 MB shards
                compute=float(0.05),
                replicas=tuple(workers[j] for j in idx[i]),
            )
            for i in range(n_tasks)
        ]
        idle = {w: float(rng.uniform(0, 2.0)) for w in workers}
        inst = Instance(fabric=fab, workers=workers, idle=idle, tasks=tasks,
                        slot_duration=0.1)
        t0 = time.perf_counter()
        sched = schedule_bass(inst)
        dt = time.perf_counter() - t0
        rows.append(
            (
                f"sched_scale_{n_hosts}hosts_{n_tasks}tasks",
                dt / n_tasks * 1e6,
                round(n_tasks / dt, 0),
            )
        )
        assert len(sched.assignments) == n_tasks
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
