"""Benchmark: scheduler scalability (beyond-paper; §VI's "much larger
network cluster" future work, delivered).

BASS as a central controller for a TPU fleet: tasks = input-shard fetches
over the DCN fabric.  Derived value = scheduled tasks/second.  The 1000+
node requirement means the controller must place tens of thousands of
flows per epoch in seconds — the wavefront placement engine
(``repro.core.wavefront``) plans batches against the TS ledger with fused
frontier-skipped scans instead of per-candidate window re-scans, byte-
identical to the sequential greedy loop.  CSV: ``name,us_per_call,derived``
where ``derived`` packs sustained throughput plus the per-batch placement
latency tail: ``tasks_s=…,p50_us=…,p99_us=…,p999_us=…`` (per-task µs
percentiles over 1024-task submit batches — the fleet's actual arrival
granularity, so tail regressions in the decision loop are visible, not
averaged away).

``--smoke`` runs the small config only and enforces a coarse tasks/s
floor (CI guard against decision-loop regressions); ``--json PATH``
appends machine-readable rows (see ``benchmarks/run.py --json``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.controller import ClusterController
from repro.core.tasks import Instance, Task
from repro.core.topology import tpu_dcn_fabric

CONFIGS = [
    (2, 128, 4000),      # 256 hosts
    (4, 256, 10000),     # 1 024 hosts
    (16, 256, 40000),    # 4 096 hosts — the ≥5× acceptance config
    (64, 256, 100000),   # 16 384 hosts — fleet scale, completes in seconds
]

#: Coarse CI floor for the smoke config (pre-wavefront: ~6.7k tasks/s on a
#: dev box; wavefront: ~15k).  Set far below both so only a real
#: decision-loop regression (or a hopeless runner) trips it.
SMOKE_FLOOR_TASKS_PER_S = 2500.0


def git_sha() -> str:
    import subprocess

    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 — best-effort provenance
        return "unknown"


def write_json(rows, path: str) -> None:
    """Machine-readable benchmark rows: name, us_per_call, derived, git
    sha — the perf-trajectory artifact CI uploads per run."""
    import json

    sha = git_sha()
    out = [
        {"name": r[0], "us_per_call": float(r[1]),
         "derived": r[2] if isinstance(r[2], str) else float(r[2]),
         "git_sha": sha}
        for r in rows
    ]
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")


def append_json(rows, path: str) -> None:
    """Merge benchmark rows into an existing artifact, deduping by
    (name, git sha): a re-run at the same commit *replaces* its old rows
    instead of growing the file unboundedly, while rows from other
    commits (the perf trajectory) and other benches are preserved.
    Backend variants keep distinct names (``…_numpy``/``…_pallas``), so
    the (name, sha) key already separates them."""
    import json
    import os

    sha = git_sha()
    new = [
        {"name": r[0], "us_per_call": float(r[1]),
         "derived": r[2] if isinstance(r[2], str) else float(r[2]),
         "git_sha": sha}
        for r in rows
    ]
    existing = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    fresh = {(r["name"], r["git_sha"]) for r in new}
    out = [
        r for r in existing
        if (r.get("name"), r.get("git_sha")) not in fresh
    ] + new
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")


def fleet_instance(pods: int, hosts: int, n_tasks: int) -> Instance:
    n_hosts = pods * hosts
    fab = tpu_dcn_fabric(n_pods=pods, hosts_per_pod=hosts)
    workers = [f"pod{p}/host{h}" for p in range(pods) for h in range(hosts)]
    rng = np.random.default_rng(0)
    idx = rng.integers(0, n_hosts, size=(n_tasks, 3))
    tasks = [
        Task(
            tid=i,
            size=float(256e6 + (i % 7) * 64e6),     # 256–640 MB shards
            compute=float(0.05),
            replicas=tuple(workers[j] for j in idx[i]),
        )
        for i in range(n_tasks)
    ]
    idle = {w: float(rng.uniform(0, 2.0)) for w in workers}
    return Instance(fabric=fab, workers=workers, idle=idle, tasks=tasks,
                    slot_duration=0.1)


def _backends(requested: str) -> list:
    """Backend legs for one run: both when jax is importable, numpy only
    otherwise (the artifact then records the trajectory it can measure)."""
    if requested != "both":
        return [requested]
    try:
        from repro.kernels import ts_plan_device

        return ["numpy", "pallas"] if ts_plan_device.available() else ["numpy"]
    except Exception:  # noqa: BLE001 — no jax on this runner
        return ["numpy"]


def run(configs=None, backend: str = "both") -> list:
    from repro.kernels import ts_plan

    rows = []
    prev = ts_plan.get_backend()
    try:
        for be in _backends(backend):
            ts_plan.set_backend(be)
            for pods, hosts, n_tasks in (
                configs if configs is not None else CONFIGS
            ):
                n_hosts = pods * hosts
                inst = fleet_instance(pods, hosts, n_tasks)
                # Stream the instance through the online controller in
                # 1024-task submit batches (the greedy order and hence the
                # schedule bytes are unchanged — the wavefront planner is
                # batch-size invariant), timing each batch so the derived
                # column carries per-task latency percentiles, not just
                # the mean.
                ctrl = ClusterController.from_instance(inst)
                batch = 1024
                lat_us = []
                t0 = time.perf_counter()
                for i in range(0, n_tasks, batch):
                    chunk = inst.tasks[i:i + batch]
                    c0 = time.perf_counter()
                    ctrl.submit(chunk, at=0.0)
                    ctrl.run_until(0.0)
                    lat_us.append(
                        (time.perf_counter() - c0) / len(chunk) * 1e6
                    )
                dt = time.perf_counter() - t0
                p50, p99, p999 = np.percentile(lat_us, [50.0, 99.0, 99.9])
                rows.append(
                    (
                        f"sched_scale_{n_hosts}hosts_{n_tasks}tasks_{be}",
                        dt / n_tasks * 1e6,
                        f"tasks_s={n_tasks / dt:.0f},p50_us={p50:.1f},"
                        f"p99_us={p99:.1f},p999_us={p999:.1f}",
                    )
                )
                assert len(ctrl.schedule().assignments) == n_tasks
            if be == "pallas":
                st = ts_plan.device_stats()
                calls = st.get("traces", 0) + st.get("cache_hits", 0)
                rate = st.get("cache_hits", 0) / calls if calls else 0.0
                rows.append(
                    (
                        "sched_scale_compile_cache",
                        0.0,
                        f"hit_rate={rate:.4f},traces={st.get('traces', 0)},"
                        f"hits={st.get('cache_hits', 0)},"
                        f"mirror_syncs={st.get('mirror_syncs', 0)}",
                    )
                )
    finally:
        ts_plan.set_backend(prev)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config only + coarse tasks/s floor")
    ap.add_argument("--json", metavar="PATH",
                    help="also write machine-readable rows (JSON)")
    ap.add_argument("--backend", choices=["numpy", "pallas", "both"],
                    default="both",
                    help="ts_plan backend leg(s) to measure")
    args = ap.parse_args()
    configs = CONFIGS[:1] if args.smoke else CONFIGS
    rows = run(configs, backend=args.backend)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        append_json(rows, args.json)
    if args.smoke:
        name, _us, derived = rows[0]  # the numpy leg guards the floor
        tasks_s = float(str(derived).split("tasks_s=")[1].split(",")[0])
        if tasks_s < SMOKE_FLOOR_TASKS_PER_S:
            raise SystemExit(
                f"{name}: {tasks_s} tasks/s below the "
                f"{SMOKE_FLOOR_TASKS_PER_S} floor"
            )


if __name__ == "__main__":
    main()
