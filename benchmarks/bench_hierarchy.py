"""Benchmark: flat vs pod-sharded control plane (DESIGN.md §12).

An open-loop sustained-arrival stream (jobs arrive on a fixed clock,
independent of completions — the fleet's actual arrival process) drives
the flat :class:`~repro.core.controller.ClusterController` and the
pod-affine :class:`~repro.core.hierarchy.HierarchicalController` over the
same fabric and workload.  Each row reports sustained scheduling
throughput (``tasks_s``) plus the per-submit wall-latency tail
(``p50_us``/``p99_us``/``p999_us`` per job) — the hierarchy's claim is a
*tail* claim: pod-local placement keeps the per-arrival critical path
O(pod), not O(fleet).

Full mode runs two legs:

* a ≥1,000,000-task stream on a k=8 fat-tree through the sharded
  controller (the tail-latency leg);
* flat vs sharded on a 16,384-host (64×256) TPU-DCN fleet — sharded
  sustained throughput must be ≥ flat's (asserted).

``--smoke`` runs a small k=4 config only: it asserts exact-mode
byte-parity against the flat controller (the dump-level contract, cheap
enough for CI) and emits flat/sharded rows without the throughput floor.
CSV: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import random
import time

import numpy as np

from repro.core.controller import ClusterController
from repro.core.hierarchy import HierarchicalController
from repro.core.tasks import Task
from repro.core.topology import storage_hosts, tpu_dcn_fabric
from repro.net.fattree import fat_tree_fabric

#: Full-mode legs: (label, fabric builder, jobs, tasks/job, arrival dt).
#: 4096 × 256 = 1,048,576 tasks on the k=8 fat-tree — the acceptance
#: floor for the tail-latency leg.
TAIL_LEG = ("fattree_k8", lambda: fat_tree_fabric(8, link_mbps=25e9),
            4096, 256, 0.1)
FLEET_LEG = ("fleet_16384h", lambda: tpu_dcn_fabric(n_pods=64,
                                                    hosts_per_pod=256),
             128, 256, 0.05)
SMOKE_LEG = ("fattree_k4", lambda: fat_tree_fabric(4, link_mbps=25e9),
             64, 32, 0.1)

SLOT = 0.1


def _jobs(hosts, pods_of, n_jobs, tasks_per_job, dt, seed=0):
    """Open-loop arrival stream: job ``j`` arrives at ``j*dt``; its
    replicas live in one pod (rotating), so the affine controller's
    pod-local fast path and the flat controller see the same bytes."""
    rng = random.Random(seed)
    by_pod = {}
    for h in hosts:
        by_pod.setdefault(pods_of(h), []).append(h)
    pods = sorted(by_pod)
    jobs = []
    tid = 0
    for j in range(n_jobs):
        pool = by_pod[pods[j % len(pods)]]
        tasks = [
            Task(
                tid + i,
                size=float(rng.uniform(64e6, 256e6)),
                compute=0.05,
                replicas=tuple(rng.sample(pool, min(3, len(pool)))),
            )
            for i in range(tasks_per_job)
        ]
        tid += tasks_per_job
        jobs.append((tasks, j * dt))
    return jobs


def _drive(ctl, jobs):
    """Submit each arrival and drain it; per-job wall latency in µs."""
    lat = np.empty(len(jobs), dtype=np.float64)
    t0 = time.perf_counter()
    for i, (tasks, at) in enumerate(jobs):
        c0 = time.perf_counter()
        ctl.submit(tasks, at=at)
        ctl.run_until(at)
        lat[i] = (time.perf_counter() - c0) * 1e6
    wall = time.perf_counter() - t0
    n_tasks = sum(len(t) for t, _ in jobs)
    return wall, n_tasks, lat


def _row(name, wall, n_tasks, lat):
    p50, p99, p999 = np.percentile(lat, [50.0, 99.0, 99.9])
    return (
        name,
        wall / n_tasks * 1e6,
        f"tasks_s={n_tasks / wall:.0f},p50_us={p50:.1f},"
        f"p99_us={p99:.1f},p999_us={p999:.1f}",
    )


def _tasks_s(row) -> float:
    return float(str(row[2]).split("tasks_s=")[1].split(",")[0])


def _leg(leg, modes=("flat", "sharded"), seed=0):
    label, build, n_jobs, per_job, dt = leg
    rows = {}
    for mode in modes:
        fab = build()
        hosts = storage_hosts(fab)
        if mode == "flat":
            ctl = ClusterController(fab, hosts, "bass", slot_duration=SLOT)
        else:
            ctl = HierarchicalController(fab, hosts, affinity=True,
                                         slot_duration=SLOT)
        jobs = _jobs(hosts, lambda h: h.split("/", 1)[0], n_jobs, per_job,
                     dt, seed=seed)
        wall, n_tasks, lat = _drive(ctl, jobs)
        assert sum(len(r.assignments) for r in ctl.jobs.values()) == n_tasks
        rows[mode] = _row(f"hierarchy_{label}_{mode}", wall, n_tasks, lat)
    return [rows[m] for m in modes]


def _parity_check():
    """Exact-mode byte parity on a cross-pod k=4 stream — the schedule-dump
    contract, asserted in-process so CI trips without diffing dumps."""
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    rng = random.Random(3)
    flat = ClusterController(fab, hosts, "bass")
    hier = HierarchicalController(fab, hosts)
    for j in range(12):
        tasks = [
            Task(j * 100 + i, size=rng.uniform(40, 400),
                 compute=rng.uniform(1, 20),
                 replicas=tuple(rng.sample(hosts, 3)))
            for i in range(rng.randint(1, 8))
        ]
        flat.submit(tasks, at=j * 2.0)
        hier.submit(tasks, at=j * 2.0)
    flat.run()
    hier.run()
    for a, b in zip(flat.schedule().assignments, hier.schedule().assignments):
        ta = (a.transfer.links, a.transfer.start, a.transfer.end,
              a.transfer.slot_fracs) if a.transfer else None
        tb = (b.transfer.links, b.transfer.start, b.transfer.end,
              b.transfer.slot_fracs) if b.transfer else None
        assert (a.tid, a.node, a.source, a.start, a.finish, ta) \
            == (b.tid, b.node, b.source, b.start, b.finish, tb), (
            f"exact-mode parity broken at tid {a.tid}"
        )


def run(smoke: bool = False) -> list:
    _parity_check()
    rows = []
    if smoke:
        rows += _leg(SMOKE_LEG)
        return rows
    # Tail-latency leg: ≥1M tasks on the k=8 fat-tree, sharded control
    # plane — p99/p999 per-submit latency is the headline number.
    rows += _leg(TAIL_LEG, modes=("sharded",))
    # Fleet leg: 16,384 hosts, flat vs sharded on identical arrivals.
    fleet = _leg(FLEET_LEG)
    rows += fleet
    flat_tps, shard_tps = _tasks_s(fleet[0]), _tasks_s(fleet[1])
    assert shard_tps >= flat_tps, (
        f"sharded controller slower than flat at 16,384 hosts: "
        f"{shard_tps:.0f} < {flat_tps:.0f} tasks/s"
    )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small k=4 config + exact-mode parity assert only")
    ap.add_argument("--json", metavar="PATH",
                    help="also merge machine-readable rows (JSON)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if args.json:
        try:  # as a module (benchmarks.run) vs standalone script (CI)
            from benchmarks.bench_sched_scale import append_json
        except ImportError:
            from bench_sched_scale import append_json

        append_json(rows, args.json)


if __name__ == "__main__":
    main()
