"""Benchmark: roofline report — reads the dry-run artifacts and prints the
three-term roofline per (arch × shape × mesh) plus dominant bottleneck.

CSV: ``name,us_per_call,derived`` where derived = roofline fraction (useful
compute time / dominant-term lower bound).  Full detail lands in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str = "pod256") -> list:
    rows = []
    for f in sorted(glob.glob(str(ARTIFACTS / f"*__{mesh}.json"))):
        r = json.loads(Path(f).read_text())
        if not r.get("ok") or "roofline" not in r:
            continue
        rows.append(r)
    return rows


def run() -> list:
    out = []
    opt = {
        (r["arch"], r["shape"]): r
        for r in load("pod256__opt")
        if r.get("policy") == "opt"
    }
    for r in load("pod256"):
        if r.get("policy", "baseline") != "baseline":
            continue
        ro = r["roofline"]
        name = f"roofline_{r['arch']}_{r['shape']}"
        o = opt.get((r["arch"], r["shape"]))
        opt_frac = (
            round(o["roofline"]["roofline_fraction"], 5)
            if o and "roofline" in o
            else ""
        )
        out.append(
            (
                name,
                r.get("compile_s", 0.0) * 1e6,
                round(ro["roofline_fraction"], 5),
                ro["dominant"],
                round(ro["compute_s"], 4),
                round(ro["memory_s"], 4),
                round(ro["collective_ici_s"] + ro["collective_dcn_s"], 4),
                round(r["memory"]["peak_gib"], 2),
                opt_frac,
            )
        )
    return out


def main() -> None:
    print("name,us_per_call,derived_roofline_frac,dominant,compute_s,memory_s,collective_s,peak_gib,opt_roofline_frac")
    rows = run()
    if not rows:
        print("# no artifacts found — run: python -m repro.launch.dryrun --mesh both")
    for row in rows:
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
