"""Benchmark: Discussion 1 / Fig. 4 — the worked Example-1 comparison.

Emits CSV ``name,us_per_call,derived`` where ``derived`` is the makespan in
seconds (paper: BASS 35, BAR 38, HDS 39, Pre-BASS 34).
"""
from __future__ import annotations

import time

from repro.core import SCHEDULERS
from repro.core.examples_fig import PAPER_MAKESPAN, example1_instance


def run() -> list:
    rows = []
    order = ["hds", "bar", "bass", "prebass"]
    paper = {"hds": 39, "bar": 38, "bass": 35, "prebass": 34}
    for name in order:
        fn = SCHEDULERS[name]
        # timing
        n = 200
        t0 = time.perf_counter()
        for _ in range(n):
            sched = fn(example1_instance())
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"discussion1_{name}", us, sched.makespan))
        assert sched.makespan == paper[name], (name, sched.makespan)
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
