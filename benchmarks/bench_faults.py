"""Benchmark: host-failure churn + straggler storm (task-plane robustness).

A k-ary fat-tree runs a cross-pod shard workload while a seeded
:class:`~repro.core.faults.FaultPlan` kills worker hosts mid-task (their
queued/running work is released and re-placed through the normal
bandwidth-aware policy path under the retry policy) and injects
progress-rate stragglers.  Two identically-faulted controllers run the
storm — LATE-style speculation off vs. on — and the benchmark:

* asserts the deterministic harness: the same seed twice produces
  byte-identical schedules and fault counters;
* asserts speculation-on beats speculation-off makespan under the
  straggler storm (the LATE gate only launches backups the ledger's
  residual bandwidth can actually finish early);
* reports re-execution / speculative-launch / wasted-bytes counters as
  machine-readable rows.

CSV: ``name,us_per_call,derived`` (us_per_call = storm wall time per
task; derived = makespan for the leg rows, counter values otherwise).
``--smoke`` runs the k=4 config only; ``--json PATH`` appends rows to the
shared benchmark artifact.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.controller import BassPolicy, ClusterController, RetryPolicy
from repro.core.faults import FaultPlan
from repro.core.tasks import Task
from repro.core.topology import storage_hosts
from repro.net.fattree import fat_tree_fabric

# (fat-tree arity, tasks, crashes, stragglers)
CONFIGS = [
    (4, 16, 2, 4),        # 16 hosts — smoke config
    (8, 128, 6, 16),      # 128 hosts — the acceptance config
]

SEED = 7
T0, T1 = 0.5, 3.0         # fault window: inside the ~2-wave run
MTTR = 2.0                # crashed hosts recover this much later
SLOW = (4.0, 8.0)         # straggler slowdown factor range


def storm_setup(k: int, n_tasks: int):
    """Sources in the lower pods, workers in the upper pods — every
    placement moves a shard across the core (same shape as
    bench_failover_scale, but with compute long enough that stragglers
    and mid-task host kills dominate the makespan)."""
    fab = fat_tree_fabric(k, link_mbps=100.0)
    hosts = storage_hosts(fab)
    half = len(hosts) // 2
    sources, workers = hosts[:half], hosts[half:]
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(sources), size=(n_tasks, 3))
    tasks = [
        Task(
            tid=i,
            size=float(32 + (i % 5) * 16),
            compute=2.0,
            replicas=tuple(sources[j] for j in idx[i]),
        )
        for i in range(n_tasks)
    ]
    return fab, workers, tasks


def _plan(workers, n_crashes: int, n_stragglers: int) -> FaultPlan:
    return FaultPlan.generate(
        SEED, workers, T0, T1,
        n_crashes=n_crashes, mttr=MTTR,
        n_stragglers=n_stragglers, slow_factor=SLOW,
    )


def _canon_sched(ctrl):
    out = []
    for a in ctrl.schedule().assignments:
        t = a.transfer
        out.append((
            a.tid, a.node, a.source, a.start.hex(), a.finish.hex(),
            None if t is None else (t.links, t.start.hex(), t.end.hex(),
                                    tuple((s, f.hex()) for s, f in
                                          t.slot_fracs)),
        ))
    return out


def run_leg(k: int, n_tasks: int, n_crashes: int, n_stragglers: int,
            speculation: bool):
    fab, workers, tasks = storm_setup(k, n_tasks)
    ctrl = ClusterController(
        fab, workers, BassPolicy(multipath=True), slot_duration=0.1,
        retry=RetryPolicy(max_attempts=4, backoff_s=0.5),
        speculation=speculation,
    )
    ctrl.submit(tasks, at=0.0)
    ctrl.run_until(0.0)
    _plan(workers, n_crashes, n_stragglers).apply(ctrl)
    t0 = time.perf_counter()
    ctrl.run()
    dt = time.perf_counter() - t0
    rec = ctrl.jobs[0]
    placed = sorted(a.tid for a in rec.assignments)
    assert placed == list(range(n_tasks)), (
        f"storm lost tasks: {n_tasks - len(placed)} missing"
    )
    return ctrl, rec, dt


def run(configs=None) -> list:
    rows = []
    for k, n_tasks, n_crashes, n_stragglers in (
            configs if configs is not None else CONFIGS):
        n_hosts = k ** 3 // 4
        tag = f"faults_{n_hosts}h_{n_tasks}t"

        c_off, r_off, dt_off = run_leg(k, n_tasks, n_crashes, n_stragglers,
                                       speculation=False)
        c_on, r_on, dt_on = run_leg(k, n_tasks, n_crashes, n_stragglers,
                                    speculation=True)
        # Determinism: the same seed replays to the byte — schedules and
        # every kill/retry/speculation counter.
        c_on2, _r2, _dt2 = run_leg(k, n_tasks, n_crashes, n_stragglers,
                                   speculation=True)
        assert _canon_sched(c_on2) == _canon_sched(c_on), (
            f"{tag}: same-seed fault storm is not deterministic"
        )
        assert dict(c_on2.fault_stats) == dict(c_on.fault_stats)

        mk_off, mk_on = r_off.makespan, r_on.makespan
        stats = c_on.fault_stats
        assert stats["killed"] > 0 and stats["reexecuted"] > 0, (
            f"{tag}: storm killed nothing — fault window misses the run"
        )
        assert stats["spec_launch"] > 0, f"{tag}: LATE gate never fired"
        # The acceptance claim: bandwidth-aware speculation pays for its
        # wasted bytes with makespan under a straggler storm.
        assert mk_on < mk_off, (
            f"{tag}: speculation-on makespan {mk_on:.2f} not better than "
            f"speculation-off {mk_off:.2f}"
        )

        rows.append((f"{tag}_specoff", dt_off / n_tasks * 1e6,
                     round(mk_off, 3)))
        rows.append((f"{tag}_specon", dt_on / n_tasks * 1e6,
                     round(mk_on, 3)))
        rows.append((f"{tag}_spec_gain", 0.0, round(mk_off / mk_on, 3)))
        rows.append((f"{tag}_reexecuted", 0.0, int(stats["reexecuted"])))
        rows.append((f"{tag}_spec_launch", 0.0, int(stats["spec_launch"])))
        rows.append((f"{tag}_spec_win", 0.0, int(stats["spec_win"])))
        rows.append((f"{tag}_wasted_bytes", 0.0,
                     round(float(stats["wasted_bytes"]), 1)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="k=4 config only (all assertions still run)")
    ap.add_argument("--json", metavar="PATH",
                    help="append machine-readable rows (JSON)")
    args = ap.parse_args()
    configs = CONFIGS[:1] if args.smoke else CONFIGS
    rows = run(configs)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        try:
            from benchmarks.bench_sched_scale import append_json
        except ImportError:
            from bench_sched_scale import append_json
        append_json(rows, args.json)


if __name__ == "__main__":
    main()
