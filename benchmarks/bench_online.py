"""Benchmark: the online controller — multi-job streams + throughput.

Two regimes:

* ``online_<policy>_3jobs`` — a 3-job arrival stream (staggered submits,
  plus a dynamically injected background flow) on the Table-I-scale
  leaf/spine fabric, for all four policies.  Derived value = stream
  makespan (absolute finish of the last job's last task).
* ``online_bass_4096hosts_40000tasks`` — the same 16-pod/256-host fleet
  and task mix as ``bench_sched_scale.py``, but arriving as four staggered
  10 000-task jobs through :class:`~repro.core.controller.ClusterController`.
  Derived value = scheduled tasks/second; the acceptance bar is parity with
  the one-shot ``bench_sched_scale`` number (the event loop and the batched
  candidate scoring must not tax single-job speed).

CSV: ``name,us_per_call,derived``.  ``--smoke`` shrinks the fleet for CI.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.controller import ClusterController, POLICIES
from repro.core.simulator import replay_online
from repro.core.tasks import BackgroundFlow, Task
from repro.core.topology import storage_hosts, tpu_dcn_fabric, two_tier_fabric


def _stream_jobs(workers, rng, n_jobs=3, tasks_per_job=24):
    jobs = []
    tid = 1
    for j in range(n_jobs):
        tasks = []
        for _ in range(tasks_per_job):
            reps = tuple(rng.choice(workers, size=2, replace=False))
            tasks.append(
                Task(
                    tid=tid,
                    size=float(rng.uniform(100, 600)),
                    compute=float(rng.uniform(2, 15)),
                    replicas=reps,
                )
            )
            tid += 1
        jobs.append((j * 25.0, tasks))
    return jobs


def run_stream(policy_name: str) -> tuple:
    fab = two_tier_fabric(4, 8, 100.0, 400.0)
    workers = storage_hosts(fab)
    rng = np.random.default_rng(0)
    jobs = _stream_jobs(workers, rng)
    idle = {w: float(rng.uniform(0, 5.0)) for w in workers}

    ctrl = ClusterController(fab, workers, POLICIES[policy_name](), idle=idle)
    t0 = time.perf_counter()
    for at, tasks in jobs:
        ctrl.submit(tasks, at=at)
    ctrl.inject_flow(BackgroundFlow(workers[0], workers[-1], 0.5, 10.0, 40.0))
    ctrl.run()
    dt = time.perf_counter() - t0

    rep = replay_online(jobs, ctrl.schedule(), idle)
    assert rep.ok, rep.violations[:3]
    n = sum(len(t) for _, t in jobs)
    mk = max(ctrl.jobs[j].makespan for j in ctrl.jobs)
    return (f"online_{policy_name}_3jobs", dt / n * 1e6, round(mk, 2))


def run_throughput(smoke: bool = False) -> tuple:
    pods, hosts, n_tasks = (2, 32, 2000) if smoke else (16, 256, 40000)
    n_hosts = pods * hosts
    fab = tpu_dcn_fabric(n_pods=pods, hosts_per_pod=hosts)
    workers = [f"pod{p}/host{h}" for p in range(pods) for h in range(hosts)]
    rng = np.random.default_rng(0)
    idx = rng.integers(0, n_hosts, size=(n_tasks, 3))
    tasks = [
        Task(
            tid=i,
            size=float(256e6 + (i % 7) * 64e6),     # 256–640 MB shards
            compute=float(0.05),
            replicas=tuple(workers[j] for j in idx[i]),
        )
        for i in range(n_tasks)
    ]
    idle = {w: float(rng.uniform(0, 2.0)) for w in workers}

    ctrl = ClusterController(
        fab, workers, "bass", idle=idle, slot_duration=0.1
    )
    quarter = n_tasks // 4
    t0 = time.perf_counter()
    for j in range(4):
        ctrl.submit(tasks[j * quarter : (j + 1) * quarter], at=j * 0.5)
    ctrl.run()
    dt = time.perf_counter() - t0

    placed = sum(len(rec.assignments) for rec in ctrl.jobs.values())
    assert placed == quarter * 4
    return (
        f"online_bass_{n_hosts}hosts_{n_tasks}tasks",
        dt / placed * 1e6,
        round(placed / dt, 0),
    )


def run(smoke: bool = False) -> list:
    rows = [run_stream(name) for name in POLICIES]
    rows.append(run_throughput(smoke))
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    for name, us, derived in run(smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
