"""Benchmark: long-running controller steady state (rolling-horizon TS
ledger, DESIGN.md §7).

The ROADMAP north star is a controller that serves continuous traffic
forever.  Before origin-shift compaction the dense ledger was anchored at
slot 0 and only ever doubled, so memory — and the wavefront engine's
per-batch full-slot mask — grew with *elapsed simulated time* instead of
with load: per-submit latency crept up without bound and a week of
simulated traffic was an OOM.  This benchmark drives the three live
surfaces over **≥100 000 simulated slots** each and asserts the two
steady-state properties the compaction exists to provide:

* **bounded memory** — the ledger's live window (``reserved.shape[1]``)
  stays O(booked horizon), orders of magnitude below the elapsed-slot
  count, and ``base_slot`` advances with the clock;
* **flat per-submit latency** — the last-decile median submit cost of the
  scheduling leg stays within a small factor of the first decile (the
  uncompacted ledger shows a monotone climb).

Legs:

* ``longrun_sched``  — an online BASS controller placing a steady stream
  of remote-shard jobs through the wavefront engine (the leg whose
  latency used to climb: its full-slot mask rebuild is O(live window)).
* ``longrun_router`` — the serving :class:`~repro.serving.router.BassRouter`
  routing requests with an advancing clock (50 ms slots).
* ``longrun_dcn``    — :class:`~repro.distributed.dcn.CrossPodSync` grad
  syncs registered as recurring controller events.
* ``longrun_equiv``  — a compacted vs never-compacted controller pair on
  the same stream: schedules must be byte-identical (the compaction-
  equivalence acceptance bar, also property-tested in
  ``tests/test_compaction.py`` and dumped by
  ``benchmarks/tools/dump_schedules.py``).

CSV: ``name,us_per_call,derived``.  ``--smoke`` shrinks request counts
(the simulated-slot spans stay ≥100k — slots are cheap, submits are not);
``--json PATH`` appends machine-readable rows to an existing file (CI
shares one artifact with ``bench_sched_scale``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.controller import ClusterController
from repro.core.tasks import Task
from repro.core.topology import tpu_dcn_fabric

#: Ceiling on the live ledger window (slots) for every leg.  Each leg
#: simulates ≥100k slots, so an elapsed-time-anchored ledger would sit at
#: ≥100k columns (it ends >131k after doubling); the live window is the
#: booked horizon only — typically a few hundred columns here.
MEM_SLOTS_CEIL = 16_384

#: Last-decile median per-submit latency must stay within this factor of
#: the first decile (plus an absolute floor so micro-jitter on a loaded
#: runner cannot trip it).  The uncompacted ledger's ratio grows with the
#: span — ~10× and climbing at 100k slots on a dev box.
FLAT_RATIO = 4.0
FLAT_FLOOR_S = 2e-3

TOTAL_SLOTS = 100_000


def _stream(n_hosts_per_pod: int, n_jobs: int, tasks_per_job: int):
    """Sources in pod0, workers in pod1: every placement is a remote
    cross-trunk shard fetch (the wavefront's fused path)."""
    fab = tpu_dcn_fabric(n_pods=2, hosts_per_pod=n_hosts_per_pod)
    sources = [f"pod0/host{h}" for h in range(n_hosts_per_pod)]
    workers = [f"pod1/host{h}" for h in range(n_hosts_per_pod)]
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(sources), size=(n_jobs * tasks_per_job, 3))
    jobs = []
    tid = 0
    for _ in range(n_jobs):
        tasks = []
        for _ in range(tasks_per_job):
            tasks.append(Task(
                tid=tid,
                size=float(25e9 * (1.0 + (tid % 5) * 0.5)),  # 1–3 s at NIC rate
                compute=0.5,
                replicas=tuple(sources[j] for j in idx[tid]),
            ))
            tid += 1
        jobs.append(tasks)
    return fab, workers, jobs


def run_sched_leg(n_jobs: int, total_slots: int = TOTAL_SLOTS,
                  retire: bool = True):
    """Steady job stream over ``total_slots`` 1-second slots; returns
    (controller, per-submit latencies, gap between jobs)."""
    fab, workers, jobs = _stream(16, n_jobs, 8)
    ctrl = ClusterController(fab, workers, "bass", slot_duration=1.0)
    if not retire:
        ctrl.state.ledger.retire_stride = None
    gap = total_slots / n_jobs
    lats = []
    for j, tasks in enumerate(jobs):
        at = j * gap
        t0 = time.perf_counter()
        ctrl.submit(tasks, at=at)
        ctrl.run_until(at)
        lats.append(time.perf_counter() - t0)
    ctrl.run_until(total_slots * 1.0)
    return ctrl, lats, gap


def _canon(ctrl) -> list:
    out = []
    for a in sorted(ctrl.schedule().assignments, key=lambda a: a.tid):
        t = a.transfer
        out.append((
            a.tid, a.node, a.source, a.start.hex(), a.finish.hex(),
            None if t is None else (t.links, t.start.hex(), t.end.hex(),
                                    tuple((s, f.hex()) for s, f in
                                          t.slot_fracs)),
        ))
    return out


def run_router_leg(n_req: int, total_slots: int = TOTAL_SLOTS):
    from repro.serving.engine import Request
    from repro.serving.router import BassRouter

    router = BassRouter([f"rep{i}" for i in range(8)])
    dur = router.ledger.slot_duration           # 0.05 s → 100k slots = 5000 s
    span = total_slots * dur
    rng = np.random.default_rng(1)
    lats = []
    for i in range(n_req):
        now = span * i / n_req
        req = Request(
            rid=i,
            prompt=np.zeros(int(rng.integers(64, 512)), dtype=np.int32),
            max_new=32,
            prefix_hash=int(rng.integers(0, 16)),
        )
        t0 = time.perf_counter()
        router.route(req, now=now)
        lats.append(time.perf_counter() - t0)
        # Engines drain their backlog between requests (this benchmark has
        # no real engines; without the decay every replica's queue grows
        # to the full span and the minnow choice degenerates).
        router.update_backlog(
            {r: max(0.0, b - span / n_req)
             for r, b in router.backlog.items()}
        )
    router.controller.run_until(span)
    return router, lats


def run_dcn_leg(n_steps: int, total_slots: int = TOTAL_SLOTS):
    from repro.distributed.dcn import CrossPodSync

    sync = CrossPodSync(n_pods=2, hosts_per_pod=4, grad_bytes=100e9)
    dur = sync.ledger.slot_duration             # 0.05 s → 100k slots = 5000 s
    span = total_slots * dur
    cadence = span / n_steps
    sync.register_steps(0, n_steps, cadence_s=cadence)
    lats = []
    for k in range(n_steps):
        t0 = time.perf_counter()
        sync.advance_to((k + 1) * cadence)
        lats.append(time.perf_counter() - t0)
    assert len(sync.flows) == n_steps, "every registered sync materialized"
    return sync, lats


def _decile_medians(lats):
    n = max(len(lats) // 10, 1)
    first = float(np.median(lats[:n]))
    last = float(np.median(lats[-n:]))
    return first, last


def _check_bounded(name: str, ledger, total_slots: int) -> None:
    width = ledger.reserved.shape[1]
    assert ledger.base_slot > 0, f"{name}: compaction never engaged"
    assert width <= MEM_SLOTS_CEIL, (
        f"{name}: live window {width} slots exceeds ceiling "
        f"{MEM_SLOTS_CEIL} over {total_slots} simulated slots"
    )


def run(smoke: bool = False) -> list:
    rows = []
    n_jobs, n_req, n_steps = (300, 400, 250) if smoke else (1000, 2000, 1000)

    ctrl, lats, gap = run_sched_leg(n_jobs)
    led = ctrl.state.ledger
    _check_bounded("longrun_sched", led, TOTAL_SLOTS)
    first, last = _decile_medians(lats)
    assert last <= max(FLAT_RATIO * first, FLAT_FLOOR_S), (
        f"longrun_sched: per-submit latency climbed {first*1e6:.0f}us -> "
        f"{last*1e6:.0f}us over {TOTAL_SLOTS} slots (not flat)"
    )
    placed = sum(len(rec.assignments) for rec in ctrl.jobs.values())
    assert placed == n_jobs * 8
    rows.append((
        "longrun_sched",
        float(np.mean(lats)) / 8 * 1e6,
        f"lat_ratio={last / max(first, 1e-9):.2f}",
    ))
    rows.append((
        "longrun_sched_mem",
        0.0,
        f"live_slots={led.reserved.shape[1]};base={led.base_slot};"
        f"retired={led.retired_slots}",
    ))

    router, rlats = run_router_leg(n_req)
    _check_bounded("longrun_router", router.ledger, TOTAL_SLOTS)
    rows.append((
        "longrun_router",
        float(np.mean(rlats)) * 1e6,
        f"live_slots={router.ledger.reserved.shape[1]};"
        f"base={router.ledger.base_slot}",
    ))

    sync, dlats = run_dcn_leg(n_steps)
    _check_bounded("longrun_dcn", sync.ledger, TOTAL_SLOTS)
    rows.append((
        "longrun_dcn",
        float(np.mean(dlats)) * 1e6,
        f"live_slots={sync.ledger.reserved.shape[1]};"
        f"base={sync.ledger.base_slot}",
    ))

    # Compacted vs never-compacted on one stream: byte-identical output.
    span = 20_000
    ca, _, _ = run_sched_leg(60, total_slots=span, retire=True)
    cb, _, _ = run_sched_leg(60, total_slots=span, retire=False)
    assert ca.state.ledger.base_slot > 0
    assert cb.state.ledger.base_slot == 0
    assert _canon(ca) == _canon(cb), (
        "compacted and never-compacted controllers diverged"
    )
    rows.append((
        "longrun_equiv", 0.0,
        f"byte-identical over {span} slots "
        f"(compacted {ca.state.ledger.reserved.shape[1]} vs "
        f"uncompacted {cb.state.ledger.reserved.shape[1]} live slots)",
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing (same ≥100k-slot spans, fewer submits)")
    ap.add_argument("--json", metavar="PATH",
                    help="append machine-readable rows (merges with an "
                         "existing file, e.g. bench_sched_scale's)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        try:  # as a module (benchmarks.run) vs standalone script (CI)
            from benchmarks.bench_sched_scale import append_json
        except ImportError:
            from bench_sched_scale import append_json

        append_json(rows, args.json)


if __name__ == "__main__":
    main()
