"""Telemetry-staleness benchmark: belief-scheduled vs oracle BASS.

Background-churn workload on a k=4 fat-tree (16 hosts, real path
diversity): a stream of jobs with storage-skewed replicas arrives while
bursty near-saturating background flows come and go and one edge→agg
link fails and recovers mid-run.  Legs:

* ``telemetry_oracle`` — plain BASS reading the TS ledger as ground
  truth, with a :class:`~repro.net.telemetry.LinkStatsMonitor` attached
  (monitoring alone must not change schedules — asserted byte-exactly
  against a monitor-less twin, the ``telemetry_parity_off`` row).
* ``telemetry_<estimator>_p<interval>`` — ``BassPolicy(telemetry=True)``
  scoring candidates against the measured-bandwidth belief refreshed
  every ``interval`` sim-seconds by an EWMA or sliding-window estimator,
  averaged over a few workload seeds.  ``derived`` reports makespan,
  mean job completion, and the ratio to the oracle leg.
* ``telemetry_staleness_probe`` — a deterministic 4-host scenario where
  the staleness failure mode is unambiguous: a saturating flow starts
  *after* the last poll, so a stale belief confidently routes a transfer
  into the saturated trunk (finish ≈ 44 s) while the oracle — and a
  belief polled frequently enough to catch the onset — keeps the task
  local (finish 13 s).

An honest finding the churn sweep surfaces (DESIGN.md §9): the oracle
is a *reference*, not an upper bound.  Greedy BASS drives each task to
its selfish best response against the true ledger; under replica skew
plus churn those truthful per-task choices over-offload and serialize
uplinks, so a chronically-pessimistic belief that hugs locality can
*beat* the oracle on mean job completion (classic price-of-anarchy
shape).  The probe row is where "stale = worse" is guaranteed; the
sweep reports whatever the measured regime actually does.

CSV: ``name,us_per_call,derived`` (us_per_call = wall µs per placed
task).  ``--json`` merges rows into the shared ``BENCH_SCHED.json``
artifact; ``--snapshot PATH`` dumps the oracle controller's full obs
snapshot (controller / wavefront / reroute / ledger / kernels /
telemetry sections + decision trace) as JSON — the observability-plane
artifact CI uploads.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.controller import BassPolicy, ClusterController
from repro.core.tasks import BackgroundFlow, Task
from repro.net import fat_tree_fabric

#: Edge→agg link killed mid-run: every victim has a surviving path via
#: the pod's other aggregation switch, so the reroute engine (not an
#: UnroutableError) handles the storm.
FAIL_LINK = "ea/p0e0a0"
FAIL_AT, RECOVER_AT = 8.0, 20.0

POLL_INTERVALS = [0.5, 1.0, 2.0, 4.0, 8.0]
SMOKE_POLL_INTERVALS = [1.0, 4.0]
ESTIMATORS = ["ewma", "window"]


def _hosts(k: int = 4) -> list:
    half = k // 2
    return [
        f"pod{p}/h{e}_{i}"
        for p in range(k)
        for e in range(half)
        for i in range(half)
    ]


def _jobs(hosts, n_jobs: int, n_tasks: int, gap: float = 8.0, seed: int = 7):
    """Job stream: (arrival, [tasks]), replicas concentrated on the first
    half of the hosts (hot HDFS storage nodes).  Arrival rate is sized so
    the *whole* cluster is feasible but the storage half alone is not:
    roughly half the tasks must offload to the idle compute half to keep
    up, so Algorithm 1's remote-vs-local bandwidth tradeoff fires
    constantly, through uplinks the churn keeps flapping.  (Oversubscribe
    the stream and the comparison inverts: under hopeless overload a
    chronically-pessimistic belief that hugs locality wastes the least
    bandwidth and beats the greedy oracle.)"""
    rng = np.random.default_rng(seed)
    storage = hosts[: len(hosts) // 2]
    out = []
    tid = 0
    for j in range(n_jobs):
        tasks = []
        for _ in range(n_tasks):
            reps = tuple(rng.choice(storage, size=2, replace=False))
            tasks.append(
                Task(
                    tid,
                    float(rng.integers(100, 400)),  # Mbit on 100 Mbps links
                    float(rng.integers(4, 10)),     # compute seconds
                    reps,
                )
            )
            tid += 1
        out.append((j * gap, tasks))
    return out


def _churn(hosts, n_flows: int, span: float, seed: int = 11):
    """Background cross-traffic the belief has to chase: *bursts* of
    near-saturating flows out of the storage half toward the compute half
    — exactly the uplinks remote placements need.  Bursts, not steady
    load: a link that looked idle at the last poll saturates moments
    later, so a stale belief confidently routes transfers into a wall
    (the oracle's plan sees the booked burst and schedules around it),
    while a fresh belief catches the onset.  Steady dense churn would do
    the opposite — a chronically-pessimistic belief hugs locality and
    accidentally beats the greedy oracle."""
    rng = np.random.default_rng(seed)
    storage = hosts[: len(hosts) // 2]
    compute = hosts[len(hosts) // 2:]
    flows = []
    for _ in range(n_flows):
        src = str(rng.choice(storage))
        dst = str(rng.choice(compute))
        start = float(rng.uniform(0.0, span))
        flows.append(
            BackgroundFlow(
                src,
                dst,
                float(rng.uniform(0.88, 0.98)),
                start,
                start + float(rng.uniform(2.0, 5.0)),
            )
        )
    return flows


def _canon(assignments):
    """Bit-exact image of a schedule (floats via ``hex``)."""
    out = []
    for a in sorted(assignments, key=lambda a: a.tid):
        t = a.transfer
        out.append((
            a.tid, a.node, a.source,
            a.start.hex(), a.finish.hex(),
            None if t is None else (
                t.links, float(t.start).hex(), float(t.end).hex(),
                tuple((s, float(f).hex()) for s, f in t.slot_fracs),
            ),
        ))
    return tuple(out)


def _run_stream(policy, jobs, flows, attach=None, trace=False):
    """One controller run over the churn workload; returns (ctrl, mk, dt)."""
    fabric = fat_tree_fabric(4, link_mbps=100.0)
    hosts = _hosts(4)
    ctrl = ClusterController(fabric, hosts, policy)
    if attach is not None:
        poll_interval, estimator = attach
        ctrl.attach_telemetry(poll_interval=poll_interval, estimator=estimator)
    if trace:
        ctrl.obs.trace.enable()
    for at, tasks in jobs:
        ctrl.submit(tasks, at=at)
    for fl in flows:
        ctrl.inject_flow(fl)
    ctrl.fail_link(FAIL_LINK, at=FAIL_AT)
    ctrl.recover_link(FAIL_LINK, at=RECOVER_AT)
    t0 = time.perf_counter()
    ctrl.run()
    dt = time.perf_counter() - t0
    sched = ctrl.schedule()
    mk = max((a.finish for a in sched.assignments), default=0.0)
    # Mean job completion (JT) is the staleness-sensitive metric: a few
    # belief-misrouted transfers stretch their own jobs long before they
    # move the whole stream's makespan.
    jt = float(np.mean([ctrl.job_metrics(j).jt for j in ctrl.jobs]))
    return ctrl, sched, (mk, jt), dt


def _probe(poll_interval: float, telemetry: bool, **est_kwargs) -> float:
    """Deterministic staleness probe: H0–H2 busy for 10 s, H3 idle; a
    flow saturating H0's uplink starts at t=0.5 — *after* the initial
    poll — and the single task (only replica on H0) arrives at t=1.
    Truth says: stay local on H0, finish 10+3=13.  A belief last polled
    at t=0 believes the fabric is idle, offloads to H3, and the commit
    plan on the true ledger crawls at the 5% residual.  Returns the
    task's finish time."""
    from repro.core.topology import two_tier_fabric

    hosts = ["H0", "H1", "H2", "H3"]
    ctrl = ClusterController(
        two_tier_fabric(2, 2),
        hosts,
        BassPolicy(telemetry=telemetry),
        idle={"H0": 10.0, "H1": 10.0, "H2": 10.0, "H3": 0.0},
    )
    ctrl.attach_telemetry(poll_interval=poll_interval, **est_kwargs)
    ctrl.inject_flow(BackgroundFlow("H0", "H2", 0.95, 0.5, 50.0))
    ctrl.submit([Task(0, 200.0, 3.0, ("H0",))], at=1.0)
    ctrl.run()
    (a,) = ctrl.schedule().assignments
    return a.finish


def run(smoke: bool = False, snapshot: str | None = None) -> list:
    n_jobs, n_tasks, n_flows = (4, 8, 10) if smoke else (10, 12, 30)
    intervals = SMOKE_POLL_INTERVALS if smoke else POLL_INTERVALS
    seeds = [(7, 11)] if smoke else [(7, 11), (8, 12)]
    hosts = _hosts(4)
    span = n_jobs * 10.0
    streams = [
        (_jobs(hosts, n_jobs, n_tasks, seed=js),
         _churn(hosts, n_flows, span=span, seed=fs))
        for js, fs in seeds
    ]
    total = n_jobs * n_tasks
    rows = []

    # Oracle baseline, monitor attached (telemetry counters tick, policy
    # never reads the belief) + byte-identity proof against a bare twin.
    oracle = []
    ctrl0 = None
    for i, (jobs, flows) in enumerate(streams):
        ctrl, sched, (mk, jt), dt = _run_stream(
            BassPolicy(), jobs, flows, attach=(1.0, "ewma"), trace=(i == 0)
        )
        assert len(sched.assignments) == total
        oracle.append((mk, jt, dt))
        if i == 0:
            ctrl0 = ctrl
            _, sched_bare, _, _ = _run_stream(BassPolicy(), jobs, flows)
            if _canon(sched.assignments) != _canon(sched_bare.assignments):
                raise SystemExit(
                    "telemetry-off parity violated: attaching a monitor "
                    "changed the oracle schedule"
                )
    mk0, jt0, dt0 = (float(np.mean([o[k] for o in oracle]))
                     for k in range(3))
    rows.append(("telemetry_oracle", dt0 / total * 1e6,
                 f"mk={mk0:.2f};mean_jt={jt0:.2f};seeds={len(seeds)}"))
    rows.append(("telemetry_parity_off", 0.0,
                 f"byte-identical ({total} tasks, monitor on vs off)"))

    # Belief legs: estimator x poll interval, averaged over the seeds.
    for est in ESTIMATORS:
        for poll in intervals:
            mks, jts, dts, polls = [], [], [], 0
            for jobs, flows in streams:
                ctrl, sched, (mk, jt), dt = _run_stream(
                    BassPolicy(telemetry=True), jobs, flows,
                    attach=(poll, est),
                )
                assert len(sched.assignments) == total
                assert np.isfinite(mk)
                mks.append(mk)
                jts.append(jt)
                dts.append(dt)
                polls = ctrl.telemetry.stats["polls"]
            mk, jt = float(np.mean(mks)), float(np.mean(jts))
            rows.append((
                f"telemetry_{est}_p{poll:g}",
                float(np.mean(dts)) / total * 1e6,
                f"mk={mk:.2f};mean_jt={jt:.2f};vs_oracle={jt / jt0:.3f}"
                f";polls={polls}",
            ))

    # Deterministic staleness probe: stale belief pays, fresh belief and
    # oracle agree.  These are exact event-driven outcomes, so assert the
    # ordering rather than eyeballing it.
    f_oracle = _probe(100.0, telemetry=False)
    f_stale = _probe(100.0, telemetry=True)
    # alpha=1 = instantaneous estimator: at the poll the belief equals the
    # ledger's occupancy bit-for-bit (the zero-staleness contract), so a
    # 0.25 s cadence catches the burst onset and agrees with the oracle.
    f_fresh = _probe(0.25, telemetry=True, alpha=1.0)
    assert f_stale > f_oracle + 10.0, (f_oracle, f_stale)
    assert abs(f_fresh - f_oracle) < 1e-9, (f_oracle, f_fresh)
    rows.append((
        "telemetry_staleness_probe", 0.0,
        f"oracle_finish={f_oracle:g};stale_finish={f_stale:g};"
        f"fresh_poll_finish={f_fresh:g}",
    ))

    if snapshot:
        snap = ctrl0.obs.snapshot()
        required = ("controller.", "wavefront.", "reroute.", "telemetry.")
        have = snap["counters"]
        missing = [p for p in required
                   if not any(k.startswith(p) for k in have)]
        for section in ("ledger", "kernels", "jobs", "telemetry"):
            if section not in snap:
                missing.append(section)
        if missing:
            raise SystemExit(f"obs snapshot incomplete, missing: {missing}")
        with open(snapshot, "w") as f:
            json.dump(snap, f, indent=1, default=str)
            f.write("\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: fewer jobs/flows and 2 poll intervals")
    ap.add_argument("--json", metavar="PATH",
                    help="merge machine-readable rows into the shared "
                         "benchmark artifact (dedupes by name + git sha)")
    ap.add_argument("--snapshot", metavar="PATH",
                    help="dump the oracle controller's obs snapshot JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, snapshot=args.snapshot)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        try:  # as a module (benchmarks.run) vs standalone script (CI)
            from benchmarks.bench_sched_scale import append_json
        except ImportError:
            from bench_sched_scale import append_json

        append_json(rows, args.json)


if __name__ == "__main__":
    main()
