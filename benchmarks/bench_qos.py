"""Benchmark: Example 3 — OpenFlow QoS queues vs single shared queue.

Paper setup: port max 150 Mbps, Q1=100 (shuffle), Q2=40, Q3=10 (background).
Derived value = shuffle completion seconds; the queued scheme must never be
slower and is strictly faster under background competition.  Also reports
the same mechanism applied to the TPU fleet's DCN classes (grad-sync vs
data-input vs checkpoint).  CSV: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

from repro.core.qos import Flow, QosPort, QueueSpec, shuffle_vs_default


def run() -> list:
    rows = []
    for n_bg in [0, 1, 2, 4]:
        t0 = time.perf_counter()
        queued, default = shuffle_vs_default(1000.0, 800.0, n_background=max(n_bg, 1))
        if n_bg == 0:
            queued, default = shuffle_vs_default(1000.0, 0.0001, 1)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"qos_shuffle_queued_bg{n_bg}", us / 2, round(queued, 3)))
        rows.append((f"qos_shuffle_default_bg{n_bg}", us / 2, round(default, 3)))

    # DCN traffic classes: grad-sync (Q1) vs input shards (Q2) vs ckpt (Q3),
    # 400 GB/s pod trunk. Values in seconds for a 100 GB grad flow vs two
    # 200 GB checkpoint pushes.
    port = QosPort(
        400.0,
        [QueueSpec("grad", 300.0, 0), QueueSpec("data", 80.0, 1), QueueSpec("ckpt", 20.0, 2)],
    )
    t0 = time.perf_counter()
    done = port.simulate(
        [
            Flow("grad", 100.0 * 8, "grad"),
            Flow("ckpt1", 200.0 * 8, "ckpt"),
            Flow("ckpt2", 200.0 * 8, "ckpt"),
        ]
    )
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("qos_dcn_gradsync_s", us, round(done["grad"], 3)))
    rows.append(("qos_dcn_ckpt_s", us, round(max(done["ckpt1"], done["ckpt2"]), 3)))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
