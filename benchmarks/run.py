"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

``python -m benchmarks.run`` executes all of them and prints a combined
``name,us_per_call,derived`` CSV:

* bench_discussion1 — Example 1 / Fig. 4 (BASS 35 s, BAR 38 s, HDS 39 s)
* bench_prebass     — Example 2 (Pre-BASS 34 s) + prefetch-gain sweep
* bench_qos         — Example 3 queue scheme (+ DCN traffic classes)
* bench_table1      — Table I(a)/(b) + Fig. 5 (Wordcount/Sort, 150M…5G)
* bench_sched_scale — beyond-paper: 4 096-host fleet controller throughput
* bench_online      — beyond-paper: online multi-job streams (all policies)
* bench_multipath   — beyond-paper: single- vs multipath BASS on a k=8
                      fat-tree with 10% random link failures
* bench_failover_scale — beyond-paper: spine-kill storm over ≥10k in-flight
                      transfers (batched vs sequential reroute engine) +
                      wavefront placement throughput on a degraded fabric
* bench_longrun     — beyond-paper: ≥100k-slot steady state (router, grad
                      sync, job stream) — bounded ledger memory and flat
                      per-submit latency under rolling-horizon compaction
* bench_telemetry   — beyond-paper: belief-scheduled vs oracle BASS under
                      background churn (telemetry-off parity, staleness
                      probe, poll-interval sweep, obs snapshot)
* bench_faults      — beyond-paper: seeded host-kill + straggler storm
                      (deterministic FaultPlan; asserts LATE speculation-on
                      beats speculation-off; re-execution/wasted-bytes rows)
* bench_recovery    — beyond-paper: control-plane crash-recovery (WAL
                      snapshot+replay vs genesis replay, headless-mode
                      completion, mailbox shed, crash makespan overhead)
* bench_hierarchy   — beyond-paper: flat vs pod-sharded control plane —
                      open-loop arrival streams (≥1M tasks on a k=8
                      fat-tree), p50/p99/p999 per-submit latency, and the
                      sharded ≥ flat throughput floor at 16,384 hosts
* bench_roofline    — §Roofline report from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys

from . import (
    bench_discussion1,
    bench_failover_scale,
    bench_faults,
    bench_hierarchy,
    bench_longrun,
    bench_multipath,
    bench_online,
    bench_prebass,
    bench_qos,
    bench_recovery,
    bench_roofline,
    bench_sched_scale,
    bench_table1,
    bench_telemetry,
)
from .bench_sched_scale import append_json

MODULES = [
    bench_discussion1,
    bench_prebass,
    bench_qos,
    bench_table1,
    bench_sched_scale,
    bench_online,
    bench_multipath,
    bench_failover_scale,
    bench_longrun,
    bench_telemetry,
    bench_faults,
    bench_recovery,
    bench_hierarchy,
    bench_roofline,
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH",
                    help="also merge every row into a machine-readable JSON "
                         "artifact (name, us_per_call, derived, git sha; "
                         "re-runs at the same sha replace their old rows)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    rows = []
    for mod in MODULES:
        try:
            for row in mod.run():
                rows.append(row)
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"{mod.__name__},ERROR,{type(e).__name__}:{e}", flush=True)
    if args.json:
        append_json(rows, args.json)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
