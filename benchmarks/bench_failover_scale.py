"""Benchmark: failure-storm fast path (batched reroute + degraded wavefront).

A k-ary fat-tree carries ≥10k in-flight shard transfers when a spine
(core) switch dies mid-stream.  The controller must replan every victim
at line rate: this benchmark times `ClusterController._reroute_dead`
under both engines — the batched `core.reroute` engine and the recorded
sequential per-victim loop (`reroute_engine = "sequential"`) — on
byte-identical controllers, asserts their reroute logs and schedules
agree bit-for-bit, and reports the speedup.  It also measures wavefront
placement throughput on the same fabric healthy vs. degraded (one core
down), the regime that used to fall back to the ~4×-slower sequential
`place` loop.

Derived values: victims replanned per second (reroute rows), tasks/s
(placement rows), and the two acceptance ratios — batched-vs-sequential
speedup (≥ 5× on the full config) and healthy-vs-degraded placement
ratio (≤ 1.5×).  CSV: ``name,us_per_call,derived``.

``--smoke`` runs the small config only (CI: byte-equality of the two
engines is still asserted; thresholds are enforced on the full config,
which runs locally via ``benchmarks.run``).  ``--json PATH`` appends
machine-readable rows.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.controller import BassPolicy, ClusterController
from repro.core.tasks import Task
from repro.core.topology import storage_hosts
from repro.net.fattree import fat_tree_fabric

# (fat-tree arity, tasks) — every task is a cross-pod remote transfer.
CONFIGS = [
    (4, 2000),       # 16 hosts — smoke config
    (8, 10000),      # 128 hosts, ≥10k in-flight — the acceptance config
]

T_KILL = 0.5
DEAD_CORE = "core0_0"
SPEEDUP_FLOOR = 5.0       # batched vs sequential on the full config
DEGRADED_RATIO_CEIL = 1.5  # healthy tasks/s vs degraded tasks/s


def storm_setup(k: int, n_tasks: int):
    """Sources in the lower pods, workers in the upper pods: every
    placement moves a shard across the core layer."""
    fab = fat_tree_fabric(k, link_mbps=100.0)
    hosts = storage_hosts(fab)
    half = len(hosts) // 2
    sources, workers = hosts[:half], hosts[half:]
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(sources), size=(n_tasks, 3))
    tasks = [
        Task(
            tid=i,
            size=float(256 + (i % 7) * 64),   # ~26–64 slots at 100 units
            compute=0.05,
            replicas=tuple(sources[j] for j in idx[i]),
        )
        for i in range(n_tasks)
    ]
    idle = {w: float(rng.uniform(0, 2.0)) for w in workers}
    return fab, workers, tasks, idle


def _controller(fab, workers, idle, engine: str) -> ClusterController:
    ctrl = ClusterController(
        fab, workers, BassPolicy(multipath=True), idle=idle,
        slot_duration=0.1,
    )
    ctrl.reroute_engine = engine
    return ctrl


def _canon_log(log):
    return [
        (r.flow, r.old_path, r.new_path, float(r.delivered).hex(),
         float(r.remaining).hex(), float(r.new_end).hex())
        for r in log
    ]


def _canon_sched(ctrl):
    out = []
    for a in ctrl.schedule().assignments:
        t = a.transfer
        out.append((
            a.tid, a.node, a.source, a.start.hex(), a.finish.hex(),
            None if t is None else (t.links, t.start.hex(), t.end.hex(),
                                    tuple((s, f.hex()) for s, f in
                                          t.slot_fracs)),
        ))
    return out


def run_reroute_leg(k: int, n_tasks: int, engine: str):
    fab, workers, tasks, idle = storm_setup(k, n_tasks)
    ctrl = _controller(fab, workers, idle, engine)
    ctrl.submit(tasks, at=0.0)
    ctrl.run_until(0.0)
    in_flight = sum(
        1 for rec in ctrl.jobs.values() for a in rec.assignments
        if a.transfer is not None and a.transfer.slot_fracs
        and a.transfer.end > T_KILL
    )
    ctrl.fail_switch(DEAD_CORE, at=T_KILL)
    t0 = time.perf_counter()
    ctrl.run_until(T_KILL)
    dt = time.perf_counter() - t0
    return ctrl, dt, in_flight, len(ctrl.reroute_log)


def run_placement_leg(k: int, n_tasks: int, degraded: bool):
    fab, workers, tasks, idle = storm_setup(k, n_tasks)
    ctrl = _controller(fab, workers, idle, "batched")
    if degraded:
        ctrl.fail_switch(DEAD_CORE, at=0.0)
    ctrl.submit(tasks, at=0.0)
    t0 = time.perf_counter()
    ctrl.run_until(0.0)
    dt = time.perf_counter() - t0
    assert len(ctrl.jobs[0].assignments) == n_tasks
    return dt


def run(configs=None) -> list:
    rows = []
    for k, n_tasks in configs if configs is not None else CONFIGS:
        n_hosts = k ** 3 // 4
        tag = f"failover_{n_hosts}h_{n_tasks}t"

        c_seq, dt_seq, in_flight, v_seq = run_reroute_leg(k, n_tasks,
                                                          "sequential")
        c_bat, dt_bat, _inf2, v_bat = run_reroute_leg(k, n_tasks, "batched")
        assert in_flight >= n_tasks * 0.9, "workload lost its in-flight set"
        assert v_bat == v_seq > 0
        assert _canon_log(c_bat.reroute_log) == _canon_log(c_seq.reroute_log)
        assert _canon_sched(c_bat) == _canon_sched(c_seq)
        speedup = dt_seq / dt_bat
        rows.append((f"{tag}_seq", dt_seq / v_seq * 1e6,
                     round(v_seq / dt_seq, 1)))
        rows.append((f"{tag}_batched", dt_bat / v_bat * 1e6,
                     round(v_bat / dt_bat, 1)))
        rows.append((f"{tag}_speedup", 0.0, round(speedup, 2)))

        dt_healthy = run_placement_leg(k, n_tasks, degraded=False)
        dt_degraded = run_placement_leg(k, n_tasks, degraded=True)
        ratio = dt_degraded / dt_healthy
        rows.append((f"{tag}_place_healthy", dt_healthy / n_tasks * 1e6,
                     round(n_tasks / dt_healthy, 0)))
        rows.append((f"{tag}_place_degraded", dt_degraded / n_tasks * 1e6,
                     round(n_tasks / dt_degraded, 0)))
        rows.append((f"{tag}_place_ratio", 0.0, round(ratio, 2)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config only (byte-equality still asserted)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write machine-readable rows (JSON)")
    args = ap.parse_args()
    configs = CONFIGS[:1] if args.smoke else CONFIGS
    rows = run(configs)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        from benchmarks.bench_sched_scale import write_json

        write_json(rows, args.json)
    if not args.smoke:
        by_name = {r[0]: r[2] for r in rows}
        for k, n_tasks in configs:
            if (k, n_tasks) != CONFIGS[-1]:
                continue  # thresholds bind on the acceptance config only
            tag = f"failover_{k ** 3 // 4}h_{n_tasks}t"
            if by_name[f"{tag}_speedup"] < SPEEDUP_FLOOR:
                raise SystemExit(
                    f"{tag}: batched reroute speedup "
                    f"{by_name[f'{tag}_speedup']} below {SPEEDUP_FLOOR}x"
                )
            if by_name[f"{tag}_place_ratio"] > DEGRADED_RATIO_CEIL:
                raise SystemExit(
                    f"{tag}: degraded placement {by_name[f'{tag}_place_ratio']}x "
                    f"slower than healthy (ceil {DEGRADED_RATIO_CEIL}x)"
                )


if __name__ == "__main__":
    main()
