"""Benchmark: Table I(a)/(b) + Fig. 5 — Wordcount & Sort at 150M…5G.

Regenerates the paper's workload shapes on the simulated 6-node/2-switch
testbed (ongoing background job, replicas=3, 64 MB blocks, 100 Mbps) and
reports JT means over seeds for BASS/BAR/HDS next to the paper's absolute
numbers.  Reproducible claims: the BASS<HDS ordering on every row, BASS's
edge over BAR in bandwidth-bound regimes, and the §V.B locality-ratio
non-monotonicity.  CSV: ``name,us_per_call,derived``(=JT seconds).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SCHEDULERS
from repro.core.simulator import evaluate_mapreduce
from repro.core.workloads import (
    DATA_SIZES_MB,
    PAPER_TABLE1,
    SORT,
    WORDCOUNT,
    make_instance,
)

SCHED_ORDER = ["bass", "bar", "hds"]


def run(seeds: int = 8, jobs=(("wordcount", WORDCOUNT), ("sort", SORT))) -> list:
    rows = []
    for jobname, job in jobs:
        for size, mb in DATA_SIZES_MB.items():
            n = seeds if mb <= 1024 else max(3, seeds // 2)
            for sname in SCHED_ORDER:
                jts, lrs = [], []
                t0 = time.perf_counter()
                for seed in range(n):
                    inst, rtasks, shuf = make_instance(job, mb, seed=seed)
                    m = evaluate_mapreduce(inst, SCHEDULERS[sname], rtasks, shuf)
                    jts.append(m.jt)
                    lrs.append(m.lr)
                us = (time.perf_counter() - t0) / n * 1e6
                paper = PAPER_TABLE1[jobname][size][sname.upper() if sname != "bass" else "BASS"]
                rows.append(
                    (
                        f"table1_{jobname}_{size}_{sname}",
                        us,
                        round(float(np.mean(jts)), 1),
                        round(float(np.mean(lrs)), 3),
                        paper,
                    )
                )
    return rows


def main() -> None:
    print("name,us_per_call,derived_jt_s,mean_lr,paper_jt_s")
    for row in run():
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
