"""Substrate tests: optimizer, checkpointing, data pipeline, runtime FT,
gradient compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, SyntheticLM, plan_epoch, uniform_shards
from repro.core.simulator import replay
from repro.core.topology import tpu_dcn_fabric
from repro.distributed.grad_compress import (
    compress,
    compress_with_feedback,
    decompress,
)
from repro.optim import AdamW, constant, global_norm, warmup_cosine
from repro.runtime import (
    HeartbeatMonitor,
    ProgressTracker,
    TrainSupervisor,
    elastic_mesh_shape,
)


# --- optimizer ---------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=constant(0.1), weight_decay=0.0, grad_clip=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    opt = AdamW(lr=constant(1.0), grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert float(gnorm) == pytest.approx(2e6, rel=1e-3)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1e-3, warmup=100, total=1000)
    assert float(sched(jnp.int32(0))) == pytest.approx(0.0)
    assert float(sched(jnp.int32(100))) == pytest.approx(1e-3, rel=1e-2)
    assert float(sched(jnp.int32(1000))) < 2e-4


# --- checkpointing --------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16) * 1.5},
        "count": jnp.int32(7),
    }
    ck = Checkpointer(tmp_path)
    ck.save(5, tree, blocking=True)
    step, restored = ck.restore(tree)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_latest_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in [1, 2, 3, 4]:
        ck.save(s, tree, blocking=True)
    assert ck.latest_step() == 4
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    ck.save(1, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


# --- data pipeline ---------------------------------------------------------------

def test_synthetic_deterministic_addressing():
    cfg = DataConfig(seq_len=64, global_batch=4, vocab_size=128, seed=3)
    src = SyntheticLM(cfg)
    b1 = src.batch(7)
    b2 = src.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_synthetic_copy_structure():
    cfg = DataConfig(seq_len=64, global_batch=1, vocab_size=128, seed=0)
    tok = SyntheticLM(cfg).sample(0, 0)
    half = 32
    agree = (tok[:half] == tok[half:]).mean()
    assert agree > 0.8        # 5% noise


def test_bass_shard_placement_valid():
    fab = tpu_dcn_fabric(1, 8)
    hosts = [f"pod0/host{i}" for i in range(8)]
    shards = uniform_shards(32, hosts, size_bytes=256e6, replication=3, seed=1)
    assigns, sched = plan_epoch(fab, hosts, {h: 0.0 for h in hosts}, shards)
    assert len(assigns) == 32
    assert {a.shard_id for a in assigns} == set(range(32))
    # local fetches dominate when the cluster starts idle
    local = sum(1 for a in assigns if a.source is None)
    assert local > len(assigns) / 2


# --- gradient compression ---------------------------------------------------------

@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_compress_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(3000), jnp.float32)
    q, s = compress(x)
    xh = decompress(q, s, x.shape)
    blockmax = float(jnp.abs(x).max())
    assert float(jnp.abs(x - xh).max()) <= blockmax / 127.0 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the *sum* of decompressed messages tracks the
    sum of true gradients — the residual never grows unboundedly."""
    rng = np.random.default_rng(0)
    res = jnp.zeros(4096)
    true_sum = np.zeros(4096)
    sent_sum = np.zeros(4096)
    for _ in range(30):
        g = jnp.asarray(rng.standard_normal(4096) * 0.1, jnp.float32)
        q, s, res = compress_with_feedback(g, res)
        sent_sum += np.asarray(decompress(q, s, g.shape))
        true_sum += np.asarray(g)
    # residual bounded by one quantization step's worth of signal
    assert np.abs(true_sum - sent_sum).max() == pytest.approx(
        float(jnp.abs(res).max()), rel=1e-5
    )
    assert float(jnp.abs(res).max()) < 0.05


# --- runtime -------------------------------------------------------------------

def test_progress_rate_formula():
    tr = ProgressTracker()
    tr.start(1, "w0", now=0.0)
    tr.update(1, 0.25, now=10.0)
    # rate = 0.25/10 → remaining = 0.75 / 0.025 = 30
    assert tr.remaining(1, now=10.0) == pytest.approx(30.0)


def test_straggler_detection():
    tr = ProgressTracker(straggler_factor=2.0)
    for i, score in enumerate([0.5, 0.5, 0.5, 0.04]):
        tr.start(i, f"w{i}", now=0.0)
        tr.update(i, score, now=10.0)
    assert tr.stragglers(now=10.0) == [3]


def test_elastic_mesh_shapes():
    assert elastic_mesh_shape(256, 16) == (16, 16)
    assert elastic_mesh_shape(255, 16) == (15, 16)   # lost a chip → 15 groups
    assert elastic_mesh_shape(8, 16) == ()
    assert elastic_mesh_shape(512, 16, prefer_pods=2) == (2, 16, 16)


def test_supervisor_restart_flow():
    mon = HeartbeatMonitor([f"h{i}" for i in range(4)], grace_s=5.0)
    calls = {}
    sup = TrainSupervisor(
        mon,
        chips_per_host=4,
        model_axis=4,
        rebuild=lambda shape: calls.setdefault("rebuild", shape),
        restore=lambda: 42,
    )
    for h in mon.hosts:
        mon.beat(h, now=0.0)
    assert sup.on_tick(10, now=1.0) is None
    mon.beat("h0", now=8.0); mon.beat("h1", now=8.0); mon.beat("h2", now=8.0)
    ev = sup.on_tick(11, now=9.0)           # h3 missed > 5 s
    assert ev is not None and ev.lost_hosts == ("h3",)
    assert ev.step == 42
    assert calls["rebuild"] == (3, 4)       # 12 chips → data=3, model=4
