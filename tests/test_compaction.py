"""Rolling-horizon TS-ledger compaction (DESIGN.md §7).

A ledger with periodic ``retire()`` must answer every query/plan/commit
identically (modulo the origin shift) to a never-compacted twin — the
hypothesis suites below drive random op streams and full controller
scenarios (including mid-transfer reroute storms) against both and demand
bit-equality.  The satellites ride along: the live-window ``utilization``
definition, allocation-free read-only queries, and ``scratch_ledger``
horizon/origin inheritance for BAR.
"""
import numpy as np
import pytest

from repro.core.controller import BassPolicy, ClusterController, ClusterState
from repro.core.tasks import BackgroundFlow, Task
from repro.core.timeslot import TimeSlotLedger
from repro.core.topology import (
    paper_fig2_fabric,
    storage_hosts,
    two_tier_fabric,
)
from repro.net.fattree import fat_tree_fabric


def _twins(slot=1.0, horizon=64):
    fab = two_tier_fabric(2, 3, 100.0, 100.0)
    a = TimeSlotLedger(fab, slot, horizon)      # compacting
    b = TimeSlotLedger(fab, slot, horizon)      # never compacts
    b.retire_stride = None
    return fab, a, b


def _assert_live_windows_equal(a: TimeSlotLedger, b: TimeSlotLedger):
    """a's physical matrix must equal the same absolute span of b."""
    off = a.base_slot - b.base_slot
    n = a.reserved.shape[1]
    b._ensure(b.base_slot + off + n - 1)
    assert np.array_equal(a.reserved, b.reserved[:, off : off + n])


# ---------------------------------------------------------------------------
# ledger-level equivalence
# ---------------------------------------------------------------------------


def test_retire_drops_past_keeps_tails():
    fab, a, b = _twins()
    rows = a.rows(fab.path("H0", "H4"))
    pa = a.plan_transfer(1000.0, rows, not_before=0.0)   # ~10 slots
    pb = b.plan_transfer(1000.0, rows, not_before=0.0)
    assert pa == pb
    a.commit(pa)
    b.commit(pb)
    dropped = a.retire(5.0)                               # mid-transfer
    assert dropped == 5 and a.base_slot == 5
    assert a.retired_slots == 5
    _assert_live_windows_equal(a, b)
    # The surviving tail releases identically on both.
    ka = a.release_after(pa, 5.0)
    kb = b.release_after(pb, 5.0)
    assert ka == kb
    assert a.plan_bytes(ka) == b.plan_bytes(kb)
    _assert_live_windows_equal(a, b)


def test_retire_is_monotone_and_idempotent():
    fab, a, _ = _twins()
    assert a.retire(10.0) == 10
    assert a.retire(10.0) == 0
    assert a.retire(3.0) == 0          # never moves backwards
    assert a.base_slot == 10


def test_retire_past_everything_booked():
    fab, a, b = _twins()
    rows = a.rows(fab.path("H0", "H1"))
    for led in (a, b):
        led.commit(led.plan_transfer(300.0, rows, not_before=0.0))
    a.retire(500.0)
    assert a.base_slot == 500
    assert a.reserved.shape[1] <= 64 and not a.reserved.any()
    # Planning resumes seamlessly at the new origin.
    pa = a.plan_transfer(200.0, rows, not_before=500.0)
    pb = b.plan_transfer(200.0, rows, not_before=500.0)
    assert pa == pb
    a.commit(pa)
    b.commit(pb)
    _assert_live_windows_equal(a, b)


def test_writes_before_origin_raise():
    fab, a, b = _twins()
    rows = a.rows(fab.path("H0", "H1"))
    plan = a.plan_transfer(100.0, rows, not_before=0.0)
    a.retire(50.0)
    with pytest.raises(ValueError, match="retired origin"):
        a.plan_transfer(100.0, rows, not_before=0.0)
    with pytest.raises(ValueError, match="retired origin"):
        a.commit(plan)
    with pytest.raises(ValueError, match="retired origin"):
        a.commit_batch([plan])
    # occupy/release clamp instead: the past portion is delivered history.
    a.occupy(rows, 0.0, 55.0, 0.25)
    b.occupy(rows, 0.0, 55.0, 0.25)
    a.release(plan)                    # fully-retired plan: no-op
    _assert_live_windows_equal(a, b)


def _twin_op_stream(seed: int, n_ops: int):
    """Random plan/commit/occupy/release_after/query streams with the
    clock advancing and the compacted ledger retiring along the way."""
    fab, a, b = _twins()
    hosts = [f"H{i}" for i in range(6)]
    rng = np.random.default_rng(seed)
    now = 0.0
    committed = []
    for _ in range(n_ops):
        op = ["plan", "occupy", "release_after", "query", "advance"][
            int(rng.integers(0, 5))
        ]
        s, d = rng.choice(hosts, 2, replace=False)
        rows = a.rows(fab.path(str(s), str(d)))
        if op == "advance":
            now += float(rng.uniform(0.5, 30.0))
            a.retire(now)
            continue
        if op == "plan":
            nb = now + float(rng.uniform(0, 10))
            size = float(rng.uniform(10, 800))
            pa = a.plan_transfer(size, rows, not_before=nb)
            pb = b.plan_transfer(size, rows, not_before=nb)
            assert pa == pb
            a.commit(pa)
            b.commit(pb)
            committed.append((pa, pb))
        elif op == "occupy":
            t0 = now + float(rng.uniform(0, 5))
            t1 = t0 + float(rng.uniform(0.5, 10))
            frac = float(rng.uniform(0.05, 0.9))
            a.occupy(rows, t0, t1, frac)
            b.occupy(rows, t0, t1, frac)
        elif op == "release_after" and committed:
            j = int(rng.integers(0, len(committed)))
            qa, qb = committed[j]
            t = now + float(rng.uniform(0, 5))
            ka = a.release_after(qa, t)
            kb = b.release_after(qb, t)
            assert ka == kb
            assert a.plan_bytes(ka) == b.plan_bytes(kb)
            committed[j] = (ka, kb)
        else:
            t = now + float(rng.uniform(0, 100))
            slot = a.slot_of(t)
            assert a.residual_fraction(rows, slot) == \
                b.residual_fraction(rows, slot)
            assert a.path_bandwidth(rows, t) == b.path_bandwidth(rows, t)
            assert a.min_path_bandwidth(rows, now, t) == \
                b.min_path_bandwidth(rows, now, t)
            got = a.path_bandwidth_batch([rows, ()], t)
            want = b.path_bandwidth_batch([rows, ()], t)
            assert np.array_equal(got, want)
    _assert_live_windows_equal(a, b)


@pytest.mark.parametrize("seed", range(12))
def test_compacted_twin_answers_identically_seeded(seed):
    _twin_op_stream(seed, n_ops=30)


def _batch_planning_case(seed: int, sizes):
    """plan_transfer_batch over shifted vs unshifted origins: same plans."""
    fab, a, b = _twins()
    hosts = [f"H{i}" for i in range(6)]
    rng = np.random.default_rng(seed)
    now = 0.0
    for size in sizes:
        cands = []
        for _ in range(3):
            s, d = rng.choice(hosts, 2, replace=False)
            cands.append(a.rows(fab.path(str(s), str(d))))
        nb = now + float(rng.uniform(0, 3))
        pa = a.plan_transfer_batch(size, cands, not_before=nb)
        pb = b.plan_transfer_batch(size, cands, not_before=nb)
        assert pa == pb
        a.commit(pa[0])
        b.commit(pb[0])
        now += float(rng.uniform(0, 10))
        a.retire(now)
    _assert_live_windows_equal(a, b)


@pytest.mark.parametrize("seed", range(6))
def test_batch_planning_matches_across_origin_shift_seeded(seed):
    rng = np.random.default_rng(1000 + seed)
    sizes = [float(s) for s in rng.uniform(10.0, 500.0, size=6)]
    _batch_planning_case(seed, sizes)


# ---------------------------------------------------------------------------
# controller-level equivalence (wavefront + reroute storms under retirement)
# ---------------------------------------------------------------------------


def _canon_sched(ctrl):
    out = []
    for a in sorted(ctrl.schedule().assignments, key=lambda x: x.tid):
        t = a.transfer
        out.append((
            a.tid, a.node, a.source, a.start.hex(), a.finish.hex(),
            None if t is None else (t.links, t.start.hex(), t.end.hex(),
                                    tuple((s, f.hex()) for s, f in
                                          t.slot_fracs)),
        ))
    return out


def _canon_log(ctrl):
    return [
        (r.flow, r.old_path, r.new_path, float(r.delivered).hex(),
         float(r.remaining).hex(), float(r.new_end).hex())
        for r in ctrl.reroute_log
    ]


def _storm_controller(stride, n_tasks=160, seed=0, engine="batched"):
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    half = len(hosts) // 2
    sources, workers = hosts[:half], hosts[half:]
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(sources), size=(n_tasks, 3))
    mk = lambda tid0: [
        Task(tid=tid0 + i, size=float(200 + (i % 7) * 64), compute=0.05,
             replicas=tuple(sources[j] for j in idx[i]))
        for i in range(n_tasks)
    ]
    idle = {w: float(rng.uniform(0, 2.0)) for w in workers}
    ctrl = ClusterController(
        fab, workers, BassPolicy(multipath=True), idle=idle,
        slot_duration=0.1,
    )
    ctrl.state.ledger.retire_stride = stride
    ctrl.reroute_engine = engine
    ctrl.submit(mk(0), at=0.0)
    ctrl.fail_switch("core0_0", at=0.5)
    ctrl.fail_link("ea/p3e0a0", at=1.0)
    ctrl.submit(mk(10_000), at=20.0)       # arrives after origin shifts
    ctrl.recover_link("ea/p3e0a0", at=30.0)
    ctrl.run_until(120.0)
    return ctrl


@pytest.mark.parametrize("engine", ["batched", "sequential"])
def test_storm_equivalence_under_compaction(engine):
    """Mid-transfer reroute storms + a post-shift second job: aggressive
    compaction and no compaction emit bit-identical schedules, reroute
    logs, and ledgers — under both reroute engines."""
    ca = _storm_controller(stride=4, engine=engine)
    cb = _storm_controller(stride=None, engine=engine)
    assert ca.state.ledger.base_slot > 0, "compaction never engaged"
    assert cb.state.ledger.base_slot == 0
    assert _canon_sched(ca) == _canon_sched(cb)
    assert _canon_log(ca) == _canon_log(cb)
    assert len(ca.reroute_log) > 0
    _assert_live_windows_equal(ca.state.ledger, cb.state.ledger)
    for jid in ca.jobs:
        ma, mb = ca.job_metrics(jid), cb.job_metrics(jid)
        assert (ma.mt, ma.rt, ma.jt, ma.lr, ma.rerouted) == \
            (mb.mt, mb.rt, mb.jt, mb.lr, mb.rerouted)


def _check_storm_equiv(seed: int, stride: int = 2, n_tasks: int = 60):
    ca = _storm_controller(stride=stride, n_tasks=n_tasks, seed=seed)
    cb = _storm_controller(stride=None, n_tasks=n_tasks, seed=seed)
    assert _canon_sched(ca) == _canon_sched(cb)
    assert _canon_log(ca) == _canon_log(cb)
    _assert_live_windows_equal(ca.state.ledger, cb.state.ledger)


@pytest.mark.parametrize("seed", range(4))
def test_storm_equivalence_seeded(seed):
    _check_storm_equiv(seed)


def test_run_until_retires_on_quiet_controller():
    """A controller idling past its stride compacts without any event."""
    fab = paper_fig2_fabric(100.0)
    ctrl = ClusterController(fab, ["N1", "N2", "N3", "N4"])
    ctrl.submit(
        [Task(tid=0, size=300.0, compute=2.0, replicas=("N2",))], at=0.0
    )
    ctrl.run_until(0.0)
    assert ctrl.state.ledger.base_slot == 0
    ctrl.run_until(10_000.0)           # no events in (0, 10k]
    led = ctrl.state.ledger
    assert led.base_slot >= 10_000 - led.retire_stride - 1
    assert led.reserved.shape[1] < 10_000


# ---------------------------------------------------------------------------
# satellites
# ---------------------------------------------------------------------------


def test_utilization_invariant_under_ensure_doubling():
    """Regression: the old definition divided by the whole allocation, so
    a `_ensure` doubling halved the reported utilization."""
    fab, led, _ = _twins()
    rows = led.rows(fab.path("H0", "H4"))
    led.commit(led.plan_transfer(400.0, rows, not_before=0.0))  # 4 full slots
    u0 = led.utilization()
    # 4 path links fully booked over slots 0..3 → half the 8-link window.
    assert u0 == pytest.approx(4 * 4 / (8 * 4))
    width0 = led.reserved.shape[1]
    led._ensure(led.base_slot + 4 * width0)    # force a doubling
    assert led.reserved.shape[1] > width0
    assert led.utilization() == u0
    # ...and origin shifts do not change the booked-window arithmetic.
    led.retire(1.0)
    assert led.utilization() == pytest.approx(4 * 3 / (8 * 3))


def test_utilization_empty_is_zero():
    _, led, _ = _twins()
    assert led.utilization() == 0.0


def test_readonly_queries_never_allocate():
    fab, led, twin = _twins(horizon=64)
    rows = led.rows(fab.path("H0", "H4"))
    led.commit(led.plan_transfer(200.0, rows, not_before=0.0))
    twin.commit(twin.plan_transfer(200.0, rows, not_before=0.0))
    width0 = led.reserved.shape[1]
    far = 5_000.0
    # The twin materializes the horizon; answers must match the clamp.
    twin._ensure(twin.slot_of(far))
    assert led.residual_fraction(rows, led.slot_of(far)) == \
        twin.residual_fraction(rows, twin.slot_of(far)) == 1.0
    assert led.path_bandwidth(rows, far) == twin.path_bandwidth(rows, far)
    assert np.array_equal(
        led.path_bandwidth_batch([rows], far),
        twin.path_bandwidth_batch([rows], far),
    )
    assert led.min_path_bandwidth(rows, 1.0, far) == \
        twin.min_path_bandwidth(rows, 1.0, far)
    assert led.reserved.shape[1] == width0, "a read-only query allocated"
    # Reads of the retired past answer "free" without resurrecting columns.
    led.retire(100.0)
    width1 = led.reserved.shape[1]
    assert led.residual_fraction(rows, 0) == 1.0
    assert led.path_bandwidth(rows, 0.0) == 100.0
    assert led.reserved.shape[1] == width1


def test_scratch_ledger_inherits_horizon_and_origin():
    fab = paper_fig2_fabric(100.0)
    state = ClusterState(fab, ["N1", "N2", "N3", "N4"], horizon_slots=64)
    state.background.append(BackgroundFlow("N1", "N3", 0.5, 10.0, 900.0))
    state.ledger._ensure(1500)          # the live ledger grew
    state.ledger.retire_to(800)         # ...and its origin advanced
    scratch = state.scratch_ledger()
    assert scratch.reserved.shape[1] == state.ledger.reserved.shape[1]
    assert scratch.base_slot == state.ledger.base_slot
    # Background flows replay clamped to the live window.
    rows = scratch.rows(fab.path("N1", "N3"))
    assert scratch.residual_fraction(rows, 850) == pytest.approx(0.5)
    assert scratch.residual_fraction(rows, 901) == 1.0
    # Explicit horizon still wins when a caller asks for one (background
    # replay may grow it past the request, never below).
    bare = ClusterState(fab, ["N1", "N2"], horizon_slots=64)
    assert bare.scratch_ledger(horizon_slots=32).reserved.shape[1] == 32


def test_bar_places_long_horizon_workload():
    """BAR's static-belief phase used to reason on a hardcoded-256-slot,
    origin-0 scratch; a job arriving deep into a long-running
    controller's life must plan cleanly on an inherited window."""
    fab = two_tier_fabric(2, 3, 100.0, 400.0)
    workers = storage_hosts(fab)
    ctrl = ClusterController(fab, workers, "bar")
    ctrl.run_until(5_000.0)             # a long quiet life: origin shifts
    assert ctrl.state.ledger.base_slot > 0
    rng = np.random.default_rng(0)
    tasks = [
        Task(tid=i, size=float(rng.uniform(100, 500)),
             compute=float(rng.uniform(1, 5)),
             replicas=tuple(rng.choice(workers, 2, replace=False)))
        for i in range(12)
    ]
    ctrl.submit(tasks, at=5_000.0)
    ctrl.run()
    rec = ctrl.jobs[0]
    assert rec.placed and len(rec.assignments) == 12
    assert all(a.start >= 5_000.0 - 1e-9 for a in rec.assignments)
    # The live matrix stayed O(window), not O(elapsed time).
    assert ctrl.state.ledger.reserved.shape[1] < 2_048


def test_router_stays_bounded_over_long_service():
    from repro.serving.engine import Request
    from repro.serving.router import BassRouter

    router = BassRouter([f"rep{i}" for i in range(4)])
    span = 30_000 * router.ledger.slot_duration     # 30k slots
    for i in range(120):
        req = Request(rid=i, prompt=np.zeros(128, dtype=np.int32),
                      max_new=16, prefix_hash=i % 8)
        router.route(req, now=span * i / 120)
        router.update_backlog(
            {r: 0.0 for r in router.replicas}
        )
    led = router.ledger
    assert led.base_slot > 0
    assert led.reserved.shape[1] < 8_192
    assert not router.controller.jobs   # per-request records still pruned


# ---------------------------------------------------------------------------
# hypothesis property suites (run where hypothesis is installed, e.g. CI) —
# the seeded sweeps above keep deterministic coverage everywhere else.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2**16), n_ops=st.integers(5, 40))
    @settings(max_examples=40, deadline=None)
    def test_compacted_twin_answers_identically(seed, n_ops):
        _twin_op_stream(seed, n_ops)

    @given(
        sizes=st.lists(st.floats(10.0, 500.0), min_size=1, max_size=8),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def test_batch_planning_matches_across_origin_shift(sizes, seed):
        _batch_planning_case(seed, sizes)

    @given(seed=st.integers(0, 2**10))
    @settings(max_examples=6, deadline=None)
    def test_storm_equivalence_property(seed):
        _check_storm_equiv(seed, n_tasks=40)
