"""Control-plane crash-recovery suite (DESIGN.md §11).

The contract under test: a controller rebuilt from a full-fidelity
snapshot plus a write-ahead-journal replay must be **byte-identical** to a
twin that never crashed — schedule dumps, reroute logs, ledger bytes,
flow-table dumps and every behavioral obs counter — at *any* crash point
of a seeded fault storm.  Plus the headless data-plane semantics: while
the control plane is down, in-flight transfers on alive paths complete,
new jobs queue in a bounded mailbox (overflow sheds), and the poll/
heartbeat chains are suspended and re-armed on recovery.

No ``hypothesis`` in this environment: the round-trip property suite
draws its cases from seeded ``random.Random`` streams instead, the same
convention as ``test_reroute_props``/``test_scheduler_props``.
"""
import pickle
import random
from collections import deque

import numpy as np
import pytest

from repro.core.controller import (
    BassPolicy,
    ClusterController,
    ClusterState,
    RetryPolicy,
)
from repro.core.faults import ControllerCrash, FaultPlan
from repro.core.journal import ControllerSnapshot, Journal
from repro.core.tasks import BackgroundFlow, Task
from repro.core.topology import storage_hosts
from repro.net.events import ControllerDown, ControllerUp
from repro.net.fattree import fat_tree_fabric
from repro.net.telemetry import WindowRateEstimator
from repro.runtime.ft import HeartbeatMonitor

SEED = 7


# ---------------------------------------------------------------------------
# workload + canon helpers
# ---------------------------------------------------------------------------


def storm_fixture(n_tasks=12):
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    half = len(hosts) // 2
    sources, workers = hosts[:half], hosts[half:]
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(sources), size=(n_tasks, 3))
    tasks = [
        Task(
            tid=i,
            size=float(32 + (i % 5) * 16),
            compute=2.0,
            replicas=tuple(sources[j] for j in idx[i]),
        )
        for i in range(n_tasks)
    ]
    return fab, workers, tasks


def build(fab, workers, **kw):
    kw.setdefault("slot_duration", 0.1)
    kw.setdefault("retry", RetryPolicy(max_attempts=4, backoff_s=0.5))
    return ClusterController(fab, workers, BassPolicy(multipath=True), **kw)


#: Counter prefixes outside the equivalence canon: wavefront hit/miss
#: ratios are artifacts of the planner *cache* (placements are
#: bit-identical regardless — PR 3's tested contract), and recovery.*
#: are meta-counters of the recovery machinery itself.
_CANON_EXCLUDE = ("wavefront.", "recovery.")


def canon_counters(ctrl):
    return {
        k: v
        for k, v in sorted(ctrl.obs.snapshot(trace_tail=0)["counters"].items())
        if not k.startswith(_CANON_EXCLUDE)
    }


def canon_sched(ctrl):
    out = []
    for a in ctrl.schedule().assignments:
        t = a.transfer
        out.append((
            a.tid, a.node, a.source, a.start.hex(), a.finish.hex(),
            None if t is None else (t.links, t.start.hex(), t.end.hex(),
                                    tuple((s, f.hex()) for s, f in
                                          t.slot_fracs)),
        ))
    return out


def canon_reroutes(ctrl):
    return [
        (float(r.at).hex(), r.flow, r.dead_links, r.src, r.dst,
         r.old_path, r.new_path, float(r.delivered).hex(),
         float(r.remaining).hex(), float(r.old_end).hex(),
         float(r.new_end).hex())
        for r in ctrl.reroute_log
    ]


def canon(ctrl):
    led = ctrl.state.ledger
    return {
        "sched": canon_sched(ctrl),
        "reroutes": canon_reroutes(ctrl),
        "counters": canon_counters(ctrl),
        "ledger": (led.reserved.tobytes(), led.base_slot, led.retired_slots),
        "tables": tuple(ctrl.dataplane.tables.dump()),
        "shed": list(ctrl.shed_jobs),
    }


def storm_script(fab, workers, tasks, with_telemetry=True):
    """The seeded storm as a list of (label, entry-point call) steps —
    crash points are injected *between* any two of these."""
    plan = FaultPlan.generate(
        SEED, workers, 0.5, 3.0, n_crashes=2, mttr=2.0,
        n_stragglers=3, slow_factor=(4.0, 8.0),
        n_ctrl_crashes=1, ctrl_mttr=0.8,
    )
    first = fab.path(tasks[0].replicas[0], workers[0])
    steps = []
    if with_telemetry:
        steps.append(("attach_telemetry",
                      lambda c: c.attach_telemetry(estimator="window")))
    steps += [
        ("submit0", lambda c: c.submit(tasks[: len(tasks) // 2], at=0.0)),
        ("run0", lambda c: c.run_until(0.0)),
        ("flow", lambda c: c.inject_flow(
            BackgroundFlow(tasks[0].replicas[0], workers[0], 0.3, 0.4, 1.2))),
        ("raw", lambda c: c.reserve_transfer_at(0.6, 24.0, first, tag="sync")),
        ("faults", plan.apply),
        ("run1", lambda c: c.run_until(1.0)),
        ("submit1", lambda c: c.submit(tasks[len(tasks) // 2:], at=1.5)),
        ("run", lambda c: c.run()),
    ]
    return steps


# ---------------------------------------------------------------------------
# tentpole: crash-point equivalence sweep
# ---------------------------------------------------------------------------


def _script_len():
    fab, workers, tasks = storm_fixture()
    return len(storm_script(fab, workers, tasks))


@pytest.mark.parametrize("crash_at", range(_script_len() + 1))
def test_crash_point_equivalence(crash_at):
    """At *every* crash point of the seeded storm, snapshot + journal
    replay reproduces the never-crashed twin byte-for-byte."""
    fab, workers, tasks = storm_fixture()
    steps = storm_script(fab, workers, tasks)

    a = build(fab, workers)
    a.attach_journal()
    for _label, step in steps[:crash_at]:
        step(a)
    snap = a.snapshot()
    for _label, step in steps[crash_at:]:
        step(a)
    want = canon(a)

    # The crashed controller: restore the snapshot from *bytes* (nothing
    # shared with the dead process) and replay the journaled suffix.
    snap2 = ControllerSnapshot.from_bytes(snap.to_bytes())
    journal = Journal.from_bytes(a.journal.to_bytes())
    assert snap2.lsn <= journal.lsn
    b = ClusterController.recover_from(fab, snap2, journal)
    assert canon(b) == want
    # The meta-counters prove it actually recovered + replayed.
    got = b.obs.snapshot(trace_tail=0)["counters"]
    assert got["recovery.recoveries"] == 1
    assert got["recovery.replayed"] == journal.lsn - snap2.lsn


def test_recovered_controller_keeps_journaling():
    """After recovery the journal is re-attached: later entry points
    append past the replayed suffix, so a second crash also recovers."""
    fab, workers, tasks = storm_fixture(n_tasks=6)
    a = build(fab, workers)
    a.attach_journal()
    a.submit(tasks[:3], at=0.0)
    a.run()
    snap = a.snapshot()
    lsn0 = a.journal.lsn

    b = ClusterController.recover_from(fab, snap, a.journal)
    assert b.journal is a.journal
    b.submit(tasks[3:], at=b.now)
    b.run()
    assert b.journal.lsn > lsn0

    c = ClusterController.recover_from(fab, snap, b.journal)
    assert canon(c) == canon(b)


def test_journal_records_resolved_args():
    """``at=None`` defaults and auto job ids are materialized into the
    record — replay must not depend on the crashed process's counters."""
    fab, workers, tasks = storm_fixture(n_tasks=4)
    ctrl = build(fab, workers)
    journal = ctrl.attach_journal()
    jid = ctrl.submit(tasks, at=2.5)
    ctrl.fail_host(workers[0])       # at=None -> resolved to now
    ops = [(r.op, r.args) for r in journal.records]
    assert ops[0] == ("submit", (2.5, jid, tuple(tasks)))
    assert ops[1] == ("fail_host", (workers[0], ctrl.now))


def test_run_journals_once():
    """``run()`` is one record; the inner ``run_until`` targets it picks
    off the heap are its own implementation detail."""
    fab, workers, tasks = storm_fixture(n_tasks=4)
    ctrl = build(fab, workers)
    journal = ctrl.attach_journal()
    ctrl.submit(tasks, at=0.0)
    ctrl.run()
    assert [r.op for r in journal.records] == ["submit", "run"]


def test_journaled_controller_rejects_estimator_objects():
    fab, workers, _tasks = storm_fixture(n_tasks=4)
    ctrl = build(fab, workers)
    ctrl.attach_journal()
    est = WindowRateEstimator(
        len(ctrl.state.ledger.capacity), ctrl.state.ledger.capacity
    )
    with pytest.raises(ValueError, match="named estimator"):
        ctrl.attach_telemetry(estimator=est)


# ---------------------------------------------------------------------------
# satellite: seeded round-trip property suite (snapshot -> bytes -> restore)
# ---------------------------------------------------------------------------


def _deep_eq(x, y):
    if isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
        return (isinstance(x, np.ndarray) and isinstance(y, np.ndarray)
                and x.dtype == y.dtype and x.shape == y.shape
                and bool(np.all(x == y)))
    if isinstance(x, dict):
        return (isinstance(y, dict) and x.keys() == y.keys()
                and all(_deep_eq(x[k], y[k]) for k in x))
    if isinstance(x, (set, frozenset)):
        return type(x) is type(y) and sorted(x) == sorted(y)
    if isinstance(x, (list, tuple, deque)):
        return (type(x) is type(y) and len(x) == len(y)
                and all(_deep_eq(a, b) for a, b in zip(x, y)))
    return pickle.dumps(x) == pickle.dumps(y)


def _comparable_payload(payload):
    """Snapshot payload minus the recovery meta-counters — taking a
    snapshot (and recovering from one) bumps ``recovery.*``, which is
    bookkeeping *about* the mechanism, not controller state."""
    q = dict(payload)
    obs = dict(q["obs"])
    obs["counters"] = {k: v for k, v in obs["counters"].items()
                       if not k.startswith("recovery.")}
    q["obs"] = obs
    return q


@pytest.mark.parametrize("case", range(6))
def test_snapshot_roundtrip_at_random_storm_points(case):
    """snapshot -> bytes -> restore -> snapshot is the identity — ledger
    bytes, event-heap order, flow-table dumps and estimator state — at a
    random point of a seeded fault storm."""
    rng = random.Random(1000 + case)
    fab, workers, tasks = storm_fixture()
    plan = FaultPlan.generate(
        100 + case, workers, 0.5, 3.0, n_crashes=2, mttr=2.0,
        n_stragglers=2, slow_factor=(3.0, 6.0),
        n_ctrl_crashes=case % 2, ctrl_mttr=0.5,
    )
    ctrl = build(fab, workers)
    ctrl.attach_telemetry(estimator=rng.choice(["ewma", "window"]))
    # Generous grace: nobody feeds beats in this storm, and mass heartbeat
    # kills are test_faults territory — here the monitor only has to
    # round-trip its state.
    ctrl.attach_heartbeats(interval=0.5, grace_s=100.0)
    ctrl.submit(tasks, at=0.0)
    plan.apply(ctrl)
    ctrl.run_until(rng.uniform(0.0, 4.0))

    snap = ctrl.snapshot()
    restored = ClusterController.recover_from(
        fab, ControllerSnapshot.from_bytes(snap.to_bytes())
    )
    again = restored.snapshot()
    assert _deep_eq(
        _comparable_payload(snap.payload), _comparable_payload(again.payload)
    ), "round-trip not identity"
    # ...and the restored controller finishes exactly like the original.
    ctrl.run()
    restored.run()
    assert canon(restored) == canon(ctrl)
    est0, est1 = ctrl.telemetry.estimator, restored.telemetry.estimator
    assert _deep_eq(est0.dump_state(), est1.dump_state())
    assert [h for h in ctrl.heartbeats.hosts] == \
        [h for h in restored.heartbeats.hosts]


# ---------------------------------------------------------------------------
# satellite: ClusterState.restore fidelity (retired_slots + device mirror)
# ---------------------------------------------------------------------------


class _MirrorStub:
    def __init__(self):
        self.invalidated = 0

    def invalidate(self):
        self.invalidated += 1

    def note_flat(self, *a):  # pragma: no cover - defensive
        pass

    def note_grid(self, *a):  # pragma: no cover - defensive
        pass


def test_state_restore_crosses_retire_and_invalidates_mirror():
    fab, workers, tasks = storm_fixture(n_tasks=4)
    state = ClusterState(fab, workers, slot_duration=0.1, horizon_slots=64)
    rows = state.ledger.path_rows(tasks[0].replicas[0], workers[0])
    plan = state.ledger.plan_transfer(40.0, rows, not_before=0.0)
    state.ledger.commit(plan)
    snap = state.snapshot()
    reserved0 = state.ledger.reserved.copy()

    # Cross a retire: the window origin moves, history is dropped.
    mirror = _MirrorStub()
    state.ledger._mirror = mirror
    retired = state.ledger.retire_to(state.ledger.slot_of(plan.end) + 8)
    assert retired > 0
    assert state.ledger.base_slot > 0 and state.ledger.retired_slots > 0
    n_inv = mirror.invalidated

    state.restore(snap)
    # Full ledger fidelity: origin, retire count AND the matrix.
    assert state.ledger.base_slot == 0
    assert state.ledger.retired_slots == 0
    assert state.ledger.reserved.tobytes() == reserved0.tobytes()
    # The device mirror must have been invalidated by the restore — its
    # uploaded columns were aligned to the post-retire origin.
    assert mirror.invalidated > n_inv


# ---------------------------------------------------------------------------
# tentpole: headless data-plane mode
# ---------------------------------------------------------------------------


def test_headless_inflight_transfers_complete():
    """A transfer whose rules are installed before the crash completes on
    the data plane: same assignment times as a never-crashed twin, rules
    stay up during the outage, and recovery reconciles the lapsed
    expiries."""
    fab, workers, tasks = storm_fixture(n_tasks=6)

    ref = build(fab, workers)
    ref.submit(tasks, at=0.0)
    ref.run()
    want = canon_sched(ref)

    ctrl = build(fab, workers)
    ctrl.submit(tasks, at=0.0)
    ctrl.run_until(0.0)   # placed: transfers booked, rules installed
    n_rules = ctrl.dataplane.tables.n_rules()
    assert n_rules > 0
    end = max(a.transfer.end for a in ctrl.schedule().assignments
              if a.transfer is not None and a.transfer.slot_fracs)
    ctrl.fail_controller(at=0.1)
    ctrl.recover_controller(at=end + 1.0)
    ctrl.run()
    # 100% of in-flight transfers completed: the schedule is untouched.
    assert canon_sched(ctrl) == want
    # Rules lapsed during the outage were reconciled at recovery, not GC'd
    # mid-outage.
    assert ctrl.ha_stats["reconciled_rules"] == n_rules
    assert ctrl.dataplane.tables.n_rules() == 0


def test_headless_mailbox_bounded_load_shed():
    fab, workers, tasks = storm_fixture(n_tasks=8)
    ctrl = build(fab, workers, mailbox_limit=2)
    ctrl.fail_controller(at=0.0)
    jids = [ctrl.submit([t], at=0.5 + 0.01 * i)
            for i, t in enumerate(tasks[:5])]
    ctrl.recover_controller(at=1.0)
    ctrl.run()
    # First two queued jobs drained at recovery; the overflow shed.
    assert [ctrl.jobs[j].placed for j in jids] == [
        True, True, False, False, False
    ]
    assert [ctrl.jobs[j].shed for j in jids] == [
        False, False, True, True, True
    ]
    assert ctrl.shed_jobs == jids[2:]
    assert ctrl.ha_stats["mailbox_queued"] == 2
    assert ctrl.ha_stats["mailbox_shed"] == 3
    # Drained jobs were placed at recovery time, not their arrival time.
    assert all(a.start >= 1.0 - 1e-9
               for j in jids[:2] for a in ctrl.jobs[j].assignments)


def test_headless_suspends_poll_and_hb_chains():
    fab, workers, tasks = storm_fixture(n_tasks=4)
    srcs = tasks[0].replicas
    tiny = lambda tid: Task(tid=tid, size=8.0, compute=0.1, replicas=srcs)
    ctrl = build(fab, workers)
    mon = ctrl.attach_telemetry(estimator="ewma")
    hb = ctrl.attach_heartbeats(interval=0.2, grace_s=1.0)
    j0 = ctrl.submit([tiny(0)], at=0.0)
    ctrl.fail_controller(at=0.4)
    ctrl.recover_controller(at=3.0)
    j1 = ctrl.submit([tiny(1)], at=2.0)  # arrives mid-outage -> mailbox
    j2 = ctrl.submit([tiny(2)], at=3.5)  # post-recovery work for the chains
    ctrl.run_until(0.3)
    for h in workers:
        hb.beat(h, now=0.35)
    ctrl.run_until(1.0)
    frozen = mon.stats["polls"]
    assert frozen > 0
    ctrl.run_until(2.9)
    # The poll/hb chains are suspended, not merely starved: the j1 arrival
    # at t=2.0 kept the heap busy mid-outage, yet nothing polled.
    assert mon.stats["polls"] == frozen, "polled while down"
    assert ctrl.ha_stats["mailbox_queued"] == 1
    ctrl.run()
    # Chains re-armed on recovery; the outage did not kill polling.
    assert mon.stats["polls"] > frozen
    assert ctrl._hb_last >= 3.0, "no post-recovery heartbeat sweep ran"
    # grace 1.0 < outage 2.6, a sweep DID run after recovery, and yet no
    # host was declared dead: missed-beat accrual was suspended across the
    # window (without suspend_accrual every worker would look 2.65 s
    # stale at the t=3.0 sweep).
    assert ctrl.fault_stats["host_down"] == 0
    assert sorted(hb.alive()) == sorted(workers)
    assert all(ctrl.jobs[j].placed for j in (j0, j1, j2))
    # The mailboxed job was scheduled at drain time, not its arrival time.
    assert all(a.start >= 3.0 - 1e-9 for a in ctrl.jobs[j1].assignments)


def test_controller_events_via_inject_net_and_fault_plan():
    fab, workers, tasks = storm_fixture(n_tasks=4)
    ctrl = build(fab, workers)
    ctrl.submit(tasks, at=0.0)
    ctrl.inject_net(ControllerDown(at=0.2))
    ctrl.inject_net(ControllerUp(at=0.8))
    ctrl.run()
    assert ctrl.ha_stats["ctrl_down"] == 1
    assert ctrl.ha_stats["ctrl_up"] == 1

    # Seed 1 draws crashes at t≈0.63 and t≈1.35 — the mttr=0.3 windows
    # don't overlap, so both down/up pairs take effect.
    plan = FaultPlan.generate(1, workers, 0.5, 1.5,
                              n_ctrl_crashes=2, ctrl_mttr=0.3)
    assert sum(isinstance(e, ControllerCrash) for e in plan.events) == 2
    ctrl2 = build(fab, workers)
    ctrl2.submit(tasks, at=0.0)
    plan.apply(ctrl2)
    ctrl2.run()
    assert ctrl2.ha_stats["ctrl_down"] == 2
    assert ctrl2.ha_stats["ctrl_up"] == 2


def test_fault_plan_generation_unchanged_without_ctrl_crashes():
    """Adding the controller-crash draw *after* the existing streams keeps
    pre-existing seeded plans byte-identical."""
    kw = dict(n_crashes=2, mttr=2.0, n_stragglers=3, slow_factor=(4.0, 8.0))
    fab, workers, _tasks = storm_fixture()
    old = FaultPlan.generate(SEED, workers, 0.5, 3.0, **kw)
    new = FaultPlan.generate(SEED, workers, 0.5, 3.0, n_ctrl_crashes=0, **kw)
    assert old == new


# ---------------------------------------------------------------------------
# satellite: heartbeat accrual suspension (injectable clock)
# ---------------------------------------------------------------------------


def test_heartbeat_suspend_accrual_injectable_clock():
    t = [0.0]
    mon = HeartbeatMonitor(["a", "b", "c"], grace_s=1.0, clock=lambda: t[0])
    t[0] = 2.0
    mon.beat("a")
    mon.beat("b")
    assert mon.sweep() == ["c"] and not mon.hosts["c"].alive

    # Outage [2.4, 12.4]: the hosts were already 0.4 s stale going in.
    # Without forgiveness every live host would be 10.4 s stale at the
    # first post-recovery sweep and get mass-declared dead.
    t[0] = 12.4
    mon.suspend_accrual(10.0)
    assert mon.sweep() == []
    assert sorted(mon.alive()) == ["a", "b"]
    # Dead hosts stay dead — the outage is not evidence of recovery.
    assert not mon.hosts["c"].alive
    # last_beat never moves into the future.
    assert all(st.last_beat <= t[0] for st in mon.hosts.values())
    # ...and staleness accrued *before* the outage still counts: the hosts
    # are 0.4 s stale again, so 0.7 s more pushes them over the 1.0 grace.
    t[0] = 13.1
    assert sorted(mon.sweep()) == ["a", "b"]
    # No-op guards.
    mon.suspend_accrual(0.0)
    mon.suspend_accrual(-5.0)
    # The cap: forgiving more than the wall allows pins last_beat at now,
    # never beyond it.
    mon.revive("a")
    mon.suspend_accrual(50.0)
    assert mon.hosts["a"].last_beat == t[0]


# ---------------------------------------------------------------------------
# satellite: telemetry counter-reset hardening
# ---------------------------------------------------------------------------


def test_window_estimator_clamps_counter_reset():
    cap = np.array([100.0, 100.0])
    est = WindowRateEstimator(2, cap, window=4.0)
    est.update(0.0, np.array([0.5, 0.5]), np.array([0.0, 0.0]))
    est.update(1.0, np.array([0.5, 0.5]), np.array([80.0, 40.0]))
    assert est.utilization() == pytest.approx([0.8, 0.4])

    # Counters went backwards (controller restart zeroed them): the rate
    # must clamp to a fresh sample, never a negative utilization.
    est.update(2.0, np.array([0.3, 0.2]), np.array([5.0, 2.0]))
    assert est.resets == 1
    u = est.utilization()
    assert np.all(u >= 0.0)
    assert u == pytest.approx([0.3, 0.2])  # fresh-sample fallback

    # Two post-reset samples: rates are differenced within the new epoch.
    est.update(3.0, np.array([0.3, 0.2]), np.array([25.0, 12.0]))
    assert est.utilization() == pytest.approx([0.2, 0.1])
    assert est.resets == 1


def test_monitor_snapshot_reports_resets():
    fab, workers, tasks = storm_fixture(n_tasks=4)
    ctrl = build(fab, workers)
    mon = ctrl.attach_telemetry(estimator="window")
    ctrl.submit(tasks, at=0.0)
    ctrl.run()
    assert mon.snapshot()["resets"] == 0


# ---------------------------------------------------------------------------
# satellite: router degraded/shed decisions are observable
# ---------------------------------------------------------------------------


def test_router_counts_degraded_decisions():
    from repro.serving.engine import Request
    from repro.serving.router import BassRouter

    router = BassRouter(["r0", "r1"], max_retries=1, retry_backoff_s=0.01)
    prompt = np.arange(64, dtype=np.int32)
    d0 = router.route(Request(rid=0, prompt=prompt, max_new=8,
                              prefix_hash=1), now=0.0)
    assert not d0.degraded

    for i in range(2):
        router.fail_link(f"nic{i}")
    d1 = router.route(Request(rid=1, prompt=prompt, max_new=8,
                              prefix_hash=2), now=router.controller.now)
    assert d1.degraded

    counters = router.controller.obs.snapshot(trace_tail=0)["counters"]
    assert counters["router.routed"] == 1
    assert counters["router.degraded"] == 1
    assert counters["router.retries"] == 1
