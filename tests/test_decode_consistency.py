"""Prefill + single-token decode must agree with the teacher-forced full
forward for every architecture family (exactness up to bf16 noise)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import encdec as ed
from repro.models.model import Model
from repro.models.transformer import apply_stack_full


def full_logits(model, cfg, params, batch):
    if cfg.family == "encdec":
        enc = ed.encode(params, batch["frames"], cfg)
        lg, _ = ed.decode_full(params, batch["tokens"], enc, cfg)
        return lg
    x = model._assemble_input(params, batch)
    rope = model._rope(jnp.arange(x.shape[1]))
    x, _, _ = apply_stack_full(cfg, params["stack"], x, rope)
    return model._head(params, x)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True).with_(remat=False)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S, SMAX = 2, 12, 20
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)

    lg_full = jax.jit(lambda p, b: full_logits(model, cfg, p, b))(params, batch)

    pb = dict(batch)
    pb["tokens"] = tok[:, : S - 1]
    last, caches = jax.jit(lambda p, b: model.prefill(p, b, SMAX))(params, pb)
    n_prefix = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    pos = jnp.int32(n_prefix + S - 1)
    lg_dec, _ = jax.jit(model.decode)(params, tok[:, S - 1 : S], pos, caches)

    scale = float(jnp.max(jnp.abs(lg_full))) + 1e-6
    tol = 0.05 * scale + 0.05
    e_prefill = float(jnp.max(jnp.abs(last - lg_full[:, n_prefix + S - 2])))
    e_decode = float(jnp.max(jnp.abs(lg_dec - lg_full[:, n_prefix + S - 1])))
    assert e_prefill < tol, (arch, e_prefill, scale)
    assert e_decode < tol, (arch, e_decode, scale)


def test_multi_step_greedy_decode_matches_rescoring():
    """Greedy-decode 6 tokens, then teacher-force the full sequence — the
    decode path's argmax choices must be self-consistent under rescoring."""
    cfg = get_config("starcoder2-3b", smoke=True).with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S, SMAX, NEW = 1, 8, 24, 6
    tok = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, SMAX))(
        params, {"tokens": tok}
    )
    seq = [int(jnp.argmax(logits[0]))]
    decode = jax.jit(model.decode)
    for i in range(NEW - 1):
        lg, caches = decode(
            params, jnp.array([[seq[-1]]], jnp.int32), jnp.int32(S + i), caches
        )
        seq.append(int(jnp.argmax(lg[0])))

    full = jnp.concatenate([tok, jnp.array([seq[:-1]], jnp.int32)], axis=1)
    lg_full = jax.jit(lambda p, b: full_logits(model, cfg, p, b))(
        params, {"tokens": full}
    )
    for i, t in enumerate(seq):
        assert int(jnp.argmax(lg_full[0, S - 1 + i])) == t
