"""Serving: engine continuous batching + BASS request routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import TINY
from repro.models.model import Model
from repro.serving import BassRouter, Request, ServeEngine


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = TINY.with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_serves_batch(tiny_engine):
    model, params = tiny_engine
    eng = ServeEngine(model, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(2, 500, size=8).astype(np.int32), max_new=4)
        for i in range(2)
    ]
    for r in reqs:
        assert eng.admit(r)
    done = []
    for _ in range(10):
        done += eng.tick()
        if len(done) == 2:
            break
    assert len(done) == 2
    for r in done:
        assert len(r.tokens_out) == 4
    assert eng.has_capacity()


def test_engine_respects_capacity(tiny_engine):
    model, params = tiny_engine
    eng = ServeEngine(model, params, slots=1, s_max=64)
    rng = np.random.default_rng(1)
    r1 = Request(rid=0, prompt=rng.integers(2, 500, size=8).astype(np.int32), max_new=3)
    r2 = Request(rid=1, prompt=rng.integers(2, 500, size=8).astype(np.int32), max_new=3)
    assert eng.admit(r1)
    assert not eng.admit(r2)          # no free slot
    while not r1.done:
        eng.tick()
    assert eng.admit(r2)              # slot freed


def test_router_prefix_stickiness():
    """When context migration is expensive relative to the backlog gap, a
    warm prefix stays home (Case 1.3).  With a near-free migration the
    router correctly moves to the idle replica instead (Case 1.2) — that
    regime is covered by test_router_migrates_under_backlog."""
    router = BassRouter(
        ["r0", "r1"], decode_s_per_token=0.001, bytes_per_ctx_token=2e6
    )
    p = np.arange(4096, dtype=np.int32)   # 8.2 GB of context to move
    d1 = router.route(Request(rid=0, prompt=p, max_new=8, prefix_hash=7))
    d2 = router.route(Request(rid=1, prompt=p, max_new=8, prefix_hash=7))
    assert d2.replica == d1.replica
    assert d2.migrated_from is None


def test_router_migrates_under_backlog():
    router = BassRouter(["r0", "r1"], decode_s_per_token=0.5)
    p = np.arange(512, dtype=np.int32)
    home = router.route(Request(rid=0, prompt=p, max_new=4, prefix_hash=3)).replica
    # pile synthetic backlog onto the home replica
    router.update_backlog({home: 1000.0})
    other = [r for r in router.replicas if r != home][0]
    router.update_backlog({other: 0.0})
    d = router.route(Request(rid=1, prompt=p, max_new=4, prefix_hash=3))
    assert d.replica == other          # Case 1.2: remote with reservation
    assert d.migrated_from is not None


def test_router_cold_request_goes_to_minnow():
    router = BassRouter(["r0", "r1", "r2"])
    router.update_backlog({"r0": 50.0, "r1": 0.5, "r2": 90.0})
    d = router.route(Request(rid=0, prompt=np.arange(8, dtype=np.int32), max_new=2,
                             prefix_hash=999))
    assert d.replica == "r1"


# -- per-tenant QoS at the router (core.qos × serving.router) ----------------


def _req(rid, prefix_hash=0, tokens=8, max_new=100):
    return Request(
        rid=rid,
        prompt=np.zeros(tokens, dtype=np.int32),
        max_new=max_new,
        prefix_hash=prefix_hash,
    )


def _tenant_router(**kw):
    from repro.core.qos import TenantSpec

    return BassRouter(
        ["r0", "r1"],
        decode_s_per_token=0.001,
        bytes_per_ctx_token=2e6,
        tenants=[
            TenantSpec("free", weight=1.0, rate=2.0, burst=2.0),
            TenantSpec("pro", weight=4.0),
        ],
        fairness_slack_s=0.05,
        **kw,
    )


def test_tenant_admission_rejects_over_rate():
    r = _tenant_router()
    d0 = r.route(_req(0), now=0.0, tenant="free")
    d1 = r.route(_req(1), now=0.0, tenant="free")
    assert not d0.rejected and not d1.rejected
    # burst exhausted: the third request at t=0 is turned away with
    # nothing committed — no replica, no reservation, no backlog charge
    backlog = dict(r.backlog)
    d2 = r.route(_req(2), now=0.0, tenant="free")
    assert d2.rejected and d2.degraded
    assert d2.replica == "" and d2.ready_at == float("inf")
    assert r.backlog == backlog
    # tokens refill at 2/s, so the same tenant is admitted again later
    d3 = r.route(_req(3), now=1.0, tenant="free")
    assert not d3.rejected
    snap = r.controller.obs.snapshot()["counters"]
    assert snap["router.rejected"] == 1
    assert snap["tenant.free.rejected"] == 1
    assert snap["tenant.free.admitted"] == 3


def test_tenant_tagging_requires_tenant_config():
    r = BassRouter(["r0", "r1"], decode_s_per_token=0.001,
                   bytes_per_ctx_token=2e6)
    with pytest.raises(ValueError):
        r.route(_req(0), tenant="free")
    with pytest.raises(KeyError):
        _tenant_router().route(_req(0), tenant="unknown")


def test_over_share_tenant_loses_migration_fast_path():
    r = _tenant_router()
    # "free" (weight 1) burns far past the fairness frontier while "pro"
    # sits at vt=0 -> lag(free) > slack: its next requests are pinned
    # data-local with no new reservation (slots=()).
    r.tenants.charge("free", 1.0)
    assert r.tenants.lag("free") > r.fairness_slack_s
    d = r.route(_req(0, prefix_hash=7), now=0.0, tenant="free")
    assert d.slots == () and d.migrated_from is None
    # the pinned request still lands somewhere real and is accounted
    assert d.replica in r.replicas
    snap = r.controller.obs.snapshot()["counters"]
    assert snap["router.pinned"] == 1
    assert snap["tenant.free.pinned"] == 1
    # ... and the under-served tenant keeps the full BASS path
    d2 = r.route(_req(1, prefix_hash=7), now=0.0, tenant="pro")
    assert not d2.rejected
    snap = r.controller.obs.snapshot()["counters"]
    assert snap["router.pinned"] == 1  # unchanged by pro's request


def test_pinned_tenant_recovers_when_frontier_catches_up():
    r = _tenant_router()
    r.tenants.charge("free", 1.0)
    assert r.route(_req(0), now=0.0, tenant="free").slots == ()
    # serving "pro" advances the frontier past free's virtual clock
    r.tenants.charge("pro", 50.0)
    assert r.tenants.lag("free") <= r.fairness_slack_s
    d = r.route(_req(1), now=1.0, tenant="free")
    assert not d.rejected  # back on the normal BASS path
    assert r.controller.obs.snapshot()["counters"]["router.pinned"] == 1


def test_tenants_survive_replica_churn():
    """Admission control composes with the SDN liveness path: a dead
    replica NIC steers tenant traffic to the survivor, full partition
    degrades without charging, recovery restores normal routing."""
    r = _tenant_router()
    r.fail_link("nic0")  # r0's NIC (star fabric wires nic<i> to replica i)
    for i in range(2):
        d = r.route(_req(i), now=float(i), tenant="pro")
        assert not d.rejected and d.replica == "r1"
    r.fail_link("nic1")  # nothing left: degraded, not rejected
    d = r.route(_req(2), now=2.0, tenant="pro")
    assert d.degraded and not d.rejected
    r.recover_link("nic0")
    r.recover_link("nic1")
    d = r.route(_req(3), now=3.0, tenant="pro")
    assert not d.degraded and d.replica in ("r0", "r1")
    counters = r.controller.obs.snapshot()["counters"]
    assert counters["router.degraded"] == 1
    assert counters["tenant.pro.admitted"] == 4


def test_router_over_hierarchical_controller_matches_flat():
    """Injecting a ``core.hierarchy`` exact-mode controller behind the
    router reproduces the flat-backed router's decisions byte for byte —
    the serving layer rides the same parity contract the schedule dumps
    pin."""
    from repro.core.hierarchy import HierarchicalController
    from repro.core.topology import storage_hosts, tpu_dcn_fabric

    def build(hier):
        fab = tpu_dcn_fabric(n_pods=2, hosts_per_pod=2)
        reps = storage_hosts(fab)
        if hier:
            ctl = HierarchicalController(
                fab, reps, slot_duration=0.05, horizon_slots=2048
            )
            return BassRouter(reps, controller=ctl,
                              decode_s_per_token=0.001,
                              bytes_per_ctx_token=2e6)
        return BassRouter(reps, fabric=fab, decode_s_per_token=0.001,
                          bytes_per_ctx_token=2e6)

    flat, hier = build(False), build(True)
    rng = np.random.default_rng(5)
    for i in range(40):
        req = _req(i, prefix_hash=int(rng.integers(0, 4)),
                   tokens=int(rng.integers(4, 64)),
                   max_new=int(rng.integers(10, 400)))
        now = i * 0.01
        bl = {rep: float(rng.uniform(0.0, 0.2))
              for rep in flat.replicas}
        flat.update_backlog(dict(bl))
        hier.update_backlog(dict(bl))
        df = flat.route(req, now=now)
        dh = hier.route(req, now=now)
        assert (df.replica, df.migrated_from, df.ready_at, df.slots) \
            == (dh.replica, dh.migrated_from, dh.ready_at, dh.slots)


def test_router_rejects_controller_missing_replicas():
    from repro.core.hierarchy import HierarchicalController
    from repro.core.topology import storage_hosts, tpu_dcn_fabric

    fab = tpu_dcn_fabric(n_pods=2, hosts_per_pod=2)
    ctl = HierarchicalController(fab, storage_hosts(fab))
    with pytest.raises(ValueError):
        BassRouter(["r0", "r1"], controller=ctl)
