"""Serving: engine continuous batching + BASS request routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import TINY
from repro.models.model import Model
from repro.serving import BassRouter, Request, ServeEngine


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = TINY.with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_serves_batch(tiny_engine):
    model, params = tiny_engine
    eng = ServeEngine(model, params, slots=2, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(2, 500, size=8).astype(np.int32), max_new=4)
        for i in range(2)
    ]
    for r in reqs:
        assert eng.admit(r)
    done = []
    for _ in range(10):
        done += eng.tick()
        if len(done) == 2:
            break
    assert len(done) == 2
    for r in done:
        assert len(r.tokens_out) == 4
    assert eng.has_capacity()


def test_engine_respects_capacity(tiny_engine):
    model, params = tiny_engine
    eng = ServeEngine(model, params, slots=1, s_max=64)
    rng = np.random.default_rng(1)
    r1 = Request(rid=0, prompt=rng.integers(2, 500, size=8).astype(np.int32), max_new=3)
    r2 = Request(rid=1, prompt=rng.integers(2, 500, size=8).astype(np.int32), max_new=3)
    assert eng.admit(r1)
    assert not eng.admit(r2)          # no free slot
    while not r1.done:
        eng.tick()
    assert eng.admit(r2)              # slot freed


def test_router_prefix_stickiness():
    """When context migration is expensive relative to the backlog gap, a
    warm prefix stays home (Case 1.3).  With a near-free migration the
    router correctly moves to the idle replica instead (Case 1.2) — that
    regime is covered by test_router_migrates_under_backlog."""
    router = BassRouter(
        ["r0", "r1"], decode_s_per_token=0.001, bytes_per_ctx_token=2e6
    )
    p = np.arange(4096, dtype=np.int32)   # 8.2 GB of context to move
    d1 = router.route(Request(rid=0, prompt=p, max_new=8, prefix_hash=7))
    d2 = router.route(Request(rid=1, prompt=p, max_new=8, prefix_hash=7))
    assert d2.replica == d1.replica
    assert d2.migrated_from is None


def test_router_migrates_under_backlog():
    router = BassRouter(["r0", "r1"], decode_s_per_token=0.5)
    p = np.arange(512, dtype=np.int32)
    home = router.route(Request(rid=0, prompt=p, max_new=4, prefix_hash=3)).replica
    # pile synthetic backlog onto the home replica
    router.update_backlog({home: 1000.0})
    other = [r for r in router.replicas if r != home][0]
    router.update_backlog({other: 0.0})
    d = router.route(Request(rid=1, prompt=p, max_new=4, prefix_hash=3))
    assert d.replica == other          # Case 1.2: remote with reservation
    assert d.migrated_from is not None


def test_router_cold_request_goes_to_minnow():
    router = BassRouter(["r0", "r1", "r2"])
    router.update_backlog({"r0": 50.0, "r1": 0.5, "r2": 90.0})
    d = router.route(Request(rid=0, prompt=np.arange(8, dtype=np.int32), max_new=2,
                             prefix_hash=999))
    assert d.replica == "r1"
