"""Host-failure fault injection, retries, LATE speculation (DESIGN.md §10).

Covers the robustness contract end to end:

* exception parity — a task whose replicas are all dead raises
  :class:`UnroutableError` after bounded retries (no silent stalls);
* recovery — a recovered host is re-admitted and serves new jobs, and a
  retry that lands inside the recovery window succeeds;
* exact slot release — killing a host mid-transfer releases precisely
  the unconsumed tail of every victim plan (property test against a
  never-failed twin controller, mirroring ``test_reroute_props``);
* blacklist — a host that crashes ``blacklist_after`` times stays out;
* FaultPlan — same seed ⇒ identical scripts and byte-identical runs;
* heartbeats — missed beats become ``fail_host`` in sim time;
* router — transient all-dead windows retry then recover; permanent
  ones degrade instead of raising.
"""
import numpy as np
import pytest

from repro.core.controller import (
    BassPolicy,
    ClusterController,
    MinnowHeap,
    RetryPolicy,
)
from repro.core.faults import FaultPlan, HostCrash, StragglerOnset
from repro.core.tasks import Task
from repro.core.topology import UnroutableError, storage_hosts, two_tier_fabric
from repro.net.events import HostDown, HostUp
from repro.net.fattree import fat_tree_fabric

from test_wavefront import canon


def _controller(fab, workers, idle=None, retry=None, speculation=False,
                slot=0.5):
    return ClusterController(
        fab, workers, BassPolicy(), idle=idle, slot_duration=slot,
        retry=retry or RetryPolicy(max_attempts=3, backoff_s=0.25),
        speculation=speculation,
    )


# ---------------------------------------------------------------------------
# MinnowHeap membership churn
# ---------------------------------------------------------------------------


def test_minnow_heap_insert_remove():
    idle = {"a": 3.0, "b": 1.0, "c": 2.0}
    h = MinnowHeap(idle, list(idle))
    assert h.minnow() == "b"
    h.remove("b")
    assert h.minnow() == "c"
    h.insert("b", 0.5)
    assert h.minnow() == "b"
    with pytest.raises(ValueError):
        h.insert("b", 9.0)
    # removing from the middle must keep every survivor addressable
    h.remove("c")
    h.update("a", 0.1)
    assert h.minnow() == "a"
    with pytest.raises(KeyError):
        h.remove("c")


def test_cluster_state_worker_membership():
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H0", "H1", "H2"])
    s = ctrl.state
    s.remove_worker("H1")
    assert "H1" not in s.workers_set and "H1" not in s.idle
    assert set(s.workers) == {"H0", "H2"}
    s.remove_worker("H1")  # idempotent
    s.add_worker("H1", 5.0)
    assert s.idle["H1"] == 5.0 and "H1" in s.workers_set


# ---------------------------------------------------------------------------
# Exception parity + recovery
# ---------------------------------------------------------------------------


def _one_remote_task(**kw):
    """H0-replica shard computed on H2/H3 (upper tier crossing)."""
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H2", "H3"], **kw)
    ctrl.submit([Task(tid=1, size=200.0, compute=4.0, replicas=("H0",))],
                at=0.0)
    ctrl.run_until(0.0)
    (a,) = ctrl.jobs[0].assignments
    return ctrl, a


def test_all_replicas_dead_raises_unroutable():
    ctrl, a = _one_remote_task()
    # Kill the source after its transfer delivered (no reroute path), then
    # the worker mid-compute: every retry finds no live replica.
    ctrl.fail_host("H0", at=a.transfer.end + 0.1)
    ctrl.fail_host(a.node, at=a.transfer.end + 0.2)
    with pytest.raises(UnroutableError, match="no live replica"):
        ctrl.run()
    assert ctrl.fault_stats["killed"] == 1
    assert ctrl.fault_stats["reexecuted"] == 0


def test_retry_succeeds_inside_recovery_window():
    ctrl, a = _one_remote_task()
    t0 = a.transfer.end + 0.1
    ctrl.fail_host("H0", at=t0)
    ctrl.fail_host(a.node, at=t0 + 0.1)
    # The source comes back before the bounded retries exhaust: the
    # transient all-replicas-dead window burns attempts, then places.
    ctrl.recover_host("H0", at=t0 + 0.5)
    ctrl.run()
    rec = ctrl.jobs[0]
    assert rec.reexecuted == 1
    (b,) = rec.assignments
    assert b.node != a.node and b.start >= t0 + 0.5
    assert ctrl.fault_stats["retries"] >= 2  # at least one burned attempt


def test_recovery_readmits_for_new_jobs():
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H2", "H3"])
    ctrl.fail_host("H2", at=0.0)
    ctrl.recover_host("H2", at=2.0)
    ctrl.submit([Task(tid=i, size=50.0, compute=1.0, replicas=("H0",))
                 for i in range(4)], at=3.0)
    ctrl.run()
    nodes = {a.node for a in ctrl.jobs[0].assignments}
    assert nodes == {"H2", "H3"}  # the recovered worker serves again
    for a in ctrl.jobs[0].assignments:
        if a.node == "H2":
            assert a.start >= 2.0


def test_host_events_via_inject_net():
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H2", "H3"])
    ctrl.inject_net(HostDown("H2", at=1.0))
    ctrl.inject_net(HostUp("H2", at=2.0))
    ctrl.run()
    assert ctrl.fault_stats["host_down"] == 1
    assert ctrl.fault_stats["host_up"] == 1
    assert "H2" in ctrl.state.workers_set


def test_blacklisted_host_stays_out():
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H2", "H3"],
                       retry=RetryPolicy(max_attempts=0, blacklist_after=2))
    for k in range(2):
        ctrl.fail_host("H2", at=float(k))
        ctrl.recover_host("H2", at=float(k) + 0.5)
    ctrl.run()
    assert "H2" in ctrl.blacklist
    assert "H2" in ctrl.dataplane.dead_hosts  # second recovery refused
    assert "H2" not in ctrl.state.workers_set
    assert ctrl.fault_stats["blacklisted"] == 1


def test_straggle_factor_validated():
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H2", "H3"])
    with pytest.raises(ValueError):
        ctrl.straggle("H2", 0.5)
    with pytest.raises(ValueError):
        ctrl.fail_host("NOPE")


# ---------------------------------------------------------------------------
# Exact slot release on kill-mid-transfer (never-failed twin property)
# ---------------------------------------------------------------------------


def _twin_case(seed):
    rng = np.random.default_rng(seed)
    fab = two_tier_fabric(2, 4, 100.0, 60.0)
    hosts = [f"H{i}" for i in range(8)]
    sources, workers = hosts[:4], hosts[4:]
    tasks = [
        Task(tid=i, size=float(rng.uniform(80, 500)),
             compute=float(rng.uniform(1, 5)),
             replicas=tuple(rng.choice(sources, 2, replace=False)))
        for i in range(int(rng.integers(4, 10)))
    ]
    idle = {w: float(rng.uniform(0, 2)) for w in workers}
    return fab, workers, idle, tasks, rng


def _released_tail(ledger, plan, t):
    """(rows, slot, frac) triples release_after frees at cut time ``t`` —
    the boundary slot is forfeited whole (DESIGN.md §4)."""
    if not plan.slot_fracs or t >= plan.end:
        return []
    cut = (plan.slot_fracs[0][0] if t <= plan.start
           else ledger.slot_of(t))
    return [(plan.links, s, f) for s, f in plan.slot_fracs if s >= cut]


@pytest.mark.parametrize("seed", range(8))
def test_host_kill_releases_exactly_unconsumed_slots(seed):
    """Kill one worker mid-storm with re-execution disabled: the failed
    controller's ledger must equal the never-failed twin's minus exactly
    the victims' unconsumed tails, and the wasted-byte counter must equal
    the delivered bytes of the truncated plans."""
    fab, workers, idle, tasks, rng = _twin_case(seed)

    twin = _controller(fab, workers, idle=dict(idle),
                       retry=RetryPolicy(max_attempts=0))
    twin.state.ledger.retire_stride = None
    twin.submit(tasks, at=0.0)
    twin.run_until(0.0)

    victim_node = workers[int(rng.integers(len(workers)))]
    t_kill = float(rng.uniform(0.3, 4.0))

    ctrl = _controller(fab, workers, idle=dict(idle),
                       retry=RetryPolicy(max_attempts=0))
    ctrl.state.ledger.retire_stride = None
    ctrl.submit(tasks, at=0.0)
    ctrl.run_until(0.0)
    ctrl.fail_host(victim_node, at=t_kill)
    ctrl.run_until(t_kill + 0.01)

    led = twin.state.ledger
    expected = led.reserved.copy()
    wasted = 0.0
    for a in twin.jobs[0].assignments:
        if a.node != victim_node or a.finish <= t_kill + 1e-9:
            continue
        if a.transfer is None or not a.transfer.slot_fracs:
            continue
        for links, s, f in _released_tail(led, a.transfer, t_kill):
            expected[list(links), s] = np.maximum(
                expected[list(links), s] - f, 0.0
            )
        wasted += led.plan_bytes(_truncated(led, a.transfer, t_kill))
    got = ctrl.state.ledger.reserved
    n = min(expected.shape[1], got.shape[1])
    assert np.allclose(got[:, :n], expected[:, :n], atol=1e-12)
    assert not got[:, n:].any() and not expected[:, n:].any()
    assert ctrl.jobs[0].wasted_bytes == pytest.approx(wasted)
    # re-execution disabled: kills only, nothing re-placed
    assert ctrl.fault_stats["reexecuted"] == 0
    surviving = {a.tid for a in ctrl.jobs[0].assignments}
    assert all(a.node != victim_node or a.finish <= t_kill + 1e-9
               for a in ctrl.jobs[0].assignments)
    assert surviving <= {t.tid for t in tasks}


def _truncated(ledger, plan, t):
    """The kept (delivered) prefix of ``plan`` cut at ``t`` — pure
    arithmetic twin of ``release_after`` with no ledger scatter."""
    from repro.core.timeslot import TransferPlan

    if not plan.slot_fracs or t >= plan.end:
        return plan
    cut = (plan.slot_fracs[0][0] if t <= plan.start
           else ledger.slot_of(t))
    keep = tuple((s, f) for s, f in plan.slot_fracs if s < cut)
    if not keep:
        return TransferPlan(plan.links, plan.start, plan.start, ())
    return TransferPlan(plan.links, plan.start,
                        min(plan.end, cut * ledger.slot_duration), keep)


# ---------------------------------------------------------------------------
# FaultPlan determinism
# ---------------------------------------------------------------------------


def test_fault_plan_same_seed_same_script():
    hosts = [f"H{i}" for i in range(12)]
    kw = dict(n_crashes=3, mttr=2.0, n_stragglers=4,
              slow_factor=(2.0, 5.0))
    p1 = FaultPlan.generate(42, hosts, 1.0, 9.0, **kw)
    p2 = FaultPlan.generate(42, hosts, 1.0, 9.0, **kw)
    assert p1 == p2
    assert p1 != FaultPlan.generate(43, hosts, 1.0, 9.0, **kw)
    ats = [e.at for e in p1.events]
    assert ats == sorted(ats)
    assert all(1.0 <= e.at < 9.0 for e in p1.events)
    assert sum(isinstance(e, HostCrash) for e in p1.events) == 3
    assert sum(isinstance(e, StragglerOnset) for e in p1.events) == 4
    for e in p1.events:
        if isinstance(e, HostCrash):
            assert e.recover_at == pytest.approx(e.at + 2.0)


def test_fault_plan_apply_is_byte_deterministic():
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    sources, workers = hosts[:8], hosts[8:]
    rng = np.random.default_rng(3)
    tasks = [
        Task(tid=i, size=float(32 + 16 * (i % 3)), compute=2.0,
             replicas=tuple(rng.choice(sources, 3, replace=False)))
        for i in range(12)
    ]

    def run():
        ctrl = ClusterController(
            fab, workers, BassPolicy(multipath=True), slot_duration=0.1,
            retry=RetryPolicy(max_attempts=4, backoff_s=0.5),
            speculation=True,
        )
        ctrl.submit(tasks, at=0.0)
        ctrl.run_until(0.0)
        FaultPlan.generate(5, workers, 0.5, 3.0, n_crashes=2, mttr=2.0,
                           n_stragglers=3, slow_factor=(4.0, 8.0)).apply(ctrl)
        ctrl.run()
        return ctrl

    c1, c2 = run(), run()
    assert canon(c1.schedule().assignments) == canon(c2.schedule().assignments)
    assert dict(c1.fault_stats) == dict(c2.fault_stats)
    assert c1.fault_stats["killed"] > 0


# ---------------------------------------------------------------------------
# LATE speculation
# ---------------------------------------------------------------------------


def test_speculation_beats_straggler_and_releases_loser():
    fab = two_tier_fabric(2, 3, 100.0, 100.0)
    workers = ["H3", "H4", "H5"]

    def run(speculation):
        ctrl = _controller(fab, workers, speculation=speculation, slot=0.1)
        ctrl.submit([Task(tid=1, size=50.0, compute=3.0, replicas=("H0",))],
                    at=0.0)
        ctrl.run_until(0.0)
        (a,) = ctrl.jobs[0].assignments
        ctrl.straggle(a.node, 8.0, at=a.start + 0.2)
        ctrl.run()
        return ctrl

    off, on = run(False), run(True)
    assert on.fault_stats["spec_launch"] == 1
    assert on.fault_stats["spec_win"] == 1
    assert on.jobs[0].makespan < off.jobs[0].makespan
    # first finisher won; the loser was torn down — one copy survives
    assert len(on.jobs[0].assignments) == 1
    assert on.jobs[0].wasted_bytes >= 0.0
    assert not on._specs


def test_speculation_gate_skips_hopeless_backup():
    """A mild straggle on an otherwise-loaded cluster must not launch a
    backup the ledger says cannot finish earlier."""
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H2", "H3"], speculation=True, slot=0.1)
    # Load both workers so any backup queues behind real work.
    ctrl.submit([Task(tid=i, size=10.0, compute=5.0, replicas=("H0",))
                 for i in range(4)], at=0.0)
    ctrl.run_until(0.0)
    a = min(ctrl.jobs[0].assignments, key=lambda x: x.start)
    ctrl.straggle(a.node, 1.05, at=a.start + 0.1)
    ctrl.run()
    assert ctrl.fault_stats["spec_launch"] == 0


# ---------------------------------------------------------------------------
# Heartbeats drive fail_host in sim time
# ---------------------------------------------------------------------------


def test_heartbeat_misses_become_host_failures():
    fab = two_tier_fabric(2, 2, 100.0, 100.0)
    ctrl = _controller(fab, ["H2", "H3"], slot=0.5,
                       retry=RetryPolicy(max_attempts=3, backoff_s=0.25))
    mon = ctrl.attach_heartbeats(interval=0.5, grace_s=1.5)
    ctrl.submit([Task(tid=i, size=50.0, compute=2.0, replicas=("H0",))
                 for i in range(4)], at=0.0)
    # A straggler of a job keeps the event heap non-empty past the grace
    # window — the sweep chain lives only while real events are queued.
    ctrl.submit([Task(tid=9, size=50.0, compute=1.0, replicas=("H0",))],
                at=4.0)
    victim = "H3"
    mon.beat("H2", 1e9)  # healthy forever; the victim never beats
    ctrl.run()  # chain dies with the event heap — must terminate
    assert victim in ctrl.dataplane.dead_hosts
    assert ctrl.fault_stats["host_down"] == 1
    rec = ctrl.jobs[0]
    assert sorted(a.tid for a in rec.assignments) == [0, 1, 2, 3]
    assert all(a.node == "H2" for a in rec.assignments)
    assert rec.reexecuted > 0
    # the monitor ran on sim time, never the wall clock
    assert mon.clock() == ctrl.now


def test_heartbeat_monitor_custom_clock_unit():
    from repro.runtime.ft import HeartbeatMonitor

    t = [0.0]
    mon = HeartbeatMonitor(["a", "b"], grace_s=1.0, clock=lambda: t[0])
    t[0] = 0.9
    assert mon.sweep() == []
    mon.beat("a")
    t[0] = 1.5
    assert mon.sweep() == ["b"]
    mon.revive("b")
    mon.beat("a")
    t[0] = 2.0
    assert mon.sweep() == []  # both beat at 1.5


# ---------------------------------------------------------------------------
# Router: transient windows retry; permanent ones degrade
# ---------------------------------------------------------------------------


def _router():
    from repro.serving.router import BassRouter

    return BassRouter(["r0", "r1"], slot_duration=0.05,
                      max_retries=3, retry_backoff_s=0.05)


def _req(rid=0):
    from repro.serving.engine import Request

    return Request(rid=rid, prompt=np.arange(8, dtype=np.int32), max_new=4)


def test_router_degrades_instead_of_raising():
    r = _router()
    r.fail_link("nic0")
    r.fail_link("nic1")
    before = r.ledger.reserved.copy()
    d = r.route(_req(), now=0.0)
    assert d.degraded and d.ready_at == float("inf") and d.slots == ()
    assert d.replica in ("r0", "r1")  # parking hint only
    np.testing.assert_array_equal(r.ledger.reserved, before)  # no commit


def test_router_retry_rides_out_transient_window():
    r = _router()
    r.fail_link("nic0")
    r.fail_link("nic1")
    # Recovery is already queued inside the backoff window: the retry
    # loop advances sim time until it fires, then routes normally.
    r.controller.recover_link("nic1", at=0.08)
    d = r.route(_req(), now=0.0)
    assert not d.degraded
    assert d.replica == "r1"
    assert d.ready_at < float("inf")
