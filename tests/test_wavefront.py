"""Wavefront placement engine — byte-identity and kernel-contract tests.

The engine's whole contract is: whatever ``BassPolicy.place_batch``
produces through the wavefront must be *bit-identical* (every float, every
slot fraction) to the sequential ``place`` loop — across contended
ledgers, bandwidth caps, multipath fat-trees, and controller runs with
mid-stream link failures (the engine plans through live failure-aware
routing — dead links priced out of candidate enumeration — and the
batched reroute engine replans the victims; see also
``tests/test_reroute_props.py``).
"""
import numpy as np
import pytest

from repro.core.controller import BassPolicy, ClusterState
from repro.core.tasks import Task
from repro.core.timeslot import TimeSlotLedger
from repro.core.topology import two_tier_fabric
from repro.kernels import ts_plan


def canon(assignments):
    """Hashable bit-exact image of a schedule (floats via ``hex``)."""
    out = []
    for a in sorted(assignments, key=lambda a: a.tid):
        t = a.transfer
        out.append((
            a.tid, a.node, a.source,
            a.start.hex(), a.finish.hex(),
            None if a.bw_needed is None else float(a.bw_needed).hex(),
            None if t is None else (
                t.links, float(t.start).hex(), float(t.end).hex(),
                tuple((s, float(f).hex()) for s, f in t.slot_fracs),
            ),
        ))
    return tuple(out)


def test_wavefront_fleet_slice_identical():
    """A deterministic slice of the fleet benchmark config — the deep
    frontier-skip / scalar micro-scan regime."""
    from benchmarks.bench_sched_scale import fleet_instance

    inst = fleet_instance(2, 32, 600)
    pol = BassPolicy()
    s_seq = ClusterState.from_instance(inst)
    seq = [pol.place(t, s_seq) for t in inst.tasks]
    s_wf = ClusterState.from_instance(inst)
    wf = pol.place_batch(inst.tasks, s_wf)
    assert canon(wf) == canon(seq)


def test_wavefront_speculation_resume_path_identical():
    """A contended 3 000-task batch drives the full adaptive-speculation
    lifecycle — waves on → hit-rate gate turns them off → re-probe at
    ``_spec_resume`` — and must stay bit-identical throughout."""
    from benchmarks.bench_sched_scale import fleet_instance
    from repro.core.wavefront import WavefrontPlanner

    inst = fleet_instance(2, 32, 3000)
    pol = BassPolicy()
    s_seq = ClusterState.from_instance(inst)
    seq = [pol.place(t, s_seq) for t in inst.tasks]
    s_wf = ClusterState.from_instance(inst)
    wf = pol.place_batch(inst.tasks, s_wf)
    assert canon(wf) == canon(seq)
    planner = WavefrontPlanner.for_state(s_wf)
    # the off → resume → probe transition actually executed
    assert planner._spec_resume > 0, "hit-rate gate never disabled waves"
    assert planner.stats["waves"] >= 2, "re-probe after resume never ran"


class _SequentialBass(BassPolicy):
    """The historical per-task loop, as a policy (reference oracle)."""

    def place_batch(self, tasks, state):
        return [self.place(t, state) for t in tasks]


def _controller_run(policy):
    from repro.core.controller import ClusterController
    from repro.core.topology import storage_hosts
    from repro.net.fattree import fat_tree_fabric

    fab = fat_tree_fabric(4)  # path diversity: failures reroute, not strand
    hosts = storage_hosts(fab)
    rng = np.random.default_rng(7)
    idle = {h: float(rng.uniform(0, 30)) for h in hosts}
    ctl = ClusterController(fab, hosts, policy, idle=idle, slot_duration=1.0)
    for jid in range(3):
        tasks = [
            Task(tid=jid * 100 + i, size=float(rng.uniform(100, 900)),
                 compute=float(rng.uniform(1, 8)),
                 replicas=tuple(rng.choice(hosts, 3, replace=False)))
            for i in range(8)
        ]
        ctl.submit(tasks, at=float(jid) * 3.0)
    # mid-stream churn: kill a link that carries an in-flight transfer
    # (both controllers are identical up to t=4, so both pick the same one)
    ctl.run_until(3.9)
    victim = max(
        (a for rec in ctl.jobs.values() for a in rec.assignments
         if a.transfer is not None and a.transfer.slot_fracs),
        key=lambda a: (a.transfer.end, a.tid),
    )
    dead = ctl.state.ledger.link_names(victim.transfer.links)[1]
    ctl.fail_link(dead, at=4.0)
    ctl.recover_link(dead, at=9.0)
    ctl.run()
    return ctl


def test_wavefront_controller_with_midstream_failures_identical():
    """Jobs placed before/during/after a link failure: the wavefront
    controller (planning through live failure-aware routing, batched
    reroute engine included) stays bit-identical to the sequential
    policy, reroutes included."""
    c_wf = _controller_run("bass")
    c_seq = _controller_run(_SequentialBass())
    assert canon(c_wf.schedule().assignments) == canon(
        c_seq.schedule().assignments
    )
    assert len(c_wf.reroute_log) == len(c_seq.reroute_log) > 0
    for a, b in zip(c_wf.reroute_log, c_seq.reroute_log):
        assert (a.flow, a.old_path, a.new_path, a.delivered, a.remaining) == (
            b.flow, b.old_path, b.new_path, b.delivered, b.remaining
        )


# ---------------------------------------------------------------------------
# Vectorized commit/release/plan_bytes ≡ the historical per-slot loops
# ---------------------------------------------------------------------------


def _commit_loop(led, plan):
    """The pre-vectorization reference implementation."""
    idx = list(plan.links)
    for slot, frac in plan.slot_fracs:
        led._ensure(slot)
        new = led.reserved[idx, slot] + frac
        if (new > 1.0 + 1e-6).any():
            raise ValueError(
                f"over-reservation on slot {slot}: {new.max():.6f} > 1"
            )
        led.reserved[idx, slot] = np.minimum(new, 1.0)


def _release_loop(led, plan):
    idx = list(plan.links)
    for slot, frac in plan.slot_fracs:
        led.reserved[idx, slot] = np.maximum(led.reserved[idx, slot] - frac, 0.0)


def _plan_bytes_loop(led, plan, until=None):
    if not plan.slot_fracs:
        return 0.0
    cap = float(led.capacity[list(plan.links)].min())
    t1 = plan.end if until is None else min(float(until), plan.end)
    total = 0.0
    for slot, frac in plan.slot_fracs:
        lo = max(plan.start, slot * led.slot_duration)
        hi = min(t1, (slot + 1) * led.slot_duration)
        if hi > lo:
            total += frac * cap * (hi - lo)
    return total


def _contended_pair():
    fab = two_tier_fabric(2, 4, 100.0, 100.0)
    a = TimeSlotLedger(fab, 1.0, 64)
    b = TimeSlotLedger(fab, 1.0, 64)
    return fab, a, b


def test_scatter_commit_release_match_reference_loops():
    fab, led_v, led_r = _contended_pair()
    rng = np.random.default_rng(11)
    hosts = [f"H{i}" for i in range(8)]
    plans = []
    for k in range(40):
        s, d = rng.choice(hosts, 2, replace=False)
        rows = led_v.rows(fab.path(str(s), str(d)))
        plan = led_v.plan_transfer(float(rng.uniform(20, 700)), rows,
                                   not_before=float(rng.uniform(0, 15)))
        plans.append(plan)
        led_v.commit(plan)
        _commit_loop(led_r, plan)
        n = min(led_v.reserved.shape[1], led_r.reserved.shape[1])
        assert np.array_equal(led_v.reserved[:, :n], led_r.reserved[:, :n])
        assert _plan_bytes_loop(led_v, plan) == pytest.approx(
            led_v.plan_bytes(plan), rel=1e-12, abs=1e-12
        )
        assert _plan_bytes_loop(led_v, plan, until=plan.start + 1.7) == (
            pytest.approx(led_v.plan_bytes(plan, until=plan.start + 1.7),
                          rel=1e-12, abs=1e-12)
        )
    for plan in plans[::3]:
        led_v.release(plan)
        _release_loop(led_r, plan)
        n = min(led_v.reserved.shape[1], led_r.reserved.shape[1])
        assert np.array_equal(led_v.reserved[:, :n], led_r.reserved[:, :n])


def test_scatter_commit_overbooking_raises_like_loop():
    fab, led_v, led_r = _contended_pair()
    rows = led_v.rows(fab.path("H1", "H0"))
    p1 = led_v.plan_transfer(300.0, rows, not_before=0.0)
    led_v.commit(p1)
    _commit_loop(led_r, p1)
    with pytest.raises(ValueError, match="over-reservation"):
        led_v.commit(p1)  # identical double-book must trip the joint check
    with pytest.raises(ValueError, match="over-reservation"):
        _commit_loop(led_r, p1)


# ---------------------------------------------------------------------------
# plan_transfer_batch: frozen window escalation
# ---------------------------------------------------------------------------


def test_escalation_freezes_finished_candidates():
    """One 100× outlier no longer forces every candidate to re-scan at 4×
    the window (regression for the joint-escalation waste)."""
    fab = two_tier_fabric(2, 5, 100.0, 1000.0)
    led = TimeSlotLedger(fab, 1.0, 64)
    # Throttle H1's uplink to a trickle for a long stretch: its transfer
    # needs ~100× the window of everyone else's.
    up1 = led.rows(["Up1"])
    led.occupy(up1, 0.0, 20000.0, 0.99)
    rows_list = [led.rows(fab.path(f"H{i}", "H0")) for i in range(1, 9)]
    size = 3000.0  # outlier: 3000s at 1 Mbps residue; others: 30 s
    led.batch_scan_cells = 0
    batch = led.plan_transfer_batch(size, rows_list, not_before=0.0)
    for rows, plan in zip(rows_list, batch):
        assert plan == led.plan_transfer(size, rows, not_before=0.0)
    # Frozen escalation: the first window scans all 8 candidates; only the
    # outlier re-scans at 256/1024/4096.  The old joint escalation cost
    # ~8×(64+256+1024+4096) cells.
    outlier_windows = 64 + 256 + 1024 + 4096
    assert led.batch_scan_cells <= 8 * 64 + outlier_windows
    assert batch[0].end >= 90 * batch[1].end  # it really is the outlier


# ---------------------------------------------------------------------------
# ts_plan kernel: numpy reference ≡ Pallas backend (float64-safe inputs)
# ---------------------------------------------------------------------------


def _safe_inputs(seed, n=11, L=4, W=64):
    """Inputs whose values and intermediates are exact in f32 and f64:
    dyadic fractions, power-of-two capacities, integer sizes."""
    rng = np.random.default_rng(seed)
    booked = rng.integers(0, 9, size=(n, L, W)) / 8.0
    caps = 2.0 ** rng.integers(2, 7, size=n)
    secs = np.ones((n, W))
    secs[:, 0] = 0.5
    sizes = rng.integers(1, 300, size=n).astype(float)
    return booked, caps, secs, sizes


@pytest.mark.parametrize("bandwidth_cap", [None, 16.0])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ts_plan_backends_agree_bitwise(seed, bandwidth_cap):
    pytest.importorskip("jax")
    booked, caps, secs, sizes = _safe_inputs(seed)
    ref = ts_plan.plan_scan_numpy(booked, caps, secs, sizes, bandwidth_cap)
    got = ts_plan.plan_scan_pallas(booked, caps, secs, sizes, bandwidth_cap)
    for r, g, name in zip(ref, got, ("resid", "bw", "cum", "hit")):
        assert np.array_equal(
            np.asarray(r, np.float64), np.asarray(g, np.float64)
        ), name


@pytest.mark.parametrize("seed", [0, 3])
def test_ts_plan_overlay_masks_cells(seed):
    """The overlay layer (the reroute engine's phantom-full view) is an
    exact elementwise max: a 0/1 overlay reproduces the overlaid ledger
    bit-for-bit, on both backends."""
    booked, caps, secs, sizes = _safe_inputs(seed)
    rng = np.random.default_rng(seed + 99)
    overlay = (rng.random(booked.shape) < 0.2).astype(np.float64)
    ref = ts_plan.plan_scan_numpy(
        np.maximum(booked, overlay), caps, secs, sizes
    )
    got = ts_plan.plan_scan(booked, caps, secs, sizes, overlay=overlay)
    for r, g, name in zip(ref, got, ("resid", "bw", "cum", "hit")):
        assert np.array_equal(r, g), name
    try:
        import jax  # noqa: F401
    except ImportError:
        return
    pal = ts_plan.plan_scan_pallas(booked, caps, secs, sizes,
                                   overlay=overlay)
    for r, g, name in zip(ref, pal, ("resid", "bw", "cum", "hit")):
        assert np.array_equal(
            np.asarray(r, np.float64), np.asarray(g, np.float64)
        ), name


def test_ts_plan_hit_is_searchsorted():
    booked, caps, secs, sizes = _safe_inputs(5)
    _resid, _bw, cum, hit = ts_plan.plan_scan_numpy(booked, caps, secs, sizes)
    for k in range(len(sizes)):
        assert hit[k] == int(np.searchsorted(cum[k], sizes[k] - ts_plan.EPS))


def test_ts_plan_backend_selection():
    # "auto" is the shipped default; CI legs force "numpy"/"pallas" via env.
    cur = ts_plan.get_backend()
    assert cur in ("numpy", "pallas", "auto")
    with pytest.raises(ValueError):
        ts_plan.set_backend("nope")
    assert ts_plan.get_backend() == cur
    try:
        for name in ("numpy", "pallas", "auto"):
            ts_plan.set_backend(name)
            assert ts_plan.get_backend() == name
    finally:
        ts_plan.set_backend(cur)
