"""Unified observability registry + artifact plumbing.

Covers the registry primitives (counters, groups, spans, flight
recorder), the snapshot contract the benchmarks validate, the back-compat
guarantees the migrated stats dicts rely on, the JSON artifact
dedupe-append, and the no-jax import boundary: ``repro.core``,
``repro.obs`` and ``repro.net.telemetry`` must import without pulling in
jax (enforced in a subprocess so this test is immune to other tests
having imported jax already).
"""
import json
import os
import subprocess
import sys

import pytest

from repro.obs import (
    Counter,
    CounterGroup,
    FlightRecorder,
    Gauge,
    Registry,
    default_registry,
)

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# ------------------------------------------------------------- primitives
def test_counter_and_gauge_cells():
    c = Counter("x")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    c.set(7)
    assert c.value == 7
    g = Gauge("depth")
    g.set(41.0)
    g.set(12.0)
    assert g.value == 12.0


def test_counter_group_is_a_dict_drop_in():
    grp = CounterGroup(("hits", "misses"), prefix="wavefront")
    assert dict(grp) == {"hits": 0, "misses": 0}
    grp["hits"] += 3          # the idiom every migrated call site uses
    grp.inc("misses")
    assert grp["hits"] == 3 and grp["misses"] == 1
    assert grp.get("absent", -1) == -1
    grp["new_key"] = 9        # assignment creates cells, like a dict
    assert set(grp) == {"hits", "misses", "new_key"}
    assert len(grp) == 3
    # the underlying cells carry prefixed metric names
    assert grp._cells["new_key"].name == "wavefront.new_key"
    grp.reset()
    assert all(v == 0 for v in grp.values())
    del grp["new_key"]
    assert "new_key" not in grp


def test_span_accumulates():
    reg = Registry()
    for _ in range(3):
        with reg.span("region"):
            pass
    s = reg.span("region")
    assert s.count == 3
    assert s.total_s >= 0.0


def test_flight_recorder_disabled_is_noop():
    rec = FlightRecorder(capacity=4)
    rec.record("decision", tid=1)
    assert list(rec.events) == []


def test_flight_recorder_bounded_ring(tmp_path):
    rec = FlightRecorder(capacity=3).enable()
    for i in range(5):
        rec.record("ev", i=i)
    assert rec.dropped == 2
    assert [e["i"] for e in rec.events] == [2, 3, 4]  # most recent kept
    assert [e["i"] for e in rec.tail(2)] == [3, 4]
    path = tmp_path / "trace.jsonl"
    assert rec.dump_jsonl(path) == 3
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert lines == [{"kind": "ev", "i": i} for i in (2, 3, 4)]
    rec.clear()
    assert len(rec.events) == 0 and rec.dropped == 0


# --------------------------------------------------------------- registry
def test_registry_memoizes_by_name():
    reg = Registry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.gauge("g") is reg.gauge("g")
    assert reg.span("s") is reg.span("s")
    assert reg.group("grp", ("x",)) is reg.group("grp")


def test_registry_snapshot_structure():
    reg = Registry()
    reg.counter("plain").inc(2)
    reg.gauge("depth").set(5.0)
    reg.group("reroute", ("events",))["events"] = 4
    with reg.span("drain"):
        pass
    reg.trace.enable()
    reg.trace.record("decision", tid=0)
    reg.register_provider("ledger", lambda: {"utilization": 0.5})
    reg.register_provider("broken", lambda: 1 / 0)
    snap = reg.snapshot()
    # groups are flattened into counters under their prefixed names
    assert snap["counters"] == {"plain": 2, "reroute.events": 4}
    assert snap["gauges"] == {"depth": 5.0}
    assert snap["spans"]["drain"]["count"] == 1
    assert snap["trace"] == [{"kind": "decision", "tid": 0}]
    assert snap["ledger"] == {"utilization": 0.5}
    # provider failures are captured, not propagated
    assert "ZeroDivisionError" in snap["broken"]["error"]
    json.dumps(snap)  # the snapshot must be JSON-serializable as-is


def test_default_registry_is_process_wide():
    assert default_registry() is default_registry()


# ----------------------------------------------- migrated stats back-compat
def test_device_kernel_stats_live_in_default_registry():
    from repro.kernels import ts_plan_device

    snap = default_registry().snapshot()
    for key in ("traces", "cache_hits", "mirror_syncs"):
        assert f"ts_plan_device.{key}" in snap["counters"]
    assert set(ts_plan_device.stats) >= {"traces", "cache_hits"}


def test_controller_snapshot_covers_every_layer():
    from repro.core.controller import ClusterController
    from repro.core.tasks import Task
    from repro.core.topology import two_tier_fabric

    ctrl = ClusterController(two_tier_fabric(2, 2), ["H0", "H1", "H2", "H3"])
    ctrl.submit([Task(i, 100.0, 1.0, ("H0", "H1")) for i in range(4)], at=0.0)
    ctrl.run()
    # legacy aliases still point at the registry-backed groups
    assert ctrl.reroute_stats is ctrl.obs.group("reroute")
    assert ctrl.state.ledger.batch_scan_cells >= 0
    snap = ctrl.obs.snapshot()
    for prefix in ("controller.", "wavefront.", "reroute."):
        assert any(k.startswith(prefix) for k in snap["counters"]), prefix
    assert snap["counters"]["controller.jobs"] == 1
    assert snap["ledger"]["links"] == len(ctrl.state.ledger.capacity)
    assert "backend" in snap["kernels"]
    assert snap["jobs"]["0"]["jt"] > 0.0
    json.dumps(snap, default=str)


def test_job_metrics_to_dict_roundtrip():
    from repro.core.simulator import JobMetrics

    m = JobMetrics(mt=3.0, rt=1.0, jt=4.0, lr=0.5, rerouted=2,
                   reexecuted=1, speculative=2, wasted_bytes=40.0)
    assert m.to_dict() == {"mt": 3.0, "rt": 1.0, "jt": 4.0, "lr": 0.5,
                           "rerouted": 2, "reexecuted": 1, "speculative": 2,
                           "wasted_bytes": 40.0}


# --------------------------------------------------------- artifact append
def _load_bench_sched_scale():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "benchmarks"))
    try:
        import bench_sched_scale
    finally:
        sys.path.pop(0)
    return bench_sched_scale

def test_append_json_dedupes_by_name_and_sha(tmp_path, monkeypatch):
    mod = _load_bench_sched_scale()
    path = str(tmp_path / "BENCH.json")
    monkeypatch.setattr(mod, "git_sha", lambda: "aaa111")
    mod.append_json([("leg_a", 1.0, "x=1"), ("leg_b", 2.0, 3.0)], path)
    # same sha, same name: replaced, not duplicated
    mod.append_json([("leg_a", 9.0, "x=2")], path)
    rows = json.load(open(path))
    assert sorted(r["name"] for r in rows) == ["leg_a", "leg_b"]
    (a,) = [r for r in rows if r["name"] == "leg_a"]
    assert a["us_per_call"] == 9.0 and a["derived"] == "x=2"
    # new sha: old rows preserved, trajectory grows
    monkeypatch.setattr(mod, "git_sha", lambda: "bbb222")
    mod.append_json([("leg_a", 4.0, "x=3")], path)
    rows = json.load(open(path))
    assert len([r for r in rows if r["name"] == "leg_a"]) == 2
    assert {r["git_sha"] for r in rows} == {"aaa111", "bbb222"}


# ------------------------------------------------------- import boundaries
def test_core_and_telemetry_import_without_jax():
    code = (
        "import sys\n"
        "import repro.core, repro.core.controller, repro.obs\n"
        "import repro.net.telemetry\n"
        "bad = [m for m in sys.modules if m == 'jax' or m.startswith('jax.')]\n"
        "assert not bad, f'jax leaked into the import graph: {bad}'\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env,
        capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stderr
