"""Borrowing modes + per-tenant QoS (no hypothesis dependency, so the
Example-3 borrowing regressions run even where ``tests/test_qos.py``'s
property suite is skipped)."""
import pytest

from repro.core.qos import (
    Flow,
    QosPort,
    QueueSpec,
    TenantBook,
    TenantSpec,
    example3_port,
)

# -- borrowing modes (Example 3 regression for both) ------------------------


def test_priority_borrowing_example3_rates():
    """``borrowing="priority"`` (the historical behavior): all spare goes
    to the single most important active class.  Example 3 with one shuffle
    and one background flow: Q1 = 100 + 40 spare = 140, Q3 = 10."""
    port = example3_port()
    assert port.borrowing == "priority"
    rates = port.rates({"Q1": 1, "Q3": 1})
    assert rates == {"Q1": 140.0, "Q2": 0.0, "Q3": 10.0}
    # docstring contract: spare follows priority even when the busier
    # queue is the less important one
    rates = port.rates({"Q1": 1, "Q3": 5})
    assert rates == {"Q1": 140.0, "Q2": 0.0, "Q3": 10.0}


def test_proportional_borrowing_example3_rates():
    """``borrowing="proportional"``: spare splits across active classes
    proportionally to active-flow counts (classic HTB).  Example 3 with
    one flow each in Q1/Q3: 40 spare splits 20/20."""
    port = example3_port(borrowing="proportional")
    rates = port.rates({"Q1": 1, "Q3": 1})
    assert rates == {"Q1": 120.0, "Q2": 0.0, "Q3": 30.0}
    rates = port.rates({"Q1": 1, "Q3": 3})
    assert rates == {"Q1": 110.0, "Q2": 0.0, "Q3": 40.0}


def test_borrowing_modes_share_guarantees_and_conserve_work():
    for mode in QosPort.BORROWING:
        port = example3_port(borrowing=mode)
        rates = port.rates({"Q1": 1, "Q2": 1, "Q3": 1})
        assert rates["Q1"] >= 100.0 and rates["Q2"] >= 40.0
        assert rates["Q3"] >= 10.0
        assert abs(sum(rates.values()) - 150.0) < 1e-9


def test_proportional_borrowing_changes_finish_times():
    """Under contention the two modes genuinely differ: proportional
    borrowing slows shuffle down (spare no longer all flows to Q1).  The
    *last* finisher is identical either way — both modes are
    work-conserving, so total drain time is total work over port rate."""
    flows = [Flow("shuffle", 1000.0, "Q1"), Flow("bg", 500.0, "Q3")]
    done_p = example3_port().simulate(flows)
    done_h = example3_port(borrowing="proportional").simulate(flows)
    assert done_p["shuffle"] == pytest.approx(1000.0 / 140.0)
    assert done_h["shuffle"] == pytest.approx(1000.0 / 120.0)
    assert done_p["shuffle"] < done_h["shuffle"]
    assert done_h["bg"] == pytest.approx(done_p["bg"]) == 1500.0 / 150.0


def test_invalid_borrowing_rejected():
    with pytest.raises(ValueError):
        QosPort(100.0, [QueueSpec("Q", 50.0)], borrowing="maxmin")


# -- per-tenant QoS: TenantSpec / TenantBook --------------------------------


def test_tenant_token_bucket_admission():
    book = TenantBook([TenantSpec("a", rate=2.0, burst=2.0),
                       TenantSpec("b")])
    # burst of 2 admits two back-to-back, the third is rejected
    assert book.admit("a", 0.0)
    assert book.admit("a", 0.0)
    assert not book.admit("a", 0.0)
    # tokens refill at 2/s: 0.5 s later one more fits
    assert book.admit("a", 0.5)
    assert not book.admit("a", 0.5)
    # infinite-rate tenants are never rejected
    for _ in range(50):
        assert book.admit("b", 0.0)
    with pytest.raises(KeyError):
        book.admit("nope", 0.0)


def test_tenant_wfq_lag_tracks_weighted_service():
    book = TenantBook([TenantSpec("heavy", weight=2.0),
                       TenantSpec("light", weight=1.0)])
    book.charge("heavy", 4.0)   # vt = 4/2 = 2
    book.charge("light", 1.0)   # vt = 1/1 = 1 (the frontier)
    assert book.lag("heavy") == pytest.approx(1.0)
    assert book.lag("light") == 0.0
    # an idle tenant re-enters at the frontier, not with banked credit
    book.charge("light", 2.0)   # vt = 3; frontier -> heavy at 2
    book.charge("heavy", 2.0)   # base = max(2, 2) + 1 = 3
    assert book.lag("heavy") == pytest.approx(0.0)


def test_tenant_book_validation():
    with pytest.raises(ValueError):
        TenantBook([])
    with pytest.raises(ValueError):
        TenantBook([TenantSpec("a"), TenantSpec("a")])
    with pytest.raises(ValueError):
        TenantSpec("a", weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec("a", rate=-1.0)
