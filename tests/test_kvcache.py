"""Paged KV-cache allocator properties (hypothesis) + gather correctness."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.kvcache import OutOfPages, PagedKVCache, gather_pages


def test_basic_alloc_free_roundtrip():
    kv = PagedKVCache(n_pages=16, page_size=8)
    sp = kv.allocate(1, n_tokens=20)            # 3 pages
    assert len(sp.pages) == 3 and kv.free_pages == 13
    kv.free(1)
    assert kv.free_pages == 16


def test_prefix_sharing_is_zero_copy():
    kv = PagedKVCache(n_pages=16, page_size=8)
    kv.allocate(1, n_tokens=24)                 # 3 pages
    kv.register_prefix(42, 1, n_tokens=16)      # first 2 pages shareable
    before = kv.free_pages
    sp2 = kv.allocate(2, n_tokens=24, prefix_hash=42)
    assert sp2.shared_prefix == 2
    assert kv.free_pages == before - 1          # only the third page is new
    # freeing the original keeps shared pages alive for seq 2
    kv.free(1)
    assert kv.free_pages == 16 - 3              # seq2 still holds 3 pages
    kv.free(2)
    assert kv.free_pages == 16


def test_copy_on_write_on_shared_page_append():
    kv = PagedKVCache(n_pages=16, page_size=4)
    kv.allocate(1, n_tokens=4)                  # exactly one full page
    kv.register_prefix(7, 1, n_tokens=4)
    sp2 = kv.allocate(2, n_tokens=4, prefix_hash=7)
    shared_page = sp2.pages[0]
    # appending into seq2's shared page must not touch seq1's data
    landed = kv.append_token(2)
    assert landed != shared_page                # COW allocated a new page
    kv.free(1)
    kv.free(2)
    assert kv.free_pages == 16


def test_out_of_pages_rolls_back():
    kv = PagedKVCache(n_pages=2, page_size=4)
    kv.allocate(1, n_tokens=8)
    with pytest.raises(OutOfPages):
        kv.allocate(2, n_tokens=8)
    assert 2 not in kv._seqs
    kv.free(1)
    assert kv.free_pages == 2


@given(
    ops=st.lists(
        st.tuples(st.integers(1, 40), st.integers(1, 3)), min_size=1, max_size=40
    )
)
@settings(max_examples=40, deadline=None)
def test_refcount_conservation(ops):
    """Random alloc/append/free interleavings never leak or double-free."""
    kv = PagedKVCache(n_pages=64, page_size=4)
    live = {}
    for i, (tok, action) in enumerate(ops):
        try:
            if action == 1 or not live:
                kv.allocate(i, n_tokens=tok)
                live[i] = True
            elif action == 2:
                sid = next(iter(live))
                kv.append_token(sid)
            else:
                sid = next(iter(live))
                kv.free(sid)
                del live[sid]
        except OutOfPages:
            pass
    for sid in list(live):
        kv.free(sid)
    assert kv.free_pages == 64
    assert (kv._ref == 0).all()


def test_gather_pages_reads_correct_tokens():
    pool = jnp.arange(8 * 4 * 2 * 3, dtype=jnp.float32).reshape(8, 4, 2, 3)
    kv = PagedKVCache(n_pages=8, page_size=4)
    sp = kv.allocate(1, n_tokens=8)
    table = kv.page_table(1, max_pages=4)
    view = gather_pages(pool, jnp.asarray(table))
    assert view.shape == (16, 2, 3)
    np.testing.assert_array_equal(
        np.asarray(view[:4]), np.asarray(pool[sp.pages[0]])
    )
    np.testing.assert_array_equal(np.asarray(view[8:]), 0)  # padded pages
