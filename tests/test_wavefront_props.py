"""Wavefront placement engine — hypothesis property suite.

Random contended ledgers, bandwidth caps and multipath fat-trees: the
wavefront engine must emit bit-identical schedules to the sequential
``place`` loop (see ``tests/test_wavefront.py`` for the deterministic
regressions and the kernel-contract tests).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.controller import BassPolicy, ClusterState
from repro.core.tasks import BackgroundFlow, Instance, Task
from repro.core.timeslot import TimeSlotLedger
from repro.core.topology import two_tier_fabric

from test_wavefront import canon

@st.composite
def instances(draw):
    """Small two-tier clusters with contended ledgers (background bursts)."""
    n_hosts = draw(st.integers(4, 10))
    n_tasks = draw(st.integers(2, 24))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    hosts_per_leaf = (n_hosts + 1) // 2
    fab = two_tier_fabric(2, hosts_per_leaf, 100.0, 100.0)
    hosts = [f"H{i}" for i in range(2 * hosts_per_leaf)][:n_hosts]
    tasks = [
        Task(
            tid=i + 1,
            size=float(rng.uniform(10, 900)),
            compute=float(rng.uniform(0.5, 15)),
            replicas=tuple(
                rng.choice(hosts, size=min(3, n_hosts), replace=False)
            ),
        )
        for i in range(n_tasks)
    ]
    idle = {h: float(rng.uniform(0, 25)) for h in hosts}
    bg = []
    for _ in range(draw(st.integers(0, 5))):
        a, b = rng.choice(hosts, 2, replace=False)
        t0 = float(rng.uniform(0, 25))
        bg.append(BackgroundFlow(str(a), str(b), float(rng.uniform(0.3, 0.95)),
                                 t0, t0 + float(rng.uniform(2, 15))))
    return Instance(fabric=fab, workers=hosts, idle=idle, tasks=tasks,
                    slot_duration=1.0, background=bg)


@given(inst=instances())
@settings(max_examples=60, deadline=None)
def test_wavefront_bit_identical_to_sequential(inst):
    pol = BassPolicy()
    s_seq = ClusterState.from_instance(inst)
    seq = [pol.place(t, s_seq) for t in inst.tasks]
    s_wf = ClusterState.from_instance(inst)
    wf = pol.place_batch(inst.tasks, s_wf)
    assert canon(wf) == canon(seq)
    n = min(s_seq.ledger.reserved.shape[1], s_wf.ledger.reserved.shape[1])
    assert np.array_equal(s_seq.ledger.reserved[:, :n],
                          s_wf.ledger.reserved[:, :n])
    assert s_seq.idle == s_wf.idle
    # the engine actually ran (this is not sequential-vs-sequential)
    planner = getattr(s_wf, "_wavefront", None)
    assert planner is not None
    assert planner.stats["hits"] + planner.stats["misses"] == sum(
        1 for a in wf if not (a.bw_needed is None and a.transfer is None)
    )


@given(inst=instances(), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_wavefront_multipath_bit_identical(inst, seed):
    from repro.net.dataplane import DataPlane

    pol = BassPolicy(multipath=True, k_paths=3)

    def mk():
        s = ClusterState(inst.fabric, inst.workers, inst.idle,
                         slot_duration=inst.slot_duration)
        for bg in inst.background:
            s.observe_flow(bg)
        s.dataplane = DataPlane(inst.fabric, k=3)
        return s

    s_seq = mk()
    seq = [pol.place(t, s_seq) for t in inst.tasks]
    s_wf = mk()
    wf = pol.place_batch(inst.tasks, s_wf)
    assert canon(wf) == canon(seq)


@given(
    size=st.floats(20.0, 2000.0),
    cap=st.one_of(st.none(), st.floats(5.0, 80.0)),
    nb=st.floats(0.0, 30.0),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_batch_plans_match_loop_under_bandwidth_caps(size, cap, nb, seed):
    """plan_transfer_batch (the ts_plan scan + frozen escalation) stays
    bit-identical to per-candidate plan_transfer with bandwidth caps on
    contended ledgers."""
    rng = np.random.default_rng(seed)
    fab = two_tier_fabric(2, 4, 100.0, 100.0)
    led = TimeSlotLedger(fab, 1.0, 64)
    hosts = [f"H{i}" for i in range(8)]
    for _ in range(6):
        a, b = rng.choice(hosts, 2, replace=False)
        p = led.plan_transfer(float(rng.uniform(50, 400)),
                              led.rows(fab.path(str(a), str(b))),
                              not_before=float(rng.uniform(0, 10)))
        led.commit(p)
    rows_list = [led.rows(fab.path(f"H{i}", "H0")) for i in range(1, 8)]
    batch = led.plan_transfer_batch(size, rows_list, not_before=nb,
                                    bandwidth_cap=cap)
    for rows, plan in zip(rows_list, batch):
        solo = led.plan_transfer(size, rows, not_before=nb, bandwidth_cap=cap)
        assert plan == solo


