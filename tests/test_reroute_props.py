"""Batched reroute engine + wavefront-under-live-routing — equivalence suite.

The failure-storm fast path's whole contract is byte-identity to the
sequential reference: ``core.reroute.RerouteEngine`` must emit the same
``reroute_log`` records, the same winner plans, the same retimed
schedules and the same ledger bytes as :func:`core.reroute.sequential_reroute`
on any storm, and ``BassPolicy.place_batch`` must match the per-task
``place`` loop while the data plane carries failures (there is no
sequential fallback anymore — the wavefront *is* the degraded path).
"""
import numpy as np
import pytest

from repro.core.controller import BassPolicy, ClusterController, ClusterState
from repro.core.tasks import Task
from repro.core.topology import UnroutableError, storage_hosts
from repro.net.dataplane import DataPlane
from repro.net.events import LinkDown, LinkUp, SwitchDown, SwitchUp
from repro.net.fattree import fat_tree_fabric, oversubscribed_leaf_spine

from test_wavefront import canon


def rr_canon(log):
    """Bit-exact image of a reroute log."""
    return [
        (
            float(r.at).hex(), r.flow, r.dead_links, r.src, r.dst,
            r.old_path, r.new_path,
            float(r.delivered).hex(), float(r.remaining).hex(),
            float(r.old_end).hex(), float(r.new_end).hex(),
        )
        for r in log
    ]


def _run_storm(engine, policy, fab, hosts, jobs, events, idle, flows=()):
    """One controller life with the given reroute engine; returns the
    controller and the exception (if the storm stranded a transfer)."""
    ctrl = ClusterController(fab, hosts, policy, idle=idle, slot_duration=1.0)
    ctrl.reroute_engine = engine
    for at, tasks in jobs:
        ctrl.submit(tasks, at=at)
    for ev in events:
        ctrl.inject_net(ev)
    for fl in flows:
        ctrl.inject_flow(fl)
    err = None
    try:
        ctrl.run()
    except (UnroutableError, RuntimeError) as e:
        err = e
    return ctrl, err


def _assert_equivalent(c_batched, e_batched, c_seq, e_seq):
    """Batched and sequential controllers must agree byte-for-byte —
    including on the exception path (the engine undoes its up-front tail
    releases before raising)."""
    assert (type(e_batched), str(e_batched)) == (type(e_seq), str(e_seq))
    assert rr_canon(c_batched.reroute_log) == rr_canon(c_seq.reroute_log)
    assert canon(c_batched.schedule().assignments) == canon(
        c_seq.schedule().assignments
    )
    rb, rs = c_batched.state.ledger.reserved, c_seq.state.ledger.reserved
    n = min(rb.shape[1], rs.shape[1])
    assert np.array_equal(rb[:, :n], rs[:, :n])
    assert not rb[:, n:].any() and not rs[:, n:].any()
    if e_batched is None:
        assert c_batched.state.idle == c_seq.state.idle
        assert c_batched._live_jobs == c_seq._live_jobs
        assert c_batched._suspended == c_seq._suspended


def _storm_jobs(rng, hosts, n_jobs, tasks_per_job):
    jobs = []
    for j in range(n_jobs):
        tasks = [
            Task(
                tid=j * 1000 + i,
                size=float(rng.uniform(100, 900)),
                compute=float(rng.uniform(1, 8)),
                replicas=tuple(rng.choice(hosts, 3, replace=False)),
            )
            for i in range(tasks_per_job)
        ]
        jobs.append((float(j) * 2.0, tasks))
    return jobs


def test_batched_reroute_spine_kill_identical():
    """Deterministic regression: a switch kill plus link churn over a
    k=4 fat-tree with dozens of in-flight transfers."""
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    rng = np.random.default_rng(11)
    idle = {h: float(rng.uniform(0, 10)) for h in hosts}
    jobs = _storm_jobs(rng, hosts, 3, 16)
    events = [
        SwitchDown("core0_0", at=4.0),
        LinkDown("ac/p1a1c1", at=6.0),
        SwitchUp("core0_0", at=30.0),
        LinkUp("ac/p1a1c1", at=32.0),
    ]
    args = (BassPolicy(multipath=True), fab, hosts, jobs, events, idle)
    cb, eb = _run_storm("batched", *args)
    cs, es = _run_storm("sequential", *args)
    assert eb is None and len(cb.reroute_log) > 0
    assert cb.reroute_stats["victims"] == len(cb.reroute_log)
    _assert_equivalent(cb, eb, cs, es)


def test_batched_reroute_unroutable_parity():
    """Stranding every path must raise identically from both engines and
    leave identical controller state behind (undo of up-front releases)."""
    fab = oversubscribed_leaf_spine(2, 2, 2, host_mbps=100.0, spine_mbps=100.0)
    jobs = [(0.0, [
        Task(tid=1, size=2000.0, compute=5.0, replicas=("H0",)),
        Task(tid=2, size=1500.0, compute=4.0, replicas=("H1",)),
    ])]
    events = [LinkDown("ls/L0S0", at=3.0), LinkDown("ls/L0S1", at=3.0)]
    args = (BassPolicy(), fab, ["H2", "H3"], jobs, events, {})
    cb, eb = _run_storm("batched", *args)
    cs, es = _run_storm("sequential", *args)
    assert isinstance(eb, UnroutableError)
    _assert_equivalent(cb, eb, cs, es)


def test_expiry_heap_compacts_during_storm():
    """Mass reinstalls across a failure storm must not accumulate stale
    expiry generations beyond the compaction bound."""
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    rng = np.random.default_rng(5)
    idle = {h: 0.0 for h in hosts}
    jobs = _storm_jobs(rng, hosts, 2, 40)
    # Alternate failures/recoveries so the same cookies reinstall often.
    events = []
    links = ["ac/p0a0c0", "ac/p1a0c0", "ac/p2a0c0", "ac/p3a0c0"]
    for k, name in enumerate(links * 4):
        events.append(LinkDown(name, at=2.0 + k))
        events.append(LinkUp(name, at=2.5 + k))
    ctrl, err = _run_storm("batched", BassPolicy(multipath=True), fab, hosts,
                           jobs, events, idle)
    assert err is None
    assert len(ctrl._expiry) <= max(64, 2 * len(ctrl._flow_gen))


def _degraded_state(fab, hosts, idle, dead_links=(), dead_switches=(), k=3):
    s = ClusterState(fab, hosts, idle, slot_duration=1.0)
    s.dataplane = DataPlane(fab, k=k)
    for n in dead_links:
        s.dataplane.fail_link(n)
    for n in dead_switches:
        s.dataplane.fail_switch(n)
    return s


def test_wavefront_under_live_routing_identical():
    """Batch placement on a degraded fabric (no sequential fallback) must
    match the per-task ``place`` loop bit-for-bit — single-path and
    multipath."""
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    rng = np.random.default_rng(23)
    idle = {h: float(rng.uniform(0, 10)) for h in hosts}
    tasks = [
        Task(tid=i, size=float(rng.uniform(50, 600)),
             compute=float(rng.uniform(1, 6)),
             replicas=tuple(rng.choice(hosts, 3, replace=False)))
        for i in range(48)
    ]
    # switch-layer churn only: every host keeps a surviving path
    dead = ("ac/p0a0c0", "ea/p1e0a0", "ac/p3a1c0")
    for multipath in (False, True):
        pol = BassPolicy(multipath=multipath, k_paths=3)
        s_seq = _degraded_state(fab, hosts, idle, dead_links=dead)
        seq = [pol.place(t, s_seq) for t in tasks]
        s_wf = _degraded_state(fab, hosts, idle, dead_links=dead)
        wf = pol.place_batch(tasks, s_wf)
        assert canon(wf) == canon(seq), f"multipath={multipath}"
        assert np.array_equal(
            s_seq.ledger.reserved, s_wf.ledger.reserved
        )
        assert s_seq.idle == s_wf.idle
        planner = getattr(s_wf, "_wavefront", None)
        assert planner is not None  # no fallback: the engine ran degraded
        assert planner.stats["hits"] + planner.stats["misses"] > 0


def test_wavefront_degraded_unroutable_parity():
    """A task whose replicas are all stranded must raise the same
    UnroutableError from the batch path as from the loop, after
    identical earlier commits."""
    fab = oversubscribed_leaf_spine(2, 2, 2, host_mbps=100.0,
                                    spine_mbps=100.0)
    tasks = [
        Task(tid=1, size=200.0, compute=3.0, replicas=("H0",)),
        Task(tid=2, size=300.0, compute=3.0, replicas=("H1",)),
    ]
    pol = BassPolicy()

    def run(batch):
        s = _degraded_state(fab, ["H2", "H3"], {},
                            dead_links=("ls/L0S0", "ls/L0S1"))
        try:
            if batch:
                pol.place_batch(tasks, s)
            else:
                for t in tasks:
                    pol.place(t, s)
        except UnroutableError as e:
            return s, e
        return s, None

    s_wf, e_wf = run(True)
    s_seq, e_seq = run(False)
    assert isinstance(e_wf, UnroutableError)
    assert (type(e_wf), str(e_wf)) == (type(e_seq), str(e_seq))
    assert np.array_equal(s_seq.ledger.reserved, s_wf.ledger.reserved)


# ---------------------------------------------------------------------------
# Randomized equivalence: case builders shared by the seed-parametrized
# deterministic sweeps (always run) and the hypothesis property suites
# (run where hypothesis is installed, e.g. CI).
# ---------------------------------------------------------------------------


def _storm_case(seed, n_jobs, tasks_per_job, n_events, multipath,
                n_flows=0):
    """A fat-tree, a couple of jobs, a multi-link/switch storm, and
    optional background cross-traffic (flows booked before or between
    placements make commits uneven — the invariant-guard/fallback
    regime)."""
    from repro.core.tasks import BackgroundFlow

    rng = np.random.default_rng(seed)
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    idle = {h: float(rng.uniform(0, 10)) for h in hosts}
    jobs = _storm_jobs(rng, hosts, n_jobs, tasks_per_job)
    switch_pool = [f"core{g}_{j}" for g in range(2) for j in range(2)]
    link_pool = sorted(n for n in fab.links if not n.startswith("eh/"))
    events = []
    for _ in range(n_events):
        t = float(rng.uniform(1.0, 20.0))
        if rng.random() < 0.35:
            node = switch_pool[int(rng.integers(len(switch_pool)))]
            events.append(SwitchDown(node, at=t))
            if rng.random() < 0.5:
                events.append(SwitchUp(node, at=t + float(rng.uniform(1, 15))))
        else:
            link = link_pool[int(rng.integers(len(link_pool)))]
            events.append(LinkDown(link, at=t))
            if rng.random() < 0.5:
                events.append(LinkUp(link, at=t + float(rng.uniform(1, 15))))
    flows = []
    for _ in range(n_flows):
        a, b = rng.choice(hosts, 2, replace=False)
        t0 = float(rng.uniform(0.0, 12.0))
        flows.append(BackgroundFlow(str(a), str(b),
                                    float(rng.uniform(0.2, 0.7)),
                                    t0, t0 + float(rng.uniform(5, 30))))
    return fab, hosts, idle, jobs, events, flows, multipath


def _check_storm_equiv(case):
    fab, hosts, idle, jobs, events, flows, multipath = case
    pol_args = {"multipath": multipath, "k_paths": 3 if multipath else None}
    cb, eb = _run_storm("batched", BassPolicy(**pol_args), fab, hosts,
                        jobs, events, idle, flows)
    cs, es = _run_storm("sequential", BassPolicy(**pol_args), fab, hosts,
                        jobs, events, idle, flows)
    _assert_equivalent(cb, eb, cs, es)


def _degraded_case(seed, n_dead, n_tasks, multipath):
    rng = np.random.default_rng(seed)
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    idle = {h: float(rng.uniform(0, 15)) for h in hosts}
    links = sorted(fab.links)
    dead = tuple(
        links[i] for i in rng.choice(len(links), n_dead, replace=False)
    )
    tasks = [
        Task(tid=i, size=float(rng.uniform(20, 700)),
             compute=float(rng.uniform(0.5, 8)),
             replicas=tuple(rng.choice(hosts, 3, replace=False)))
        for i in range(n_tasks)
    ]
    return fab, hosts, idle, dead, tasks, multipath


def _check_degraded_equiv(case):
    fab, hosts, idle, dead, tasks, multipath = case
    pol = BassPolicy(multipath=multipath, k_paths=3 if multipath else None)

    def run(batch):
        s = _degraded_state(fab, hosts, idle, dead_links=dead)
        try:
            out = (pol.place_batch(tasks, s) if batch
                   else [pol.place(t, s) for t in tasks])
        except UnroutableError as e:
            return s, None, e
        return s, out, None

    s_wf, wf, e_wf = run(True)
    s_seq, seq, e_seq = run(False)
    assert (type(e_wf), str(e_wf)) == (type(e_seq), str(e_seq))
    if e_wf is None:
        assert canon(wf) == canon(seq)
        assert s_seq.idle == s_wf.idle
    n = min(s_seq.ledger.reserved.shape[1], s_wf.ledger.reserved.shape[1])
    assert np.array_equal(s_seq.ledger.reserved[:, :n],
                          s_wf.ledger.reserved[:, :n])
    assert not s_seq.ledger.reserved[:, n:].any()
    assert not s_wf.ledger.reserved[:, n:].any()


@pytest.mark.parametrize("seed", range(0, 16, 2))
def test_batched_reroute_equiv_seeded(seed):
    _check_storm_equiv(_storm_case(seed, 1 + seed % 3, 6 + seed,
                                   1 + seed % 4, bool(seed % 2),
                                   n_flows=seed % 3))


def test_batched_reroute_uneven_commits_identical(monkeypatch):
    """Regression (review finding): cross-traffic injected *after* a
    clean placement makes walk commits book unevenly across links — a
    consumed cell's non-bottleneck links keep residue the sequential
    loop later books, so availability may only drop where a commit
    actually saturated the cell.  ``WAVE=1`` forces later victims'
    column enumeration to happen after earlier commits."""
    from repro.core.reroute import RerouteEngine
    from repro.core.tasks import BackgroundFlow

    monkeypatch.setattr(RerouteEngine, "WAVE", 1)
    fab = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(fab)
    srcs, workers = hosts[:8], hosts[8:]
    rng = np.random.default_rng(1)
    tasks = [
        Task(tid=i, size=float(rng.uniform(200, 900)), compute=1.0,
             replicas=tuple(rng.choice(srcs, 3, replace=False)))
        for i in range(40)
    ]

    def run(engine):
        ctrl = ClusterController(fab, workers, BassPolicy(multipath=True),
                                 slot_duration=0.1)
        ctrl.reroute_engine = engine
        ctrl.submit(tasks, at=0.0)
        for k, (a, b) in enumerate(zip(srcs, workers)):
            ctrl.inject_flow(BackgroundFlow(a, b, 0.35, 0.45, 40.0 + k))
        ctrl.fail_switch("core0_0", at=0.5)
        err = None
        try:
            ctrl.run()
        except UnroutableError as e:
            err = e
        return ctrl, err

    cb, eb = run("batched")
    cs, es = run("sequential")
    assert len(cs.reroute_log) > 0
    # the guard must not have tripped: this exercises the engine itself
    assert cb.reroute_stats["fallbacks"] == 0
    assert cb.reroute_stats["events"] == 1
    _assert_equivalent(cb, eb, cs, es)


@pytest.mark.parametrize("seed", range(1, 17, 2))
def test_wavefront_degraded_equiv_seeded(seed):
    _check_degraded_equiv(_degraded_case(seed, 1 + seed % 6, 4 + seed,
                                         bool(seed % 2)))


try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(
        seed=st.integers(0, 2**16),
        n_jobs=st.integers(1, 3),
        tasks_per_job=st.integers(4, 14),
        n_events=st.integers(1, 4),
        multipath=st.booleans(),
        n_flows=st.integers(0, 4),
    )
    @settings(max_examples=25, deadline=None)
    def test_batched_reroute_equiv_property(seed, n_jobs, tasks_per_job,
                                            n_events, multipath, n_flows):
        _check_storm_equiv(
            _storm_case(seed, n_jobs, tasks_per_job, n_events, multipath,
                        n_flows)
        )

    @given(
        seed=st.integers(0, 2**16),
        n_dead=st.integers(1, 6),
        n_tasks=st.integers(2, 24),
        multipath=st.booleans(),
    )
    @settings(max_examples=25, deadline=None)
    def test_wavefront_degraded_equiv_property(seed, n_dead, n_tasks,
                                               multipath):
        _check_degraded_equiv(
            _degraded_case(seed, n_dead, n_tasks, multipath)
        )


# ---------------------------------------------------------------------------
# Satellite ledger plumbing: path-row cache + grouped commit scatter
# ---------------------------------------------------------------------------

from repro.core.timeslot import TimeSlotLedger  # noqa: E402
from repro.core.topology import two_tier_fabric  # noqa: E402


def test_path_rows_cache_tracks_fabric_version():
    fab = two_tier_fabric(2, 2, host_mbps=100.0, trunk_mbps=40.0)
    led = TimeSlotLedger(fab, 1.0, 16)
    rows = led.path_rows("H0", "H2")
    assert rows == led.rows(fab.path("H0", "H2"))
    assert led.path_rows("H0", "H2") is rows  # cached tuple
    # topology mutation bumps fabric.version: the cache must not serve a
    # pre-mutation row set
    fab.add_node("X", "host")
    fab.add_link("xl", "X", "H0", 100.0)
    led2 = TimeSlotLedger(fab, 1.0, 16)
    assert led2.path_rows("X", "H0") == led2.rows(fab.path("X", "H0"))
    led._path_rows_version = -1  # simulate stale snapshot
    led._path_rows[("H0", "H2")] = (999,)
    assert led.path_rows("H0", "H2") == rows  # version check cleared it


def test_commit_batch_equals_sequential_commits():
    fab = two_tier_fabric(2, 4, host_mbps=100.0, trunk_mbps=100.0)
    led_a = TimeSlotLedger(fab, 1.0, 32)
    led_b = TimeSlotLedger(fab, 1.0, 32)
    # three plans over disjoint cells (different host uplink paths)
    plans = []
    for src, dst in (("H0", "H1"), ("H2", "H3"), ("H4", "H5")):
        rows = led_a.rows(fab.path(src, dst))
        plans.append(led_a.plan_transfer(250.0, rows, not_before=0.0))
    led_a.commit_batch(plans)
    for p in plans:
        led_b.commit(p)
    n = min(led_a.reserved.shape[1], led_b.reserved.shape[1])
    assert np.array_equal(led_a.reserved[:, :n], led_b.reserved[:, :n])
    assert not led_a.reserved[:, n:].any()
    assert not led_b.reserved[:, n:].any()
    # over-reservation still raises jointly
    with pytest.raises(ValueError):
        led_a.commit_batch([plans[0]])


def test_commit_batch_empty_and_no_op_plans():
    fab = two_tier_fabric(2, 2, host_mbps=100.0, trunk_mbps=40.0)
    led = TimeSlotLedger(fab, 1.0, 8)
    before = led.reserved.copy()
    led.commit_batch([])
    rows = led.rows(fab.path("H0", "H2"))
    led.commit_batch([led.plan_transfer(0.0, rows, not_before=1.0)])
    assert np.array_equal(led.reserved, before)
