"""Example-3 QoS queue model properties."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.qos import (
    Flow,
    QosPort,
    QueueSpec,
    example3_port,
    shuffle_vs_default,
    single_queue_port,
)


def test_example3_shuffle_beats_default():
    """The paper's claim: Q1=100 for shuffle + Q3=10 for background beats a
    single shared 150 Mbps queue whenever background traffic competes."""
    queued, default = shuffle_vs_default(1000.0, 500.0, n_background=1)
    assert queued < default
    # shuffle gets ≥ its guaranteed 100 Mbps (HTB borrowing may add more):
    # 1000 Mbit → at most 10 s
    assert queued <= 10.0 + 1e-9


@given(
    shuffle=st.floats(100.0, 5000.0),
    bg=st.floats(100.0, 5000.0),
    n_bg=st.integers(1, 6),
)
@settings(max_examples=40, deadline=None)
def test_queued_never_slower_for_shuffle(shuffle, bg, n_bg):
    queued, default = shuffle_vs_default(shuffle, bg, n_background=n_bg)
    assert queued <= default + 1e-6


def test_no_background_borrowing_matches_default():
    """With zero competition, HTB borrowing lends the whole port to Q1, so
    the queued scheme matches the single shared queue exactly."""
    q = example3_port().simulate([Flow("s", 1500.0, "Q1")])["s"]
    d = single_queue_port().simulate([Flow("s", 1500.0, "Q")])["s"]
    assert q == pytest.approx(d) == pytest.approx(10.0)


def test_work_conservation():
    """Total service time never exceeds serialized time at max rate."""
    port = example3_port()
    flows = [
        Flow("a", 300.0, "Q1"),
        Flow("b", 300.0, "Q2"),
        Flow("c", 300.0, "Q3"),
    ]
    done = port.simulate(flows)
    assert max(done.values()) <= 900.0 / 150.0 + 1e-6


def test_rate_guarantees_sum_below_port():
    with pytest.raises(ValueError):
        QosPort(100.0, [QueueSpec("a", 80.0), QueueSpec("b", 40.0)])


def test_arrival_ordering():
    port = example3_port()
    done = port.simulate(
        [Flow("early", 100.0, "Q1", arrival=0.0), Flow("late", 100.0, "Q1", arrival=5.0)]
    )
    assert done["early"] < done["late"]
