"""Dry-run policy selection rules (§Perf) — pure unit tests."""
import pytest

from repro.configs import TRAIN_4K, PREFILL_32K, DECODE_32K
from repro.launch.dryrun import SMALL_MODEL_PARAMS, policy_rules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_baseline_rules_have_no_opt_axes():
    cfg, pr, act = policy_rules("qwen3-32b", TRAIN_4K, SINGLE, "baseline")
    assert "heads" not in act and "d_ff" not in act
    assert "megatron_blocks" not in act
    assert cfg.moe_impl == "gather"


def test_opt_train_gets_megatron_rules():
    cfg, pr, act = policy_rules("qwen3-32b", TRAIN_4K, SINGLE, "opt")
    assert act.get("heads") == "model"
    assert act.get("d_ff") == "model"
    assert act.get("megatron_blocks") is True


def test_opt_prefill_keeps_baseline_sharding():
    """Measured lesson: head-sharding regresses 32k prefill."""
    cfg, pr, act = policy_rules("qwen3-32b", PREFILL_32K, SINGLE, "opt")
    assert "heads" not in act and "megatron_blocks" not in act


def test_opt_moe_gets_a2a_everywhere():
    for shape in (TRAIN_4K, PREFILL_32K):
        cfg, _, _ = policy_rules("moonshot-v1-16b-a3b", shape, SINGLE, "opt")
        assert cfg.moe_impl == "a2a"


def test_opt_small_model_pure_dp():
    from repro.configs import get_config

    assert get_config("whisper-base").param_count() < SMALL_MODEL_PARAMS
    cfg, pr, act = policy_rules("whisper-base", TRAIN_4K, SINGLE, "opt")
    assert isinstance(act["batch"], list)          # DP candidate chain
    assert pr == {}                                # params replicated


def test_internvl2_above_small_threshold():
    from repro.configs import get_config

    assert get_config("internvl2-1b").param_count() > SMALL_MODEL_PARAMS
    _, pr, act = policy_rules("internvl2-1b", TRAIN_4K, SINGLE, "opt")
    assert pr is None and not isinstance(act["batch"], list)


def test_multipod_batch_axes():
    _, _, act = policy_rules("qwen3-32b", TRAIN_4K, MULTI, "baseline")
    assert act["batch"] == ("pod", "data")
    _, _, act = policy_rules("qwen3-32b", DECODE_32K, MULTI, "baseline")
    assert act["batch"] == ("pod", "data")