"""End-to-end system tests: the full training loop learns, checkpoints
restore bit-exactly, the BASS control plane is wired into the data path,
and a tiny dry-run (lower+compile on a 1-device mesh) works outside the
512-device environment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import make_train_step
from repro.launch.train import TINY
from repro.models.model import Model
from repro.optim import AdamW, constant, warmup_cosine


def _run_steps(model, params, opt, opt_state, source, n, start=0, accum=1):
    step_fn = jax.jit(make_train_step(model, opt, accum=accum))
    losses = []
    for s in range(start, start + n):
        batch = {k: jnp.asarray(v) for k, v in source.batch(s).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    return params, opt_state, losses


def test_tiny_training_learns():
    """The increment task is learnable from unigram structure — loss must
    collapse well below the uniform floor within 80 steps.  (The richer
    copy task needs ~10⁶ tokens to reach onset and is exercised by
    examples/train_e2e.py instead.)"""
    cfg = TINY
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(1e-2, 10, 80))
    opt_state = opt.init(params)
    src = SyntheticLM(DataConfig(seq_len=64, global_batch=16,
                                 vocab_size=cfg.vocab_size, seed=0,
                                 task="increment"))
    _, _, losses = _run_steps(model, params, opt, opt_state, src, 80)
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 2.0, (first, last)


def test_grad_accumulation_equivalence():
    """accum=4 must match accum=1 on the same global batch (up to bf16)."""
    cfg = TINY.with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    opt = AdamW(lr=constant(1e-3))
    src = SyntheticLM(DataConfig(seq_len=64, global_batch=8,
                                 vocab_size=cfg.vocab_size, seed=1))
    batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}

    p1, _, _ = jax.jit(make_train_step(model, opt, accum=1))(params, opt.init(params), batch)
    p4, _, _ = jax.jit(make_train_step(model, opt, accum=4))(params, opt.init(params), batch)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-3
        )


def test_checkpoint_restart_bit_exact(tmp_path):
    """Stop at step 6, restore, continue — must equal the uninterrupted run
    (fault-tolerance requirement: restart is invisible)."""
    cfg = TINY
    model = Model(cfg)
    params0 = model.init(jax.random.PRNGKey(2))
    opt = AdamW(lr=constant(1e-3))
    src = SyntheticLM(DataConfig(seq_len=64, global_batch=4,
                                 vocab_size=cfg.vocab_size, seed=2))

    # uninterrupted: 12 steps
    p_ref, o_ref, _ = _run_steps(model, params0, opt, opt.init(params0), src, 12)

    # interrupted: 6 steps → checkpoint → restore → 6 more
    p_a, o_a, _ = _run_steps(model, params0, opt, opt.init(params0), src, 6)
    ck = Checkpointer(tmp_path)
    ck.save(6, (p_a, o_a), blocking=True)
    step, (p_b, o_b) = ck.restore((p_a, o_a))
    assert step == 6
    p_fin, o_fin, _ = _run_steps(model, p_b, opt, o_b, src, 6, start=6)

    for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_fin)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_smoke_mesh_lower_compile():
    """A miniature dry-run on the real (1-device) mesh: lower + compile the
    sharded train step exactly as launch.dryrun does at 512 devices."""
    from repro.distributed.sharding import param_shardings
    from repro.launch.inputs import train_inputs
    from repro.configs.base import ShapeSpec

    mesh = make_smoke_mesh()
    cfg = get_config("starcoder2-3b", smoke=True)
    model = Model(cfg)
    shape = ShapeSpec("t", "train", 32, 4)
    step = make_train_step(model, AdamW(lr=constant(1e-3)), accum=2)
    params_abs = model.abstract()
    param_sh = param_shardings(model.defs(), mesh)
    batch_abs, batch_sh = train_inputs(cfg, shape, mesh)
    opt_abs = jax.eval_shape(AdamW(lr=1e-3).init, params_abs)
    with mesh:
        lowered = jax.jit(step).lower(params_abs, opt_abs, batch_abs)
        compiled = lowered.compile()
    assert compiled.memory_analysis() is not None
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # jax < 0.5 returns a one-element list
        cost = cost[0] if cost else {}
    assert cost.get("flops", 0) > 0


def test_moe_drops_are_bounded():
    """Capacity-factor property: with cf=1.25 and near-uniform routing, the
    realized drop rate on random tokens stays small."""
    from repro.models.moe import capacity, moe_block

    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # peel one layer's moe params
    moe_p = jax.tree_util.tree_map(lambda a: a[0], params["stack"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model), jnp.bfloat16)
    y, aux = moe_block(moe_p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux))
    # output should be non-trivial for most tokens (few drops)
    nonzero = float((jnp.abs(y.astype(jnp.float32)).sum(-1) > 0).mean())
    assert nonzero > 0.85
