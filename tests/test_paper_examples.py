"""Exactness tests against the paper's §IV worked examples.

Every number here is stated in the paper text: Example 1 (BASS, 35 s; TK1 on
N1 finishing at 17 s with slots TS4..TS8 on Link1+Link2), Discussion 1 (HDS
39 s with the per-node allocation spelled out; BAR 38 s via the TK9→N3
move), Example 2 (Pre-BASS 34 s, last finisher TK8, prefetch slots
TS1..TS5).
"""
import pytest

from repro.core.bass import schedule_bass
from repro.core.baselines import schedule_bar, schedule_hds
from repro.core.prebass import schedule_prebass
from repro.core.simulator import replay
from repro.core.examples_fig import (
    PAPER_HDS_ALLOC,
    PAPER_MAKESPAN,
    PAPER_TK1,
    example1_instance,
)


def test_bass_makespan_35s():
    s = schedule_bass(example1_instance())
    assert s.makespan == pytest.approx(PAPER_MAKESPAN["BASS"])


def test_bass_tk1_detail():
    s = schedule_bass(example1_instance())
    a1 = next(a for a in s.assignments if a.tid == 1)
    assert a1.node == PAPER_TK1["node"]
    assert a1.finish == pytest.approx(PAPER_TK1["completion"])
    assert a1.transfer is not None
    assert a1.transfer.slots == PAPER_TK1["slots"]          # TS4..TS8
    links = set(s.ledger.link_names(a1.transfer.links))
    assert links == {"Link1", "Link2"}


def test_bass_tk9_determines_makespan():
    s = schedule_bass(example1_instance())
    latest = s.latest()
    assert latest.tid == 9 and latest.node == "N1"
    assert latest.finish == pytest.approx(35.0)


def test_hds_makespan_39s_and_allocation():
    s = schedule_hds(example1_instance())
    assert s.makespan == pytest.approx(PAPER_MAKESPAN["HDS"])
    alloc = {n: {a.tid for a in q} for n, q in s.by_node().items()}
    assert alloc == PAPER_HDS_ALLOC


def test_bar_makespan_38s_moves_tk9():
    s = schedule_bar(example1_instance())
    assert s.makespan == pytest.approx(PAPER_MAKESPAN["BAR"])
    a9 = next(a for a in s.assignments if a.tid == 9)
    assert a9.node == "N3" and a9.finish == pytest.approx(38.0)


def test_prebass_makespan_34s_last_is_tk8():
    s = schedule_prebass(example1_instance())
    assert s.makespan == pytest.approx(PAPER_MAKESPAN["Pre-BASS"])
    assert s.latest().tid == 8
    a1 = next(a for a in s.assignments if a.tid == 1)
    assert a1.transfer.slots == (1, 2, 3, 4, 5)             # TS1..TS5
    # node N1 finishes at 32 s (paper: "reduced from 35s to 32s")
    n1_finish = max(a.finish for a in s.assignments if a.node == "N1")
    assert n1_finish == pytest.approx(32.0)


@pytest.mark.parametrize(
    "scheduler", [schedule_bass, schedule_hds, schedule_bar, schedule_prebass]
)
def test_schedules_replay_cleanly(scheduler):
    inst = example1_instance()
    rep = replay(inst, scheduler(inst))
    assert rep.ok, rep.violations


def test_paper_ordering():
    ms = {
        name: fn(example1_instance()).makespan
        for name, fn in [
            ("BASS", schedule_bass),
            ("BAR", schedule_bar),
            ("HDS", schedule_hds),
            ("Pre-BASS", schedule_prebass),
        ]
    }
    assert ms["Pre-BASS"] < ms["BASS"] < ms["BAR"] < ms["HDS"]
