"""The a2a expert-parallel MoE must match the gather oracle bit-for-bit
(capacity high enough that neither impl drops tokens).

Multi-device semantics (the actual all-to-alls) need >1 device, so the
test runs in a subprocess with 8 forced host devices — the parent process
must keep its single-device view for every other test.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import _make_mesh
    from repro.models.moe import moe_block, moe_defs
    from repro.models.params import init_params
    from repro.distributed.actctx import activation_sharding

    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)  # E=8, top-2
    mesh = _make_mesh((2, 4), ("data", "model"))
    p = init_params(moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)

    hi = cfg.with_(capacity_factor=8.0)   # no drops on either path
    y_ref, aux_ref = jax.jit(
        lambda p, x: moe_block(p, x, hi.with_(moe_impl="gather"))
    )(p, x)
    rules = {"batch": ("data",), "seq": "model"}
    with mesh, activation_sharding(mesh, rules):
        y_a2a, aux_a2a = jax.jit(
            lambda p, x: moe_block(p, x, hi.with_(moe_impl="a2a"))
        )(p, x)
    err = float(jnp.max(jnp.abs(y_ref - y_a2a)))
    aerr = abs(float(aux_ref) - float(aux_a2a))
    assert err < 1e-4, ("y mismatch", err)
    assert aerr < 1e-4, ("aux mismatch", aerr)

    # and with realistic capacity, outputs stay finite + mostly nonzero
    lo = cfg.with_(capacity_factor=1.25, moe_impl="a2a")
    with mesh, activation_sharding(mesh, rules):
        y2, aux2 = jax.jit(lambda p, x: moe_block(p, x, lo))(p, x)
    assert bool(jnp.isfinite(y2).all()) and bool(jnp.isfinite(aux2))
    print("A2A_OK")
    """
)


def test_a2a_matches_gather_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "A2A_OK" in out.stdout


def test_a2a_falls_back_without_mesh_context():
    """Outside an activation-sharding context the a2a config must silently
    use the gather path (smoke tests / single host)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.moe import moe_block, moe_defs
    from repro.models.params import init_params

    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).with_(moe_impl="a2a")
    p = init_params(moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, aux = moe_block(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(aux))
