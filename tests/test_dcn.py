"""Cross-pod DCN sync: TS-slot reservations, compression wire math, and the
shard_map all-reduce (multi-device semantics exercised in a subprocess)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.distributed.dcn import CrossPodSync

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_reserved_flows_serialize_on_trunk():
    sync = CrossPodSync(n_pods=2, hosts_per_pod=4, grad_bytes=100e9)
    f1 = sync.reserve_step(1, not_before=0.0)
    f2 = sync.reserve_step(2, not_before=0.0)
    # full-residue transfers: step 2's flow must wait for step 1's slots
    assert f2.plan.start >= f1.plan.end - 1e-9
    assert (sync.ledger.reserved <= 1.0 + 1e-6).all()


def test_compression_quarters_wire_bytes():
    a = CrossPodSync(n_pods=2, hosts_per_pod=4, grad_bytes=80e9, compress=False)
    b = CrossPodSync(n_pods=2, hosts_per_pod=4, grad_bytes=80e9, compress=True)
    assert a.wire_bytes() == pytest.approx(4.0 * b.wire_bytes())


def test_projected_sync_seconds_matches_ledger_bandwidth():
    sync = CrossPodSync(n_pods=2, hosts_per_pod=4, grad_bytes=100e9)
    t = sync.projected_sync_seconds()
    # 2·100 GB·(1/2) over a 400 GB/s trunk = 0.25 s
    assert t == pytest.approx(100e9 / 400e9, rel=1e-6)


CROSS_POD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.dcn import cross_pod_allreduce
    from repro.launch.mesh import _make_mesh

    mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))
    x = jnp.arange(16.0).reshape(4, 4)
    # replicate x but give each pod a different value via explicit put
    with mesh:
        y = jax.jit(lambda v: cross_pod_allreduce(v, mesh))(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2)  # psum over 2 pods

    with mesh:
        yc = jax.jit(lambda v: cross_pod_allreduce(v, mesh, compressed=True))(x)
    # int8 path: relative error bounded by block max / 127
    err = np.abs(np.asarray(yc) - np.asarray(x) * 2).max()
    assert err <= 2 * np.abs(x).max() / 127 + 1e-6, err
    print("DCN_OK")
    """
)


def test_cross_pod_allreduce_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", CROSS_POD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DCN_OK" in out.stdout
