"""Table-I reproduction properties (statistical, small sizes for CI speed)."""
import numpy as np
import pytest

from repro.core import SCHEDULERS
from repro.core.simulator import evaluate_mapreduce, replay
from repro.core.workloads import DATA_SIZES_MB, SORT, WORDCOUNT, make_instance


def _mean_jt(job, mb, scheduler, seeds=6):
    out = []
    for seed in range(seeds):
        inst, rtasks, shuf = make_instance(job, mb, seed=seed)
        m = evaluate_mapreduce(inst, scheduler, rtasks, shuf)
        out.append(m.jt)
    return float(np.mean(out))


@pytest.mark.parametrize("job", [WORDCOUNT, SORT], ids=["wordcount", "sort"])
@pytest.mark.parametrize("size", ["300M", "600M"])
def test_bass_beats_hds(job, size):
    """The paper's headline ordering: BASS < HDS on every row."""
    bass = _mean_jt(job, DATA_SIZES_MB[size], SCHEDULERS["bass"])
    hds = _mean_jt(job, DATA_SIZES_MB[size], SCHEDULERS["hds"])
    assert bass < hds


def test_bass_beats_bar_when_bandwidth_bound():
    """Sort (shuffle-heavy) at mid size: the regime where bandwidth
    awareness is the differentiator (§V.B)."""
    bass = _mean_jt(SORT, DATA_SIZES_MB["300M"], SCHEDULERS["bass"], seeds=8)
    bar = _mean_jt(SORT, DATA_SIZES_MB["300M"], SCHEDULERS["bar"], seeds=8)
    assert bass < bar


def test_locality_ratio_non_monotonic_insight():
    """§V.B: BASS may win with a *lower* locality ratio — verify LR is a
    recorded metric and at least one seed shows BASS winning with LR below
    HDS's (the paper's 600M Wordcount row)."""
    found = False
    for mbsize, bg in [(1024, 30.0), (600, 60.0)]:
        for seed in range(12):
            inst, rtasks, shuf = make_instance(WORDCOUNT, mbsize, seed=seed,
                                               background_load=bg)
            mb = evaluate_mapreduce(inst, SCHEDULERS["bass"], rtasks, shuf)
            inst, rtasks, shuf = make_instance(WORDCOUNT, mbsize, seed=seed,
                                               background_load=bg)
            mh = evaluate_mapreduce(inst, SCHEDULERS["hds"], rtasks, shuf)
            if mb.jt < mh.jt and mb.lr < mh.lr:
                found = True
                break
        if found:
            break
    assert found


def test_mapreduce_replay_clean():
    inst, rtasks, shuf = make_instance(SORT, 300, seed=1)
    sched = SCHEDULERS["bass"](inst)
    assert replay(inst, sched).ok
