"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEYS = jax.random.split(jax.random.PRNGKey(0), 8)


def _mk_qkv(b, s, nq, nkv, hd, dtype, sq=None):
    sq = s if sq is None else sq
    q = jax.random.normal(KEYS[0], (b, sq, nq, hd), dtype)
    k = jax.random.normal(KEYS[1], (b, s, nkv, hd), dtype)
    v = jax.random.normal(KEYS[2], (b, s, nkv, hd), dtype)
    return q, k, v


FLASH_CASES = [
    # (B, S, nq, nkv, hd, dtype)
    (2, 256, 4, 2, 64, jnp.float32),
    (1, 128, 8, 8, 128, jnp.float32),
    (2, 256, 6, 2, 64, jnp.bfloat16),
    (1, 512, 4, 4, 128, jnp.bfloat16),
    (1, 128, 14, 2, 64, jnp.float32),      # internvl2-like odd grouping
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
def test_flash_attention_matches_ref(case):
    b, s, nq, nkv, hd, dtype = case
    q, k, v = _mk_qkv(b, s, nq, nkv, hd, dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = jnp.swapaxes(
        ref.attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=True,
        ),
        1, 2,
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shape_invariance(block_q, block_k):
    q, k, v = _mk_qkv(1, 256, 4, 2, 64, jnp.float32)
    base = ops.flash_attention(q, k, v, causal=True, interpret=True)
    out = ops.flash_attention(
        q, k, v, causal=True, block_q=block_q, block_k=block_k, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-5)


DECODE_CASES = [
    (2, 512, 4, 2, 64, 137),
    (1, 1024, 8, 8, 128, 1023),
    (2, 256, 6, 2, 64, 0),          # first token
    (1, 512, 16, 16, 64, 300),
]


@pytest.mark.parametrize("case", DECODE_CASES, ids=str)
def test_flash_decode_matches_ref(case):
    b, s, nq, nkv, hd, pos = case
    q, k, v = _mk_qkv(b, s, nq, nkv, hd, jnp.float32, sq=1)
    out = ops.flash_decode(q, k, v, jnp.int32(pos), interpret=True)
    want = jnp.swapaxes(
        ref.decode_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            jnp.int32(pos),
        ),
        1, 2,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_decode_masks_stale_cache():
    """Entries beyond ``pos`` must not leak — poison them with huge values."""
    b, s, nq, nkv, hd, pos = 1, 256, 4, 4, 64, 63
    q, k, v = _mk_qkv(b, s, nq, nkv, hd, jnp.float32, sq=1)
    v = v.at[:, pos + 1 :].set(1e6)
    k = k.at[:, pos + 1 :].set(3.0)
    out = ops.flash_decode(q, k, v, jnp.int32(pos), interpret=True)
    assert float(jnp.abs(out).max()) < 1e3


MAMBA_CASES = [
    (2, 256, 128, 8),
    (1, 512, 256, 16),
    (2, 128, 512, 4),
]


@pytest.mark.parametrize("case", MAMBA_CASES, ids=str)
def test_mamba_scan_matches_ref(case):
    b, s, d_in, n = case
    x = jax.random.normal(KEYS[3], (b, s, d_in), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(KEYS[4], (b, s, d_in), jnp.float32))
    a = -jnp.exp(jax.random.normal(KEYS[5], (d_in, n), jnp.float32) * 0.5)
    bm = jax.random.normal(KEYS[6], (b, s, n), jnp.float32)
    cm = jax.random.normal(KEYS[7], (b, s, n), jnp.float32)
    out = ops.mamba_scan(x, dt, a, bm, cm, interpret=True)
    want = ref.mamba_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


@pytest.mark.parametrize("chunk", [64, 128, 256])
def test_mamba_chunk_invariance(chunk):
    """The chunked carry must be exact — changing the chunk size is a pure
    blocking decision, not a numerics decision."""
    b, s, d_in, n = 1, 256, 128, 8
    x = jax.random.normal(KEYS[3], (b, s, d_in), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(KEYS[4], (b, s, d_in), jnp.float32))
    a = -jnp.exp(jax.random.normal(KEYS[5], (d_in, n), jnp.float32) * 0.5)
    bm = jax.random.normal(KEYS[6], (b, s, n), jnp.float32)
    cm = jax.random.normal(KEYS[7], (b, s, n), jnp.float32)
    base = ref.mamba_scan_ref(x, dt, a, bm, cm)
    out = ops.mamba_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=2e-4)


def test_model_flash_path_matches_xla_path():
    """cfg.attn_impl='pallas' must agree with the XLA reference attention
    end-to-end through a real layer stack."""
    from repro.configs import get_config
    from repro.models.model import Model

    cfg = get_config("starcoder2-3b", smoke=True).with_(remat=False)
    key = jax.random.PRNGKey(0)
    tok = jax.random.randint(key, (2, 128), 0, cfg.vocab_size)

    model_x = Model(cfg.with_(attn_impl="xla"))
    params = model_x.init(key)
    lx, _ = model_x.loss(params, {"tokens": tok})
    model_p = Model(cfg.with_(attn_impl="pallas"))
    lp, _ = model_p.loss(params, {"tokens": tok})
    assert float(jnp.abs(lx - lp)) < 0.02, (float(lx), float(lp))
