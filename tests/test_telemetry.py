"""Telemetry plane: estimators, belief exactness, separation contract.

Three layers of guarantees (DESIGN.md §9):

* estimator math — EWMA blending / sliding-window counter differentiation
  converge to the true utilization on synthetic streams;
* zero-staleness exactness — with the instantaneous estimator
  (``alpha=1.0``) polled at ``t``, every :class:`BeliefState` query is
  *bit*-equal to the corresponding ledger query at ``t``;
* separation — attaching a monitor never changes an oracle schedule
  (byte-identical), ``telemetry=True`` without an attached monitor is an
  error, and a stale belief can misroute a task but the committed plan is
  always booked on the true ledger.
"""
import numpy as np
import pytest

from repro.core.controller import BassPolicy, ClusterController, PreBassPolicy
from repro.core.tasks import BackgroundFlow, Task
from repro.core.timeslot import TimeSlotLedger
from repro.core.topology import two_tier_fabric
from repro.net.telemetry import (
    BeliefState,
    EwmaEstimator,
    LinkStatsMonitor,
    WindowRateEstimator,
    make_estimator,
)

HOSTS = ["H0", "H1", "H2", "H3"]


def make_ledger(slot=1.0, horizon=64):
    return TimeSlotLedger(two_tier_fabric(2, 2, 100.0, 100.0), slot, horizon)


# ---------------------------------------------------------------- estimators
def test_ewma_first_sample_primes_exactly():
    est = EwmaEstimator(3, alpha=0.25)
    occ = np.array([0.2, 0.8, 0.5])
    est.update(0.0, occ, np.zeros(3))
    np.testing.assert_array_equal(est.utilization(), occ)


def test_ewma_converges_to_constant_signal():
    est = EwmaEstimator(2, alpha=0.5)
    est.update(0.0, np.zeros(2), np.zeros(2))
    target = np.array([0.9, 0.3])
    for k in range(1, 40):
        est.update(float(k), target, np.zeros(2))
    np.testing.assert_allclose(est.utilization(), target, atol=1e-9)


def test_ewma_alpha_one_is_last_sample_bitwise():
    est = EwmaEstimator(2, alpha=1.0)
    for k in range(5):
        occ = np.array([0.1 * k + 0.037, 1.0 - 0.2 * k / 7.0])
        est.update(float(k), occ, np.zeros(2))
        assert (est.utilization() == occ).all()  # bitwise, not approx


def test_ewma_rejects_bad_alpha():
    with pytest.raises(ValueError):
        EwmaEstimator(2, alpha=0.0)
    with pytest.raises(ValueError):
        EwmaEstimator(2, alpha=1.5)


def test_window_rate_recovers_constant_rate():
    cap = np.array([100.0, 100.0])
    est = WindowRateEstimator(2, cap, window=4.0)
    # counters advancing at 40 and 90 Mbit/s against 100 Mbps capacity
    rate = np.array([40.0, 90.0])
    for k in range(10):
        est.update(float(k), np.zeros(2), rate * k)
    np.testing.assert_allclose(est.utilization(), rate / cap, atol=1e-12)


def test_window_rate_clips_and_falls_back_cold():
    cap = np.array([100.0])
    est = WindowRateEstimator(1, cap, window=2.0)
    occ = np.array([0.4])
    est.update(0.0, occ, np.zeros(1))
    # one sample: falls back to instantaneous occupancy
    np.testing.assert_array_equal(est.utilization(), occ)
    # counter jump far above capacity*dt clips to 1.0
    est.update(1.0, occ, np.array([1e6]))
    assert est.utilization()[0] == 1.0


def test_window_rate_evicts_old_samples():
    cap = np.array([100.0])
    est = WindowRateEstimator(1, cap, window=2.0)
    # 0..4: rate 100; 5..9: rate 0.  A 2 s window must forget the burst.
    for k in range(5):
        est.update(float(k), np.zeros(1), np.array([100.0 * k]))
    for k in range(5, 10):
        est.update(float(k), np.zeros(1), np.array([400.0]))
    assert est.utilization()[0] == 0.0


def test_make_estimator_rejects_unknown():
    with pytest.raises(ValueError):
        make_estimator("kalman", 2, np.ones(2))


# ----------------------------------------------------- zero-staleness limit
def _booked_ledger():
    led = make_ledger()
    for src, dst, size, nb in [
        ("H0", "H2", 180.0, 0.0),
        ("H1", "H3", 90.0, 1.0),
        ("H0", "H3", 250.0, 2.0),
    ]:
        rows = led.rows(led.fabric.path(src, dst))
        led.commit(led.plan_transfer(size, rows, not_before=nb))
    return led


@pytest.mark.parametrize("t", [0.0, 0.5, 1.0, 2.75, 3.0])
def test_belief_bit_equals_ledger_at_poll_instant(t):
    led = _booked_ledger()
    mon = LinkStatsMonitor(led, poll_interval=1.0, estimator="ewma", alpha=1.0)
    belief = mon.poll(t)
    paths = [
        led.rows(led.fabric.path(a, b))
        for a in HOSTS
        for b in HOSTS
        if a != b
    ]
    slot = led.slot_of(t)
    for rows in paths:
        assert belief.residual_fraction(rows, slot) == led.residual_fraction(
            rows, slot
        )
        assert belief.path_bandwidth(rows, t) == led.path_bandwidth(rows, t)
        # window inside the polled slot: flat belief == true window min
        t1 = (slot + 1) * led.slot_duration
        assert belief.min_path_bandwidth(rows, t, t1) == led.min_path_bandwidth(
            rows, t, t1
        )
    got = belief.path_bandwidth_batch(paths, t)
    want = led.path_bandwidth_batch(paths, t)
    assert (got == want).all()


def test_belief_empty_path_edge_semantics():
    belief = BeliefState(np.array([100.0, 50.0]))
    belief.util[:] = [0.3, 0.9]
    assert belief.residual_fraction([], 0) == 1.0
    assert belief.path_bandwidth([], 0.0) == float("inf")
    out = belief.path_bandwidth_batch([[], [1]], 0.0)
    assert out[0] == float("inf")
    assert out[1] == pytest.approx((1 - 0.9) * 50.0)


# ------------------------------------------------------- counter synthesis
def test_monitor_integrates_reserved_bytes():
    led = _booked_ledger()
    mon = LinkStatsMonitor(led, poll_interval=1.0)
    mon.poll(0.0)
    assert (mon.cum_bytes == 0).all()
    t = 2.5
    mon.poll(t)
    # independent integral of reserved × capacity over [0, 2.5)
    want = (
        led.reserved[:, 0] + led.reserved[:, 1] + 0.5 * led.reserved[:, 2]
    ) * led.capacity
    np.testing.assert_allclose(mon.cum_bytes, want, atol=1e-9)
    assert mon.stats["missed_slots"] == 0


def test_monitor_counts_retired_slots_as_missed():
    led = _booked_ledger()
    mon = LinkStatsMonitor(led, poll_interval=1.0)
    mon.poll(0.0)
    led.retire(3.0)  # drops slots 0-2 before the monitor sampled them
    mon.poll(4.0)
    assert mon.stats["missed_slots"] >= 1


def test_monitor_rejects_bad_poll_interval():
    with pytest.raises(ValueError):
        LinkStatsMonitor(make_ledger(), poll_interval=0.0)


# ------------------------------------------------------ separation contract
def _mini_stream(policy, attach=None):
    ctrl = ClusterController(
        two_tier_fabric(2, 3), [f"H{i}" for i in range(6)], policy
    )
    if attach:
        ctrl.attach_telemetry(poll_interval=attach)
    rng = np.random.default_rng(3)
    tid = 0
    for j in range(3):
        tasks = []
        for _ in range(5):
            reps = tuple(rng.choice([f"H{i}" for i in range(3)], 2, replace=False))
            tasks.append(Task(tid, float(rng.integers(50, 300)), 2.0, reps))
            tid += 1
        ctrl.submit(tasks, at=j * 4.0)
    ctrl.inject_flow(BackgroundFlow("H0", "H4", 0.6, 1.0, 9.0))
    ctrl.run()
    return ctrl.schedule().assignments


def _canon(assignments):
    return [
        (a.tid, a.node, a.source, a.start.hex(), a.finish.hex())
        for a in sorted(assignments, key=lambda a: a.tid)
    ]


def test_monitor_attach_is_schedule_neutral():
    plain = _mini_stream(BassPolicy())
    monitored = _mini_stream(BassPolicy(), attach=0.5)
    assert _canon(plain) == _canon(monitored)


def test_telemetry_policy_without_monitor_raises():
    # the replica holder must be busy so the remote-vs-local tradeoff
    # (the path that consults the belief) actually fires
    ctrl = ClusterController(
        two_tier_fabric(2, 2),
        HOSTS,
        BassPolicy(telemetry=True),
        idle={"H0": 10.0},
    )
    ctrl.submit([Task(0, 100.0, 1.0, ("H0",))], at=0.0)
    with pytest.raises(RuntimeError, match="telemetry"):
        ctrl.run()


def test_prebass_telemetry_smoke():
    ctrl = ClusterController(
        two_tier_fabric(2, 2), HOSTS, PreBassPolicy(telemetry=True)
    )
    ctrl.attach_telemetry(poll_interval=1.0)
    ctrl.submit([Task(i, 120.0, 1.0, ("H0", "H1")) for i in range(4)], at=0.0)
    ctrl.run()
    assert len(ctrl.schedule().assignments) == 4


# The deterministic staleness probe (also a bench row): truth keeps the
# task local on its busy replica holder; a belief last polled before a
# saturating flow started confidently offloads into the congested trunk —
# and because commits always book the *true* ledger, the realized plan
# crawls at the 5% residual instead of corrupting data-plane state.
def _probe_finish(telemetry, poll_interval, **est_kwargs):
    ctrl = ClusterController(
        two_tier_fabric(2, 2),
        HOSTS,
        BassPolicy(telemetry=telemetry),
        idle={"H0": 10.0, "H1": 10.0, "H2": 10.0, "H3": 0.0},
    )
    ctrl.attach_telemetry(poll_interval=poll_interval, **est_kwargs)
    ctrl.inject_flow(BackgroundFlow("H0", "H2", 0.95, 0.5, 50.0))
    ctrl.submit([Task(0, 200.0, 3.0, ("H0",))], at=1.0)
    ctrl.run()
    (a,) = ctrl.schedule().assignments
    return a


def test_stale_belief_misroutes_but_commits_true_plan():
    oracle = _probe_finish(False, 100.0)
    assert oracle.node == "H0" and oracle.transfer is None
    assert oracle.finish == pytest.approx(13.0)

    stale = _probe_finish(True, 100.0)
    assert stale.node == "H3" and stale.transfer is not None
    # planned on the true ledger: 200 Mbit at the 5 Mbps residual ≈ 40 s
    assert stale.finish == pytest.approx(44.0, abs=0.5)
    assert stale.finish > oracle.finish + 10.0


def test_fresh_instantaneous_belief_matches_oracle():
    oracle = _probe_finish(False, 100.0)
    fresh = _probe_finish(True, 0.25, alpha=1.0)
    assert fresh.node == oracle.node == "H0"
    assert fresh.finish == oracle.finish


def test_telemetry_snapshot_section():
    ctrl = ClusterController(two_tier_fabric(2, 2), HOSTS, BassPolicy())
    mon = ctrl.attach_telemetry(poll_interval=1.0)
    ctrl.submit([Task(0, 100.0, 1.0, ("H0", "H1"))], at=0.0)
    ctrl.run()
    with pytest.raises(RuntimeError):
        ctrl.attach_telemetry()  # double attach is an error
    snap = ctrl.obs.snapshot()
    tel = snap["telemetry"]
    assert tel["polls"] == mon.stats["polls"] >= 1
    assert tel["estimator"] == "ewma"
    assert snap["counters"]["telemetry.polls"] == tel["polls"]
