"""Hypothesis properties over random scheduling instances.

The independent discrete-event replayer (``core.simulator.replay``) is the
oracle: whatever any scheduler emits must replay without violations (node
exclusivity, transfer-before-compute, no link over-booking) and with
matching completion times.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SCHEDULERS
from repro.core.simulator import replay
from repro.core.tasks import BackgroundFlow, Instance, Task
from repro.core.topology import two_tier_fabric


@st.composite
def instances(draw):
    n_hosts = draw(st.integers(3, 8))
    n_tasks = draw(st.integers(1, 15))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    hosts_per_leaf = (n_hosts + 1) // 2
    fab = two_tier_fabric(2, hosts_per_leaf, 100.0, 100.0)
    hosts = [f"H{i}" for i in range(2 * hosts_per_leaf)][:n_hosts]
    tasks = [
        Task(
            tid=i + 1,
            size=float(rng.uniform(50, 600)),
            compute=float(rng.uniform(1, 20)),
            replicas=tuple(rng.choice(hosts, size=min(2, n_hosts), replace=False)),
        )
        for i in range(n_tasks)
    ]
    idle = {h: float(rng.uniform(0, 30)) for h in hosts}
    bg = []
    if draw(st.booleans()):
        for _ in range(draw(st.integers(1, 4))):
            a, b = rng.choice(hosts, 2, replace=False)
            t0 = float(rng.uniform(0, 30))
            bg.append(BackgroundFlow(str(a), str(b), float(rng.uniform(0.2, 0.8)),
                                     t0, t0 + float(rng.uniform(2, 10))))
    return Instance(fabric=fab, workers=hosts, idle=idle, tasks=tasks,
                    slot_duration=1.0, background=bg)


@pytest.mark.parametrize("name", list(SCHEDULERS))
@given(inst=instances())
@settings(max_examples=25, deadline=None)
def test_replay_clean_all_schedulers(name, inst):
    sched = SCHEDULERS[name](inst)
    rep = replay(inst, sched)
    assert rep.ok, (name, rep.violations)
    # every task exactly once
    tids = sorted(a.tid for a in sched.assignments)
    assert tids == sorted(t.tid for t in inst.tasks)


@given(inst=instances())
@settings(max_examples=25, deadline=None)
def test_bass_local_tasks_have_no_transfer(inst):
    s = SCHEDULERS["bass"](inst)
    for a in s.assignments:
        task = next(t for t in inst.tasks if t.tid == a.tid)
        if a.source is None:
            assert a.node in task.replicas
            assert a.transfer is None
        else:
            assert a.source in task.replicas
            assert a.transfer is not None
            # compute never starts before the transfer completes (Eq. 2-3)
            assert a.start >= a.transfer.end - 1e-9


@given(inst=instances())
@settings(max_examples=25, deadline=None)
def test_bass_remote_moves_beat_local_option(inst):
    """Case 1.2: a remote assignment must strictly beat the local ΥC the
    scheduler saw at decision time — verified ex post: finish < idle-free
    local bound is unverifiable after mutation, so we check the invariant
    the paper states: remote ⇒ ΥC = ΥI_minnow + TM + TP."""
    s = SCHEDULERS["bass"](inst)
    tasks = {t.tid: t for t in inst.tasks}
    for a in s.assignments:
        if a.transfer is not None:
            assert a.finish == pytest.approx(
                a.start + tasks[a.tid].compute, rel=1e-9
            )


@given(inst=instances())
@settings(max_examples=15, deadline=None)
def test_prebass_never_worse_than_bass(inst):
    bass = SCHEDULERS["bass"](inst).makespan
    pre = SCHEDULERS["prebass"](inst).makespan
    assert pre <= bass + 1e-6
