"""Online controller: offline-wrapper equivalence, multi-job metrics
isolation, batched-ledger agreement, online replay, and node-role tags."""
import numpy as np
import pytest

from repro.core import POLICIES, SCHEDULERS
from repro.core.controller import ClusterController
from repro.core.examples_fig import example1_instance
from repro.core.simulator import replay_online
from repro.core.tasks import BackgroundFlow, Instance, Task
from repro.core.timeslot import TimeSlotLedger
from repro.core.topology import (
    paper_fig2_fabric,
    storage_hosts,
    tpu_dcn_fabric,
    two_tier_fabric,
)


def random_instance(seed: int) -> Instance:
    rng = np.random.default_rng(seed)
    n_hosts = int(rng.integers(3, 9))
    n_tasks = int(rng.integers(1, 16))
    hpl = (n_hosts + 1) // 2
    fab = two_tier_fabric(2, hpl, 100.0, 100.0)
    hosts = [f"H{i}" for i in range(2 * hpl)][:n_hosts]
    tasks = [
        Task(
            tid=i + 1,
            size=float(rng.uniform(50, 600)),
            compute=float(rng.uniform(1, 20)),
            replicas=tuple(rng.choice(hosts, size=min(2, n_hosts), replace=False)),
        )
        for i in range(n_tasks)
    ]
    idle = {h: float(rng.uniform(0, 30)) for h in hosts}
    bg = []
    if rng.random() < 0.5:
        for _ in range(int(rng.integers(1, 4))):
            a, b = rng.choice(hosts, 2, replace=False)
            t0 = float(rng.uniform(0, 30))
            bg.append(
                BackgroundFlow(
                    str(a), str(b), float(rng.uniform(0.2, 0.8)),
                    t0, t0 + float(rng.uniform(2, 10)),
                )
            )
    return Instance(
        fabric=fab, workers=hosts, idle=idle, tasks=tasks,
        slot_duration=1.0, background=bg,
    )


def assert_assignments_equal(got, want):
    assert len(got) == len(want)
    for a, b in zip(
        sorted(got, key=lambda a: a.tid), sorted(want, key=lambda a: a.tid)
    ):
        assert (a.tid, a.node, a.source) == (b.tid, b.node, b.source)
        assert a.start == b.start and a.finish == b.finish
        if b.transfer is None:
            assert a.transfer is None
        else:
            assert a.transfer == b.transfer


# ---------------------------------------------------------------------------
# Online-arrival equivalence: submit-everything-at-t=0 == offline wrapper
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(POLICIES))
def test_online_t0_matches_offline_example1(name):
    offline = SCHEDULERS[name](example1_instance())
    ctrl = ClusterController.from_instance(example1_instance(), name)
    ctrl.submit(example1_instance().tasks, at=0.0)
    ctrl.run()
    assert_assignments_equal(ctrl.schedule().assignments, offline.assignments)
    np.testing.assert_array_equal(
        ctrl.state.ledger.reserved, offline.ledger.reserved
    )


@pytest.mark.parametrize("name", list(POLICIES))
@pytest.mark.parametrize("seed", [0, 3, 11, 42])
def test_online_t0_matches_offline_random(name, seed):
    offline = SCHEDULERS[name](random_instance(seed))
    inst = random_instance(seed)
    ctrl = ClusterController.from_instance(inst, name)
    ctrl.submit(inst.tasks, at=0.0)
    ctrl.run()
    assert_assignments_equal(ctrl.schedule().assignments, offline.assignments)


# ---------------------------------------------------------------------------
# Multi-job streams
# ---------------------------------------------------------------------------


def _three_job_stream(seed=5):
    rng = np.random.default_rng(seed)
    fab = two_tier_fabric(2, 4, 100.0, 200.0)
    workers = storage_hosts(fab)
    jobs, tid = [], 1
    for j, at in enumerate([0.0, 15.0, 30.0]):
        tasks = []
        for _ in range(8):
            kind = "reduce" if (tid % 4 == 0) else "map"
            tasks.append(
                Task(
                    tid=tid,
                    size=float(rng.uniform(80, 400)),
                    compute=float(rng.uniform(2, 10)),
                    replicas=tuple(rng.choice(workers, 2, replace=False)),
                    kind=kind,
                )
            )
            tid += 1
        jobs.append((at, tasks))
    idle = {w: float(rng.uniform(0, 4.0)) for w in workers}
    return fab, workers, idle, jobs


@pytest.mark.parametrize("name", list(POLICIES))
def test_online_stream_with_metrics_and_replay(name):
    fab, workers, idle, jobs = _three_job_stream()
    ctrl = ClusterController(fab, workers, name, idle=idle)
    jids = [ctrl.submit(tasks, at=at) for at, tasks in jobs]
    ctrl.inject_flow(BackgroundFlow(workers[0], workers[-1], 0.6, 5.0, 20.0))
    ctrl.run()

    # Per-job metrics are relative to each job's own arrival.
    for jid, (at, tasks) in zip(jids, jobs):
        m = ctrl.job_metrics(jid)
        assert m.jt >= 0.0 and 0.0 <= m.lr <= 1.0
        assert m.jt == pytest.approx(m.mt + m.rt)
        assert ctrl.jobs[jid].makespan >= at
        for a in ctrl.jobs[jid].assignments:
            # no task starts — and no transfer delivers — before arrival
            assert a.start >= at - 1e-9
            if a.transfer is not None and a.transfer.slot_fracs:
                assert a.transfer.start >= at - 1e-9

    rep = replay_online(jobs, ctrl.schedule(), idle)
    assert rep.ok, rep.violations


def test_job_metrics_isolated_between_jobs():
    """Job A's recorded assignments and metrics are fixed at placement time:
    a later job arriving cannot rewrite them."""
    fab, workers, idle, jobs = _three_job_stream()
    ctrl = ClusterController(fab, workers, "bass", idle=idle)
    j0 = ctrl.submit(jobs[0][1], at=jobs[0][0])
    ctrl.run_until(10.0)
    m0 = ctrl.job_metrics(j0)
    frozen = [(a.tid, a.node, a.start, a.finish) for a in ctrl.jobs[j0].assignments]

    j1 = ctrl.submit(jobs[1][1], at=15.0)
    ctrl.run()
    assert [(a.tid, a.node, a.start, a.finish) for a in ctrl.jobs[j0].assignments] == frozen
    m0b = ctrl.job_metrics(j0)
    assert (m0.mt, m0.rt, m0.jt, m0.lr) == (m0b.mt, m0b.rt, m0b.jt, m0b.lr)
    # and the later job's metrics cover only its own tasks
    assert len(ctrl.jobs[j1].assignments) == len(jobs[1][1])


def test_online_arrival_clamps_idle():
    """A job arriving at t=50 on a long-idle cluster starts no earlier
    than t=50 (ΥI_j is clamped to the controller clock)."""
    inst = example1_instance()
    ctrl = ClusterController.from_instance(inst, "bass")
    ctrl.submit(inst.tasks, at=50.0)
    ctrl.run()
    for a in ctrl.schedule().assignments:
        assert a.start >= 50.0 - 1e-9


def test_events_fire_in_time_order():
    inst = example1_instance()
    ctrl = ClusterController.from_instance(inst, "bass")
    tasks = inst.tasks
    ctrl.submit(tasks[:5], at=20.0)
    ctrl.submit(tasks[5:], at=0.0)      # earlier despite later submission
    ctrl.run_until(10.0)
    assert ctrl.jobs[1].placed and not ctrl.jobs[0].placed
    ctrl.run()
    assert ctrl.jobs[0].placed


# ---------------------------------------------------------------------------
# Batched ledger planning
# ---------------------------------------------------------------------------


def _busy_ledger(seed=0):
    fab = two_tier_fabric(2, 3, 100.0, 60.0)
    led = TimeSlotLedger(fab, 1.0, 64)
    rng = np.random.default_rng(seed)
    hosts = [f"H{i}" for i in range(6)]
    for _ in range(10):
        a, b = rng.choice(hosts, 2, replace=False)
        rows = led.rows(fab.path(str(a), str(b)))
        plan = led.plan_transfer(
            float(rng.uniform(20, 400)), rows, not_before=float(rng.uniform(0, 10))
        )
        led.commit(plan)
    return fab, led, hosts


def test_plan_transfer_batch_matches_loop_deterministic():
    fab, led, hosts = _busy_ledger()
    dst = "H0"
    rows_list = [led.rows(fab.path(h, dst)) for h in hosts[1:]] + [()]
    for size in (1.0, 77.7, 512.0):
        for nb in (0.0, 0.4, 7.3):
            batch = led.plan_transfer_batch(size, rows_list, not_before=nb)
            for rows, plan in zip(rows_list, batch):
                assert plan == led.plan_transfer(size, rows, not_before=nb)


def test_path_bandwidth_batch_matches_loop():
    fab, led, hosts = _busy_ledger(3)
    dst = "H5"
    rows_list = [led.rows(fab.path(h, dst)) for h in hosts[:-1]]
    for t in (0.0, 2.5, 9.9):
        batch = led.path_bandwidth_batch(rows_list, t)
        for rows, bw in zip(rows_list, batch):
            assert bw == led.path_bandwidth(rows, t)


def test_plan_transfer_batch_property():
    """Hypothesis property: batch ≡ loop on random ledger states."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        size=st.floats(1.0, 900.0),
        nb=st.floats(0.0, 20.0),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=40, deadline=None)
    def inner(size, nb, seed):
        fab, led, hosts = _busy_ledger(seed)
        dst = hosts[seed % 6]
        rows_list = [led.rows(fab.path(h, dst)) for h in hosts if h != dst]
        batch = led.plan_transfer_batch(size, rows_list, not_before=nb)
        for rows, plan in zip(rows_list, batch):
            assert plan == led.plan_transfer(size, rows, not_before=nb)

    inner()


# ---------------------------------------------------------------------------
# Node-role tags
# ---------------------------------------------------------------------------


def test_builder_roles_are_explicit():
    f = paper_fig2_fabric()
    assert sorted(storage_hosts(f)) == ["N1", "N2", "N3", "N4"]
    assert f.role("SwA") == "switch" and f.role("Router") == "switch"
    assert f.role("Master") == "infra" and f.role("Controller") == "infra"

    f = two_tier_fabric(2, 3)
    assert sorted(storage_hosts(f)) == [f"H{i}" for i in range(6)]
    assert f.role("Sw0") == "switch" and f.role("Spine") == "switch"

    f = tpu_dcn_fabric(2, 2)
    assert sorted(storage_hosts(f)) == [
        "pod0/host0", "pod0/host1", "pod1/host0", "pod1/host1"
    ]
    assert f.role("pod0/agg") == "switch" and f.role("dcn-core") == "switch"


def test_role_validation_and_retag():
    from repro.core.topology import Fabric

    f = Fabric()
    with pytest.raises(ValueError):
        f.add_node("x", role="router")
    f.add_uplink("l0", "h0", "sw", 10.0)
    assert f.role("h0") == "host" and f.role("sw") == "switch"
    f.add_node("h0", role="infra")      # explicit re-tag wins
    assert storage_hosts(f) == []
