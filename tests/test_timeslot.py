"""Property tests for the Time-Slot ledger (paper §IV.A invariants)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.timeslot import TimeSlotLedger
from repro.core.topology import paper_fig2_fabric, two_tier_fabric


def make_ledger(slot=1.0):
    return TimeSlotLedger(paper_fig2_fabric(100.0), slot, 64)


@given(
    size=st.floats(1.0, 2000.0),
    not_before=st.floats(0.0, 50.0),
)
@settings(max_examples=60, deadline=None)
def test_plan_delivers_exactly_size(size, not_before):
    led = make_ledger()
    rows = led.rows(led.fabric.path("N2", "N1"))
    plan = led.plan_transfer(size, rows, not_before=not_before)
    # End time implies delivered bytes = size at 100 Mbps residue.
    assert plan.end - plan.start == pytest.approx(size / 100.0, rel=1e-6)
    assert plan.start >= not_before - 1e-9


@given(
    sizes=st.lists(st.floats(10.0, 800.0), min_size=1, max_size=12),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_never_overbooked(sizes, seed):
    rng = np.random.default_rng(seed)
    fab = two_tier_fabric(2, 3, 100.0, 100.0)
    led = TimeSlotLedger(fab, 1.0, 64)
    hosts = [f"H{i}" for i in range(6)]
    for size in sizes:
        a, b = rng.choice(hosts, 2, replace=False)
        rows = led.rows(fab.path(str(a), str(b)))
        plan = led.plan_transfer(size, rows, not_before=float(rng.uniform(0, 20)))
        led.commit(plan)
    assert (led.reserved <= 1.0 + 1e-6).all()


@given(size=st.floats(10.0, 500.0), nb=st.floats(0.0, 10.0))
@settings(max_examples=40, deadline=None)
def test_commit_release_roundtrip(size, nb):
    led = make_ledger()
    rows = led.rows(led.fabric.path("N3", "N4"))
    before = led.reserved.copy()
    plan = led.plan_transfer(size, rows, not_before=nb)
    led.commit(plan)
    led.release(plan)
    n = before.shape[1]
    np.testing.assert_allclose(led.reserved[:, :n], before, atol=1e-12)
    assert (led.reserved[:, n:] == 0).all()  # growth area untouched


def test_second_transfer_waits_for_residue():
    led = make_ledger()
    rows = led.rows(led.fabric.path("N2", "N1"))
    p1 = led.plan_transfer(500.0, rows, not_before=0.0)   # occupies 0..5 s
    led.commit(p1)
    p2 = led.plan_transfer(500.0, rows, not_before=0.0)
    assert p2.start >= p1.end - 1e-6                       # full residue taken
    led.commit(p2)
    assert (led.reserved <= 1.0 + 1e-6).all()


def test_partial_residue_shares_bandwidth():
    led = make_ledger()
    rows = led.rows(led.fabric.path("N2", "N1"))
    # Manually book 50% of slots 0..9 on Link1.
    r1 = led.rows(["Link1"])
    led.reserved[list(r1), 0:10] = 0.5
    plan = led.plan_transfer(100.0, rows, not_before=0.0)
    # 50 Mbps residue → 2 s for 100 Mbit.
    assert plan.end == pytest.approx(2.0)


def test_path_bandwidth_is_min_over_links():
    fab = two_tier_fabric(2, 2, host_mbps=100.0, trunk_mbps=40.0)
    led = TimeSlotLedger(fab, 1.0, 16)
    rows = led.rows(fab.path("H0", "H2"))   # crosses the 40 Mbps trunk
    assert led.path_bandwidth(rows, 0.0) == pytest.approx(40.0)


@given(
    frac=st.floats(0.05, 0.95),
    size=st.floats(10.0, 300.0),
)
@settings(max_examples=30, deadline=None)
def test_earliest_window_respects_deadline(frac, size):
    led = make_ledger()
    rows = led.rows(led.fabric.path("N2", "N1"))
    led.reserved[list(rows), 0:4] = frac
    tm_full = size / 100.0
    plan = led.earliest_window(rows, size, 0.0, deadline=tm_full * 0.5)
    if plan is not None:
        assert plan.end <= tm_full * 0.5 + 1e-9
