"""SDN data-plane tests: k-shortest paths, flow tables, failure rerouting.

Covers the acceptance scenarios of the ``repro.net`` subsystem:

* k=1 routing is byte-identical to ``Fabric.path`` on the Fig. 2 testbed
  and leaf/spine builders;
* ECMP spread on a k=4 fat-tree (sequential transfers fan out over the
  equal-cost core paths);
* path-cache staleness regression (``add_link`` after a ``path()`` query);
* ``release_after`` / ``plan_bytes`` partial-release invariants;
* fail-link mid-transfer → the job still completes (later, finite);
* fail-all-paths → explicit ``UnroutableError``;
* router / DCN consumers survive injected failures.
"""
import numpy as np
import pytest

from repro.core.controller import BassPolicy, ClusterController, HdsPolicy
from repro.core.simulator import replay_online
from repro.core.tasks import Task
from repro.core.timeslot import TimeSlotLedger
from repro.core.topology import (
    Fabric,
    UnroutableError,
    paper_fig2_fabric,
    storage_hosts,
    two_tier_fabric,
)
from repro.net import (
    DataPlane,
    FlowTables,
    LinkDown,
    PathEngine,
    fat_tree_fabric,
    k_shortest_paths,
    oversubscribed_leaf_spine,
)


# ---------------------------------------------------------------------------
# k-shortest paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "fab", [paper_fig2_fabric(), two_tier_fabric(3, 4)], ids=["fig2", "leafspine"]
)
def test_k1_byte_identical_to_fabric_path(fab):
    nodes = fab.nodes
    for a in nodes:
        for b in nodes:
            if a != b:
                assert k_shortest_paths(fab, a, b, 1) == (fab.path(a, b),)


def test_yen_candidates_fat_tree():
    ft = fat_tree_fabric(4)
    paths = k_shortest_paths(ft, "pod0/h0_0", "pod1/h0_0", 8)
    # (k/2)^2 = 4 equal-cost 6-hop inter-pod paths, then 8-hop detours.
    assert [len(p) for p in paths[:4]] == [6, 6, 6, 6]
    assert len(set(paths)) == len(paths)
    for p in paths:
        # each candidate is a real loop-free src→dst walk
        nodes = ft.path_nodes("pod0/h0_0", p)
        assert nodes[-1] == "pod1/h0_0"
        assert len(set(nodes)) == len(nodes)
    # lengths are non-decreasing (Yen pops candidates best-first)
    lens = [len(p) for p in paths]
    assert lens == sorted(lens)


def test_unroutable_raises():
    fab = Fabric()
    fab.add_node("A")
    fab.add_node("B")
    with pytest.raises(UnroutableError):
        k_shortest_paths(fab, "A", "B", 1)


def test_ecmp_spread_on_fat_tree():
    """Concurrent pod0→pod1 transfers fan out over all four core switches.

    The pairs differ (per-host uplinks are never the shared bottleneck);
    what they contend on is the edge→agg→core tier, and residue-driven
    path choice must spread them across distinct cores.
    """
    ft = fat_tree_fabric(4, link_mbps=100.0)
    ledger = TimeSlotLedger(ft, 1.0, 64)
    engine = PathEngine(ft, k=4)
    pairs = [(f"pod0/h{e}_{i}", f"pod1/h{e}_{i}") for e in (0, 1) for i in (0, 1)]
    cores = []
    for src, dst in pairs:
        cands = engine.paths(src, dst)
        i = engine.best(ledger, cands, 0.0)
        plan = ledger.plan_transfer(400.0, ledger.rows(cands[i]), not_before=0.0)
        ledger.commit(plan)
        nodes = ft.path_nodes(src, cands[i])
        cores.append([n for n in nodes if n.startswith("core")][0])
    assert len(set(cores)) == 4  # all four cores carry one transfer each


def test_incidence_matrix_matches_rows():
    ft = fat_tree_fabric(4)
    ledger = TimeSlotLedger(ft, 1.0, 16)
    engine = PathEngine(ft, k=4)
    paths = engine.paths("pod0/h0_0", "pod3/h1_0")
    m = engine.incidence(ledger, paths)
    assert m.shape == (len(paths), len(ledger.capacity))
    for i, p in enumerate(paths):
        assert m[i].sum() == len(p)
        assert set(np.nonzero(m[i])[0]) == set(ledger.rows(p))


def test_path_engine_cache_invalidates_on_mutation():
    fab = Fabric()
    fab.add_uplink("l1", "A", "M", 100.0)
    fab.add_uplink("l2", "B", "M", 100.0)
    engine = PathEngine(fab, k=2)
    assert engine.paths("A", "B") == (("l1", "l2"),)
    fab.add_link("direct", "A", "B", 100.0)
    assert engine.paths("A", "B")[0] == ("direct",)


# ---------------------------------------------------------------------------
# Fabric staleness regression (satellite)
# ---------------------------------------------------------------------------


def test_fabric_path_cache_invalidated_by_add_link():
    fab = Fabric()
    fab.add_uplink("l1", "A", "M", 100.0)
    fab.add_uplink("l2", "B", "M", 100.0)
    # Query first: caches the 2-hop tree path.
    assert fab.path("A", "B") == ("l1", "l2")
    fab.add_link("direct", "A", "B", 100.0)
    # The shortcut must be visible — stale tree/LCA answers are the bug.
    assert fab.path("A", "B") == ("direct",)
    assert fab.path("B", "A") == ("direct",)


def test_fabric_version_counts_mutations():
    fab = Fabric()
    v0 = fab.version
    fab.add_uplink("l1", "A", "M", 100.0)
    assert fab.version > v0


# ---------------------------------------------------------------------------
# Flow tables
# ---------------------------------------------------------------------------


def test_flow_table_install_trace_uninstall():
    ft = fat_tree_fabric(4)
    tables = FlowTables(ft)
    path = k_shortest_paths(ft, "pod0/h0_0", "pod1/h1_0", 1)[0]
    rules = tables.install_path("xfer1", "pod0/h0_0", "pod1/h1_0", path)
    assert len(rules) == len(path)  # one rule per hop except the destination
    assert tables.trace("pod0/h0_0", "pod1/h1_0") == path
    # dump is per-node inspectable
    first_hop = ft.path_nodes("pod0/h0_0", path)[0]
    assert any(r.cookie == "xfer1" for r in tables.dump(first_hop))
    assert tables.uninstall("xfer1") == len(path)
    assert tables.n_rules() == 0
    with pytest.raises(LookupError):
        tables.trace("pod0/h0_0", "pod1/h1_0")


def test_flow_table_reroute_overrides_lookup():
    ft = fat_tree_fabric(4)
    tables = FlowTables(ft)
    src, dst = "pod0/h0_0", "pod1/h0_0"
    p1, p2 = k_shortest_paths(ft, src, dst, 2)
    tables.install_path("t", src, dst, p1)
    tables.uninstall("t")
    tables.install_path("t", src, dst, p2)
    assert tables.trace(src, dst) == p2


def test_controller_installs_and_expires_rules():
    fab = oversubscribed_leaf_spine(2, 2, 2)
    ctrl = ClusterController(fab, ["H2", "H3"], BassPolicy())
    ctrl.submit([Task(tid=1, size=500.0, compute=2.0, replicas=("H0",))], at=0.0)
    ctrl.run_until(0.0)
    assert ctrl.dataplane.tables.n_rules() > 0
    a = ctrl.jobs[0].assignments[0]
    # advancing the clock past the transfer's end garbage-collects its
    # rules — no trailing event required
    ctrl.run_until(a.transfer.end + 1.0)
    assert ctrl.dataplane.tables.n_rules() == 0


# ---------------------------------------------------------------------------
# Ledger: release / release_after (satellite)
# ---------------------------------------------------------------------------


def _contended_ledger():
    led = TimeSlotLedger(paper_fig2_fabric(100.0), 1.0, 64)
    rows = led.rows(led.fabric.path("N2", "N1"))
    led.reserved[list(rows), 2:5] = 0.35  # pre-existing contention
    return led, rows


def test_release_after_start_is_full_release():
    led, rows = _contended_ledger()
    before = led.reserved.copy()
    plan = led.plan_transfer(700.0, rows, not_before=0.5)
    led.commit(plan)
    kept = led.release_after(plan, plan.start)
    assert kept.slot_fracs == ()
    np.testing.assert_allclose(led.reserved, before, atol=1e-12)


def test_release_after_midway_conserves_bytes():
    led, rows = _contended_ledger()
    plan = led.plan_transfer(650.0, rows, not_before=0.0)
    led.commit(plan)
    total = led.plan_bytes(plan)
    assert total == pytest.approx(650.0, rel=1e-6)
    t_fail = (plan.start + plan.end) / 2.0
    kept = led.release_after(plan, t_fail)
    delivered = led.plan_bytes(kept)
    # Forfeit-boundary-slot semantics: delivered counts whole slots that
    # completed strictly before t_fail's slot.
    assert 0.0 <= delivered < total
    assert kept.end <= t_fail + 1e-9
    # Replanning the remainder then releasing both restores a clean matrix.
    rest = led.plan_transfer(total - delivered, rows, not_before=t_fail)
    led.commit(rest)
    assert led.plan_bytes(rest) == pytest.approx(total - delivered, rel=1e-6)
    led.release(rest)
    led.release_after(kept, 0.0)
    assert led.reserved[:, :2].max() == 0.0
    assert led.reserved[:, 5:].max() == 0.0


def test_release_after_past_end_is_noop():
    led, rows = _contended_ledger()
    plan = led.plan_transfer(300.0, rows, not_before=0.0)
    led.commit(plan)
    after = led.reserved.copy()
    assert led.release_after(plan, plan.end + 1.0) is plan
    np.testing.assert_allclose(led.reserved, after, atol=0)


try:
    from hypothesis import given, settings, strategies as st

    @given(size=st.floats(20.0, 900.0), nb=st.floats(0.0, 8.0),
           frac=st.floats(0.0, 0.7))
    @settings(max_examples=40, deadline=None)
    def test_commit_release_roundtrip_contended(size, nb, frac):
        """commit→release restores the reserved-fraction matrix exactly,
        including on ledgers carrying prior contention."""
        led = TimeSlotLedger(paper_fig2_fabric(100.0), 1.0, 64)
        rows = led.rows(led.fabric.path("N3", "N1"))
        led.reserved[list(rows), 1:6] = frac
        before = led.reserved.copy()
        plan = led.plan_transfer(size, rows, not_before=nb)
        led.commit(plan)
        led.release(plan)
        n = before.shape[1]
        np.testing.assert_allclose(led.reserved[:, :n], before, atol=1e-12)

    @given(size=st.floats(50.0, 900.0), t_frac=st.floats(0.0, 1.2))
    @settings(max_examples=40, deadline=None)
    def test_release_after_partitions_release(size, t_frac):
        """release_after(t) + release(kept) ≡ release(plan) for any t."""
        led = TimeSlotLedger(paper_fig2_fabric(100.0), 1.0, 64)
        rows = led.rows(led.fabric.path("N4", "N2"))
        before = led.reserved.copy()
        plan = led.plan_transfer(size, rows, not_before=0.0)
        led.commit(plan)
        t = plan.start + t_frac * (plan.end - plan.start)
        kept = led.release_after(plan, t)
        led.release(kept)
        n = before.shape[1]
        np.testing.assert_allclose(led.reserved[:, :n], before, atol=1e-12)

except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    pass


# ---------------------------------------------------------------------------
# Failure-aware rerouting through the controller
# ---------------------------------------------------------------------------


def _remote_job():
    """Two tasks whose replicas live on leaf 0, workers on leaf 1 — every
    placement needs a cross-spine transfer."""
    return [
        Task(tid=1, size=2000.0, compute=5.0, replicas=("H0",)),
        Task(tid=2, size=1500.0, compute=4.0, replicas=("H1",)),
    ]


def test_fail_link_mid_transfer_job_completes():
    fab = oversubscribed_leaf_spine(2, 2, 2, host_mbps=100.0, spine_mbps=100.0)
    baseline = ClusterController(fab, ["H2", "H3"], BassPolicy())
    baseline.submit(_remote_job(), at=0.0)
    baseline.run()
    base_mk = baseline.jobs[0].makespan

    ctrl = ClusterController(fab, ["H2", "H3"], BassPolicy())
    ctrl.submit(_remote_job(), at=0.0)
    ctrl.run_until(0.0)
    victim = ctrl.jobs[0].assignments[0]
    assert victim.transfer is not None
    t_fail = (victim.transfer.start + victim.transfer.end) / 2.0
    dead = ctrl.state.ledger.link_names(victim.transfer.links)[1]  # spine hop
    ctrl.fail_link(dead, at=t_fail)
    ctrl.run()

    rec = ctrl.jobs[0]
    assert rec.rerouted >= 1
    assert len(ctrl.reroute_log) >= 1
    assert np.isfinite(rec.makespan)
    assert rec.makespan >= base_mk - 1e-9  # failure can't speed the job up
    assert (ctrl.state.ledger.reserved <= 1.0 + 1e-6).all()
    # the rerouted plan avoids the dead link
    for a in rec.assignments:
        if a.transfer is not None and a.transfer.slot_fracs:
            assert dead not in ctrl.state.ledger.link_names(a.transfer.links)
    # replay oracle: recomputed timeline is causally consistent
    rep = replay_online([(0.0, _remote_job())], ctrl.schedule(),
                        {w: 0.0 for w in ["H2", "H3"]})
    assert rep.ok, rep.violations[:3]
    assert ctrl.job_metrics(0).rerouted == rec.rerouted


def test_fail_all_paths_raises_unroutable():
    fab = oversubscribed_leaf_spine(2, 2, 2, host_mbps=100.0, spine_mbps=100.0)
    ctrl = ClusterController(fab, ["H2", "H3"], BassPolicy())
    ctrl.submit(_remote_job(), at=0.0)
    ctrl.run_until(0.0)
    ctrl.fail_link("ls/L0S0", at=3.0)
    ctrl.fail_link("ls/L0S1", at=3.0)
    with pytest.raises(UnroutableError):
        ctrl.run()


def test_switch_failure_reroutes_and_recovers():
    fab = oversubscribed_leaf_spine(2, 2, 2, host_mbps=100.0, spine_mbps=100.0)
    ctrl = ClusterController(fab, ["H2", "H3"], BassPolicy(multipath=True))
    ctrl.submit(_remote_job(), at=0.0)
    ctrl.run_until(0.0)
    ctrl.fail_switch("Spine0", at=2.0)
    ctrl.recover_switch("Spine0", at=40.0)
    ctrl.run()
    rec = ctrl.jobs[0]
    assert np.isfinite(rec.makespan)
    assert not ctrl.dataplane.has_failures()
    for a in rec.assignments:
        if a.transfer is not None and a.transfer.slot_fracs:
            names = ctrl.state.ledger.link_names(a.transfer.links)
            # transfers planned/rerouted during the outage avoid Spine0
            if a.transfer.start >= 2.0 - 1e-9 and a.transfer.end <= 40.0:
                assert not any(n.endswith("S0") for n in names)


def test_fail_and_recover_validate_names():
    fab = oversubscribed_leaf_spine(2, 2, 2)
    ctrl = ClusterController(fab, ["H2", "H3"], BassPolicy())
    with pytest.raises(KeyError):
        ctrl.fail_link("no-such-link")
    with pytest.raises(KeyError):
        ctrl.recover_link("no-such-link")  # typo'd recovery must not no-op
    with pytest.raises(ValueError):
        ctrl.fail_switch("no-such-node")
    with pytest.raises(ValueError):
        ctrl.recover_switch("no-such-node")


def test_retime_respects_external_idle_estimates():
    """A reroute retime must not rewind starts that encoded ``set_idle``
    backlog estimates (the router feeds those in per request)."""
    fab = oversubscribed_leaf_spine(2, 2, 2)
    ctrl = ClusterController(fab, ["H0", "H3"], BassPolicy())
    ctrl.state.set_idle({"H0": 20.0, "H3": 30.0})
    # Local task on H0: committed start = the 20 s backlog estimate.
    ctrl.submit([Task(tid=1, size=0.0, compute=5.0, replicas=("H0",))], at=0.0)
    # Remote task: H2 (leaf 1, non-worker) → H0 crosses a spine link.
    ctrl.submit([Task(tid=2, size=800.0, compute=3.0, replicas=("H2",))], at=0.0)
    ctrl.run_until(0.0)
    a1 = ctrl.jobs[0].assignments[0]
    a2 = ctrl.jobs[1].assignments[0]
    assert a1.start == pytest.approx(20.0)
    spine = [n for n in ctrl.state.ledger.link_names(a2.transfer.links)
             if n.startswith("ls/")][0]
    ctrl.fail_link(spine, at=(a2.transfer.start + a2.transfer.end) / 2.0)
    ctrl.run()
    assert ctrl.jobs[0].rerouted == 0 and ctrl.jobs[1].rerouted == 1
    assert a1.start == pytest.approx(20.0)  # history not rewound


def test_inject_net_event_api():
    fab = oversubscribed_leaf_spine(2, 2, 2)
    ctrl = ClusterController(fab, ["H2", "H3"], BassPolicy())
    ctrl.submit(_remote_job(), at=0.0)
    ctrl.run_until(0.0)
    ctrl.inject_net(LinkDown("ls/L0S0", at=1.0))
    ctrl.run()
    assert "ls/L0S0" in ctrl.dataplane.dead_links


def test_multipath_bass_survives_random_failures_on_fat_tree():
    """BASS-multipath completes every job on a fat-tree with link churn."""
    ft = fat_tree_fabric(4, link_mbps=100.0)
    hosts = storage_hosts(ft)
    rng = np.random.default_rng(3)
    tasks = []
    for i in range(12):
        reps = tuple(rng.choice(hosts, size=2, replace=False))
        tasks.append(Task(tid=i + 1, size=float(rng.uniform(200, 900)),
                          compute=float(rng.uniform(2, 8)), replicas=reps))
    ctrl = ClusterController(ft, hosts, BassPolicy(multipath=True))
    ctrl.submit(tasks, at=0.0)
    # kill two switch-layer links mid-run (10%-ish churn on the core tier)
    ctrl.fail_link("ea/p0e0a0", at=4.0)
    ctrl.fail_link("ac/p1a0c0", at=6.0)
    ctrl.run()
    rec = ctrl.jobs[0]
    assert len(rec.assignments) == len(tasks)
    assert np.isfinite(rec.makespan)
    assert (ctrl.state.ledger.reserved <= 1.0 + 1e-6).all()


def test_multipath_equals_singlepath_without_diversity():
    """On a tree fabric (one path per pair) multipath BASS ≡ base BASS."""
    fab = two_tier_fabric(2, 3)
    hosts = storage_hosts(fab)
    rng = np.random.default_rng(0)
    tasks = [
        Task(tid=i + 1, size=float(rng.uniform(100, 500)),
             compute=float(rng.uniform(2, 9)),
             replicas=tuple(rng.choice(hosts, size=2, replace=False)))
        for i in range(10)
    ]
    a = ClusterController(fab, hosts, BassPolicy())
    b = ClusterController(fab, hosts, BassPolicy(multipath=True))
    for c in (a, b):
        c.submit(tasks, at=0.0)
        c.run()
    for x, y in zip(a.jobs[0].assignments, b.jobs[0].assignments):
        assert (x.tid, x.node, x.source, x.start, x.finish) == (
            y.tid, y.node, y.source, y.start, y.finish
        )


def test_prebass_routes_around_failures():
    """Pre-BASS's prefetch re-plan must not book dead links (its source
    choice is the state-level failure-aware one)."""
    from repro.core.controller import PreBassPolicy

    fab = oversubscribed_leaf_spine(2, 2, 2)
    ctrl = ClusterController(fab, ["H2", "H3"], PreBassPolicy())
    ctrl.fail_link("ls/L0S0", at=0.0)
    ctrl.submit(_remote_job(), at=1.0)
    ctrl.run()
    for a in ctrl.jobs[0].assignments:
        if a.transfer is not None and a.transfer.slot_fracs:
            names = ctrl.state.ledger.link_names(a.transfer.links)
            assert "ls/L0S0" not in names


def test_hds_routes_around_failures_too():
    """Bandwidth-oblivious policies must still not book dead links."""
    fab = oversubscribed_leaf_spine(2, 2, 2)
    ctrl = ClusterController(fab, ["H2", "H3"], HdsPolicy())
    ctrl.fail_link("ls/L0S0", at=0.0)
    ctrl.submit(_remote_job(), at=1.0)
    ctrl.run()
    for a in ctrl.jobs[0].assignments:
        if a.transfer is not None and a.transfer.slot_fracs:
            names = ctrl.state.ledger.link_names(a.transfer.links)
            assert "ls/L0S0" not in names


# ---------------------------------------------------------------------------
# Consumers survive injected failures
# ---------------------------------------------------------------------------


def test_router_survives_replica_nic_failure():
    from repro.serving.engine import Request
    from repro.serving.router import BassRouter

    router = BassRouter(["r0", "r1", "r2"])
    d0 = router.route(Request(rid=1, prompt="x" * 64, max_new=8,
                              prefix_hash=7), now=0.0)
    router.fail_link("nic0")  # r0's only link
    alive = {"r1", "r2"}
    for rid in range(2, 6):
        d = router.route(Request(rid=rid, prompt="y" * 32, max_new=8,
                                 prefix_hash=100 + rid), now=0.1 * rid)
        assert d.replica in alive
    router.recover_link("nic0")
    # r0 is eligible again once recovered
    router.backlog.update({"r1": 99.0, "r2": 99.0})
    d = router.route(Request(rid=9, prompt="z" * 32, max_new=8,
                             prefix_hash=999), now=1.0)
    assert d.replica == "r0"


def test_router_degrades_when_all_replicas_dead():
    # Revised contract (DESIGN.md §10): the router retries with sim-time
    # backoff and then degrades — committing nothing — instead of
    # propagating UnroutableError for a permanent all-dead partition.
    from repro.serving.engine import Request
    from repro.serving.router import BassRouter

    router = BassRouter(["r0", "r1"])
    router.fail_link("nic0")
    router.fail_link("nic1")
    d = router.route(Request(rid=1, prompt="x" * 16, max_new=4,
                             prefix_hash=1), now=0.0)
    assert d.degraded and d.ready_at == float("inf") and d.slots == ()


def test_dcn_sync_suspends_and_resumes_across_trunk_failure():
    from repro.distributed.dcn import CrossPodSync

    sync = CrossPodSync(n_pods=2, hosts_per_pod=4, grad_bytes=200e9)
    sync.register_steps(first_step=0, n_steps=3, cadence_s=1.0)
    sync.advance_to(0.0)
    plan0 = sync.flows[0].plan
    t_fail = (plan0.start + plan0.end) / 2.0
    sync.fail_link("pod0/trunk", at=t_fail)
    sync.advance_to(t_fail)
    # recovery: the suspended remainder is re-planned; later steps fire
    sync.recover_link("pod0/trunk", at=t_fail + 5.0)
    sync.advance_to(10.0)
    assert set(sync.flows) == {0, 1, 2}
    for f in sync.flows.values():
        assert np.isfinite(f.plan.end)
    assert sync.flows[0].plan.end >= t_fail + 5.0 - 1e-9  # resumed after outage
    assert (sync.ledger.reserved <= 1.0 + 1e-6).all()


# -- pluggable path-cost functions (PathEngine cost modes) -------------------


def test_path_engine_rejects_unknown_cost():
    ft = fat_tree_fabric(4)
    with pytest.raises(ValueError):
        PathEngine(ft, cost="latency")
    with pytest.raises(ValueError):
        PathEngine(ft, cost="residual")  # residual needs a ledger


def test_hop_cost_k1_is_fabric_path():
    """``cost="hop"`` (the default) at k=1 is ``Fabric.path`` verbatim —
    the historical identity every installed flow rule relies on."""
    ft = fat_tree_fabric(4)
    engine = PathEngine(ft, k=1)
    hosts = [n for n in sorted(ft.nodes) if ft.role(n) == "host"]
    for a in hosts[:4]:
        for b in hosts[-4:]:
            if a != b:
                assert engine.paths(a, b) == (ft.path(a, b),)


def test_ospf_cost_matches_hop_on_uniform_capacity():
    """On a uniform-capacity fabric every link costs the same, so the
    OSPF metric ranks paths exactly like hop count."""
    ft = fat_tree_fabric(4)  # single link_mbps everywhere
    hop = PathEngine(ft, k=4, cost="hop")
    ospf = PathEngine(ft, k=4, cost="ospf")
    for a, b in [("pod0/h0_0", "pod1/h1_0"), ("pod2/h0_1", "pod2/h1_0")]:
        assert hop.paths(a, b) == ospf.paths(a, b)


def test_ospf_cost_prefers_fat_links():
    """OSPF inverse-capacity cost takes a longer path over fat links when
    the short path is thin."""
    fab = Fabric()
    for n in ("S", "M", "T"):
        fab.add_node(n, "host" if n in ("S", "T") else "switch")
    fab.add_link("thin", "S", "T", 10.0)
    fab.add_link("fat1", "S", "M", 1000.0)
    fab.add_link("fat2", "M", "T", 1000.0)
    assert PathEngine(fab, k=1, cost="hop").paths("S", "T") == (("thin",),)
    # ref_bw = 1000: thin costs 100, the two-hop fat path costs 2
    assert PathEngine(fab, k=1, cost="ospf").paths("S", "T") \
        == (("fat1", "fat2"),)


def test_residual_cost_steers_around_booked_links():
    """``cost="residual"`` reads the live TS ledger at ``engine.at``: a
    heavily booked link gets expensive, so the engine steers around it —
    and the ranking changes back once the booking expires."""
    fab = Fabric()
    for n in ("S", "A", "B", "T"):
        fab.add_node(n, "host" if n in ("S", "T") else "switch")
    fab.add_link("sa", "S", "A", 100.0)
    fab.add_link("at", "A", "T", 100.0)
    fab.add_link("sb", "S", "B", 100.0)
    fab.add_link("bt", "B", "T", 100.0)
    ledger = TimeSlotLedger(fab, slot_duration=1.0, horizon_slots=32)
    engine = PathEngine(fab, k=1, cost="residual", ledger=ledger)
    # untouched ledger: residual == capacity everywhere, ranking == hop,
    # and hop's deterministic tie-break picks the A side
    first = engine.paths("S", "T")[0]
    assert first == ("sa", "at")
    # book 90 of 100 on "at" for t in [0, 4): the A side's bottleneck
    # residual drops to 10, the B side stays at 100
    plan = ledger.plan_transfer(90.0 * 4, ledger.rows(("at",)),
                                not_before=0.0, bandwidth_cap=90.0)
    ledger.commit(plan)
    engine.at = plan.start + 1e-6
    assert engine.paths("S", "T")[0] == ("sb", "bt")
    # after the booking drains, the A side wins again (no stale cache)
    engine.at = plan.end + 1.0
    assert engine.paths("S", "T")[0] == ("sa", "at")


def test_yen_fallback_honors_link_cost():
    """k>1 with bans exercises the Yen spur loop; the spur paths must be
    ranked by the plugged cost, not hop count."""
    fab = Fabric()
    for n in ("S", "M", "T"):
        fab.add_node(n, "host" if n in ("S", "T") else "switch")
    fab.add_link("thin", "S", "T", 10.0)
    fab.add_link("fat1", "S", "M", 1000.0)
    fab.add_link("fat2", "M", "T", 1000.0)
    ospf = PathEngine(fab, k=2, cost="ospf")
    assert ospf.paths("S", "T") == (("fat1", "fat2"), ("thin",))
