"""Hierarchical controller (core.hierarchy): pod partition, sharded
ledger float-exactness, flat-vs-sharded byte parity, pod-affine mode,
rebalancing, and per-shard WAL recovery (DESIGN.md §12)."""
import random

import numpy as np
import pytest

from repro.core.controller import ClusterController, ClusterState
from repro.core.hierarchy import HierarchicalController, HierarchicalState
from repro.core.simulator import replay_online
from repro.core.tasks import Task
from repro.core.timeslot import ShardedLedger, TimeSlotLedger
from repro.core.topology import storage_hosts, tpu_dcn_fabric
from repro.net.fattree import fat_tree_fabric, pod_partition


def _tasks(hosts, n, seed, tid0=0, in_pod=None):
    rng = random.Random(seed)
    pool = [h for h in hosts if in_pod is None or h.startswith(in_pod + "/")]
    return [
        Task(
            tid0 + i,
            size=rng.uniform(40, 400),
            compute=rng.uniform(1, 20),
            replicas=tuple(rng.sample(pool, min(3, len(pool)))),
        )
        for i in range(n)
    ]


def _stream(hosts, seed, n_jobs=6, spacing=3.0, in_pod=None):
    rng = random.Random(seed)
    return [
        (_tasks(hosts, rng.randint(1, 10), seed * 100 + i, tid0=i * 100,
                in_pod=in_pod), i * spacing)
        for i in range(n_jobs)
    ]


def _assert_same_schedule(sa, sb):
    assert len(sa.assignments) == len(sb.assignments)
    for a, b in zip(sa.assignments, sb.assignments):
        assert (a.tid, a.node, a.source, a.start, a.finish, a.bw_needed) == (
            b.tid, b.node, b.source, b.start, b.finish, b.bw_needed
        )
        ta, tb = a.transfer, b.transfer
        assert (ta is None) == (tb is None)
        if ta is not None:
            assert ta.links == tb.links
            assert ta.start == tb.start and ta.end == tb.end
            assert ta.slot_fracs == tb.slot_fracs


# -- pod partition ----------------------------------------------------------


def test_pod_partition_fat_tree_shard_contract():
    fab = fat_tree_fabric(4)
    part = pod_partition(fab)
    assert part.pods == ("pod0", "pod1", "pod2", "pod3")
    all_links = set(fab.links)
    seen = set()
    for p, links in part.pod_links.items():
        assert not (seen & set(links))  # pairwise disjoint
        seen |= set(links)
    assert not (seen & set(part.boundary_links))
    assert seen | set(part.boundary_links) == all_links  # covering
    # agg->core uplinks are exactly the boundary of a fat-tree
    assert all(l.startswith("ac/") for l in part.boundary_links)
    for p in part.pods:
        assert part.pod_hosts[p]
        for h in part.pod_hosts[p]:
            assert part.pod_of(h) == p
    groups = part.groups()
    assert set(groups) == set(part.pods) | {"__boundary__"}


def test_pod_partition_tpu_dcn():
    fab = tpu_dcn_fabric(n_pods=3, hosts_per_pod=4)
    part = pod_partition(fab)
    assert len(part.pods) == 3
    assert sum(len(v) for v in part.pod_hosts.values()) == 12


def test_pod_partition_rejects_flat_fabric():
    from repro.core.topology import two_tier_fabric

    with pytest.raises(ValueError):
        pod_partition(two_tier_fabric(2, 4))


# -- sharded ledger float-exactness ----------------------------------------


def test_sharded_ledger_matches_flat_under_random_traffic():
    fab = fat_tree_fabric(4)
    part = pod_partition(fab)
    hosts = storage_hosts(fab)
    flat = TimeSlotLedger(fab, slot_duration=1.0, horizon_slots=64)
    shard = ShardedLedger(fab, part.groups(), slot_duration=1.0,
                          horizon_slots=64)
    rng = random.Random(3)
    t = 0.0
    for i in range(120):
        src, dst = rng.sample(hosts, 2)
        rows_f = flat.path_rows(src, dst)
        rows_s = shard.path_rows(src, dst)
        assert rows_f == rows_s  # same global row numbering
        size = rng.uniform(10, 500)
        nb = t + rng.uniform(0.0, 8.0)
        pf = flat.plan_transfer(size, rows_f, not_before=nb)
        ps = shard.plan_transfer(size, rows_s, not_before=nb)
        assert pf.links == ps.links
        assert pf.start == ps.start and pf.end == ps.end
        assert pf.slot_fracs == ps.slot_fracs
        if rng.random() < 0.7:
            flat.commit(pf)
            shard.commit(ps)
            if rng.random() < 0.2:
                cut = pf.start + rng.random() * max(pf.end - pf.start, 1e-6)
                flat.release_after(pf, cut)
                shard.release_after(ps, cut)
        assert flat.path_bandwidth(rows_f, t) == shard.path_bandwidth(rows_s, t)
        if rng.random() < 0.3:
            t += rng.uniform(0.0, 3.0)
            flat.maybe_retire(t)
            shard.maybe_retire(t)
    # final sweep: every single link row reads identically at several times
    all_rows = [(flat.rows([l]), shard.rows([l])) for l in sorted(fab.links)]
    for probe in (t, t + 4.0, t + 16.0):
        for rf, rs in all_rows:
            assert rf == rs
            assert flat.path_bandwidth(rf, probe) \
                == shard.path_bandwidth(rs, probe)


def test_sharded_ledger_batch_and_min_path():
    fab = tpu_dcn_fabric(n_pods=3, hosts_per_pod=3)
    part = pod_partition(fab)
    hosts = storage_hosts(fab)
    flat = TimeSlotLedger(fab, 1.0, 64)
    shard = ShardedLedger(fab, part.groups(), 1.0, 64)
    rng = random.Random(5)
    rows_list = []
    for _ in range(12):
        src, dst = rng.sample(hosts, 2)
        rows = flat.path_rows(src, dst)
        rows_list.append(rows)
        p = flat.plan_transfer(rng.uniform(20, 200), rows, not_before=0.0)
        flat.commit(p)
        # facade rows == flat rows, so the same plan commits to both
        shard.commit(p)
    got = shard.path_bandwidth_batch(rows_list, 2.0)
    want = flat.path_bandwidth_batch(rows_list, 2.0)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    for rows in rows_list:
        assert np.array_equal(
            flat.min_path_bandwidth(rows, 0.0, 30.0),
            shard.min_path_bandwidth(rows, 0.0, 30.0),
        )


def test_sharded_ledger_reserved_materializes_flat_matrix():
    """The facade's read-only ``reserved`` (what the replay oracle's
    over-booking sweep reads) equals the flat matrix cell-for-cell over
    the shared window, zero-padded beyond each shard's live width."""
    fab = tpu_dcn_fabric(n_pods=3, hosts_per_pod=3)
    part = pod_partition(fab)
    hosts = storage_hosts(fab)
    flat = TimeSlotLedger(fab, 1.0, 64)
    shard = ShardedLedger(fab, part.groups(), 1.0, 64)
    rng = random.Random(17)
    for _ in range(20):
        src, dst = rng.sample(hosts, 2)
        p = flat.plan_transfer(rng.uniform(20, 200), flat.path_rows(src, dst),
                               not_before=0.0)
        flat.commit(p)
        shard.commit(p)
    got, want = shard.reserved, flat.reserved
    assert shard.base_slot == flat.base_slot
    w = min(got.shape[1], want.shape[1])
    assert np.array_equal(got[:, :w], want[:, :w])
    assert not got[:, w:].any() and not want[:, w:].any()


# -- exact-mode byte parity -------------------------------------------------


@pytest.mark.parametrize("fab_fn", [
    lambda: fat_tree_fabric(4),
    lambda: tpu_dcn_fabric(n_pods=4, hosts_per_pod=8),
])
def test_exact_mode_matches_flat_cross_pod(fab_fn):
    fab = fab_fn()
    hosts = storage_hosts(fab)
    flat = ClusterController(fab, hosts, "bass")
    hier = HierarchicalController(fab, hosts)
    for tasks, at in _stream(hosts, seed=11):
        flat.submit(tasks, at=at)
        hier.submit(tasks, at=at)
    flat.run()
    hier.run()
    _assert_same_schedule(flat.schedule(), hier.schedule())


@pytest.mark.parametrize("affinity", [False, True])
def test_replay_oracle_accepts_hierarchy_schedules(affinity):
    """The independent replay oracle (arrival causality, node exclusivity,
    over-booking via ``ledger.reserved``) validates sharded schedules in
    both modes — the facade's materialized matrix is what it sweeps."""
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    hier = HierarchicalController(fab, hosts, affinity=affinity)
    jobs = [(at, tasks) for tasks, at in _stream(hosts, seed=31)]
    for at, tasks in jobs:
        hier.submit(tasks, at=at)
    hier.run()
    report = replay_online(jobs, hier.schedule(), {h: 0.0 for h in hosts})
    assert report.ok, report.violations


def test_exact_mode_matches_flat_single_pod_workload():
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    flat = ClusterController(fab, hosts, "bass")
    hier = HierarchicalController(fab, hosts)
    for tasks, at in _stream(hosts, seed=23, in_pod="pod1"):
        flat.submit(tasks, at=at)
        hier.submit(tasks, at=at)
    flat.run()
    hier.run()
    # Exact mode is the *global* Algorithm-1 oracle: it may still migrate
    # out of pod1 (Case 1.2 against the global minnow) — the contract is
    # byte parity with flat, not pod locality (that's affine mode).
    _assert_same_schedule(flat.schedule(), hier.schedule())


def test_lazy_state_tracks_flat_state_exactly():
    """The lazy idle/minnow surface resolves the same values and argmin as
    the eagerly-clamped flat state under interleaved advances/commits."""
    fab = fat_tree_fabric(4)
    part = pod_partition(fab)
    hosts = storage_hosts(fab)
    from repro.obs import Registry

    flat = ClusterState(fab, hosts, slot_duration=1.0)
    lazy = HierarchicalState(
        fab, part, hosts, None,
        ShardedLedger(fab, part.groups(), 1.0, 256), Registry(),
    )
    rng = random.Random(9)
    t = 0.0
    for i in range(300):
        op = rng.random()
        if op < 0.3:
            t += rng.uniform(0.0, 2.0)
            flat.advance(t)
            lazy.advance(t)
        else:
            task = Task(i, size=50.0, compute=rng.uniform(0.0, 10.0),
                        replicas=(rng.choice(hosts),))
            node = rng.choice(hosts)
            af = flat.commit_local(task, node)
            al = lazy.commit_local(task, node)
            assert (af.start, af.finish) == (al.start, al.finish)
        assert flat.minnow() == lazy.minnow()
        for n in rng.sample(hosts, 5):
            assert flat.idle[n] == lazy.idle[n]


# -- pod-affine mode + rebalancer ------------------------------------------


def test_affine_mode_places_home_pod_local():
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    aff = HierarchicalController(fab, hosts, affinity=True)
    jobs = _stream(hosts, seed=31, in_pod="pod2")
    for tasks, at in jobs:
        aff.submit(tasks, at=at)
    aff.run()
    n = sum(len(tasks) for tasks, _ in jobs)
    s = aff.schedule()
    assert len(s.assignments) == n
    assert all(a.node.startswith("pod2/") for a in s.assignments)
    for rec in aff.jobs.values():
        for a in rec.assignments:
            assert a.start >= rec.submit_at - 1e-9
    # transfer plans are re-expressed in global facade rows
    for a in s.assignments:
        if a.transfer is not None and a.transfer.links:
            names = aff.ledger.link_names(a.transfer.links)
            assert all(n in fab.links for n in names)


def test_affine_mode_single_pod_matches_flat_over_pod():
    """A pod's state machine IS a flat controller over that pod's hosts:
    on a workload confined to pod0, affine placement matches a flat
    controller restricted to pod0's workers, byte for byte (the shard's
    plans re-expressed in global rows equal the flat ledger's)."""
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    pod0 = [h for h in hosts if h.startswith("pod0/")]
    flat = ClusterController(fab, pod0, "bass")
    aff = HierarchicalController(fab, hosts, affinity=True)
    for tasks, at in _stream(hosts, seed=37, in_pod="pod0"):
        flat.submit(tasks, at=at)
        aff.submit(tasks, at=at)
    flat.run()
    aff.run()
    _assert_same_schedule(flat.schedule(), aff.schedule())


def test_rebalancer_requires_affinity():
    fab = fat_tree_fabric(4)
    with pytest.raises(ValueError):
        HierarchicalController(fab, storage_hosts(fab),
                               rebalance_interval=1.0)


def test_rebalancer_rehomes_from_hot_pod():
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    aff = HierarchicalController(
        fab, hosts, affinity=True, rebalance_interval=2.0,
        rebalance_ratio=1.25,
    )
    # Hammer pod0 only: every job's replicas live there, so every task
    # homes to pod0 and the pod's backlog diverges from the others'.
    for i in range(12):
        aff.submit(_tasks(hosts, 8, seed=41 + i, tid0=i * 100,
                          in_pod="pod0"), at=i * 1.0)
    aff.run()
    checks = aff._stats["rebalance_checks"]
    assert checks >= 2
    assert aff._stats["rebalance_triggers"] >= 1
    assert aff._stats["rehomed"] > 0
    rehomed_nodes = [
        a.node
        for rec in aff.jobs.values()
        for a in rec.assignments
        if not a.node.startswith("pod0/")
    ]
    assert rehomed_nodes  # some work actually left the hot pod


def test_rebalancer_quiet_on_balanced_load():
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    aff = HierarchicalController(fab, hosts, affinity=True,
                                 rebalance_interval=2.0)
    for tasks, at in _stream(hosts, seed=53):
        aff.submit(tasks, at=at)
    aff.run()  # terminates: the rebalance tick is a chain event
    assert aff._stats["rehomed"] == 0 or aff._stats["rebalance_triggers"] > 0


# -- recovery ---------------------------------------------------------------


@pytest.mark.parametrize("affinity", [False, True])
def test_recovery_twin_is_byte_identical(affinity):
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    kw = dict(affinity=affinity)
    if affinity:
        kw["rebalance_interval"] = 3.0
    h1 = HierarchicalController(fab, hosts, **kw)
    jrn = h1.attach_journal()
    jobs = _stream(hosts, seed=61, n_jobs=8, spacing=2.0)
    for tasks, at in jobs[:4]:
        h1.submit(tasks, at=at)
    h1.run_until(5.0)
    snap = h1.snapshot()
    for tasks, at in jobs[4:]:
        h1.submit(tasks, at=at)
    h1.run()
    h2 = HierarchicalController.recover_from(fab, snap, jrn)
    _assert_same_schedule(h1.schedule(), h2.schedule())
    for name in h1.ledger.shards:
        assert (h1.ledger.shards[name].reserved
                == h2.ledger.shards[name].reserved).all()
        assert h1.ledger.shards[name].base_slot \
            == h2.ledger.shards[name].base_slot


def test_sharded_journal_segments_route_by_pod():
    from repro.core.journal import ShardedJournal

    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    aff = HierarchicalController(fab, hosts, affinity=True)
    jrn = aff.attach_journal()
    assert isinstance(jrn, ShardedJournal)
    aff.submit(_tasks(hosts, 3, seed=71, in_pod="pod0"), at=0.0)
    aff.submit(_tasks(hosts, 3, seed=72, tid0=100, in_pod="pod3"), at=1.0)
    aff.run()
    assert "pod0" in jrn.segments and "pod3" in jrn.segments
    assert ShardedJournal.ROOT in jrn.segments  # run() lands at the root
    lsns = [r.lsn for r in jrn.merged()]
    assert lsns == sorted(lsns) == list(range(len(lsns)))
    blob = jrn.to_bytes()
    back = ShardedJournal.from_bytes(blob)
    assert [r.lsn for r in back.merged()] == lsns


def test_journal_roundtrip_replay_without_snapshot():
    fab = tpu_dcn_fabric(n_pods=2, hosts_per_pod=4)
    hosts = storage_hosts(fab)
    h1 = HierarchicalController(fab, hosts)
    jrn = h1.attach_journal()
    for tasks, at in _stream(hosts, seed=83, n_jobs=4):
        h1.submit(tasks, at=at)
    h1.run()
    h2 = HierarchicalController(fab, hosts)
    for rec in jrn.merged():
        if rec.op == "submit":
            h2.submit(list(rec.args[2]), at=rec.args[0], jid=rec.args[1])
        elif rec.op == "run_until":
            h2.run_until(rec.args[0])
        elif rec.op == "run":
            h2.run()
    _assert_same_schedule(h1.schedule(), h2.schedule())


# -- guard rails ------------------------------------------------------------


def test_hierarchy_rejects_non_bass_policies():
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    with pytest.raises(ValueError):
        HierarchicalController(fab, hosts, policy="hds")
    from repro.core.controller import BassPolicy

    with pytest.raises(ValueError):
        HierarchicalController(fab, hosts, policy=BassPolicy(multipath=True))


def test_hierarchy_obs_provider_reports_pods():
    fab = fat_tree_fabric(4)
    hosts = storage_hosts(fab)
    hier = HierarchicalController(fab, hosts)
    hier.submit(_tasks(hosts, 5, seed=91), at=0.0)
    hier.run()
    snap = hier.obs.snapshot()
    assert snap["hierarchy"]["pods"] == 4
    assert snap["hierarchy"]["affinity"] == 0
    assert snap["counters"]["hier.tasks"] == 5
    pod_tasks = sum(
        v for k, v in snap["counters"].items()
        if k.startswith("pod.") and k.endswith(".tasks")
    )
    assert pod_tasks == 5
