"""Sharding rule unit tests + HLO collective-parser tests."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec

from repro.distributed.sharding import PARAM_RULES, spec_for
from repro.launch.hlo_analysis import (
    CollectiveReport,
    _wire_bytes,
    parse_collectives,
    roofline_terms,
)
from repro.launch.mesh import make_smoke_mesh


@pytest.fixture(scope="module")
def mesh():
    # single-device CPU mesh: shape (1, 1)
    return make_smoke_mesh()


def test_spec_divisibility_downgrade():
    import numpy as np

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = spec_for((14, 64), ("heads", "d_ff"), FakeMesh(), PARAM_RULES)
    # 14 heads not divisible by 16 → replicated; 64 d_ff divisible → model
    assert spec == PartitionSpec(None, "model")


def test_spec_axis_used_once():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = spec_for((64, 64), ("d_ff", "vocab"), FakeMesh(), PARAM_RULES)
    # both want "model"; only the first gets it
    assert spec == PartitionSpec("model")


def test_spec_tuple_axes():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    rules = {"batch": ("pod", "data")}
    spec = spec_for((64, 128), ("batch", None), FakeMesh(), rules)
    assert spec == PartitionSpec(("pod", "data"))


HLO_SAMPLE = """
ENTRY %main_spmd (p0: f32[16,256]) -> f32[] {
  %all-gather = f32[256,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}, metadata={op_name="jit(f)/scan_layers/while/body/ag"}
  %all-reduce = f32[16,256]{1,0} all-reduce(%y), channel_id=3, replica_groups=[2,4]<=[8], to_apply=%add, metadata={op_name="jit(f)/scan_layers/while/body/scan_qchunk/while/body/ar"}
  ROOT %all-reduce.1 = f32[] all-reduce(%z), channel_id=4, replica_groups=[1,8]<=[8], metadata={op_name="jit(f)/loss"}
}
"""


def test_parse_collectives_trips_and_groups():
    rep = parse_collectives(HLO_SAMPLE, {"scan_layers": 6, "scan_qchunk": 8}, world=8)
    assert rep.count() == 3
    ag, ar_inner, ar_outer = rep.ops
    assert ag.kind == "all-gather" and ag.group == 2 and ag.trips == 6
    assert ag.result_bytes == 256 * 256 * 4
    assert ar_inner.group == 4 and ar_inner.trips == 48         # 6 × 8
    assert ar_outer.group == 8 and ar_outer.trips == 1
    assert ar_outer.result_bytes == 4


def test_wire_byte_formulas():
    assert _wire_bytes("all-gather", 1000, 4) == pytest.approx(750.0)
    assert _wire_bytes("all-reduce", 1000, 4) == pytest.approx(1500.0)
    assert _wire_bytes("reduce-scatter", 250, 4) == pytest.approx(750.0)
    assert _wire_bytes("collective-permute", 1000, 4) == 1000.0
    assert _wire_bytes("all-reduce", 1000, 1) == 0.0


def test_roofline_terms_dominance():
    rep = CollectiveReport()
    t = roofline_terms(
        hlo_flops_global=1e18,
        hlo_bytes_global=1e15,
        collectives=rep,
        chips=256,
        model_flops=6e17,
    )
    assert t.dominant == "compute"
    assert t.useful_flops_fraction == pytest.approx(0.6)
    assert t.compute_s == pytest.approx(1e18 / (256 * 197e12))


def test_mesh_functions_touch_no_global_state(mesh):
    # make_production_mesh is only importable, not callable, on 1 device —
    # the module-level import must not create meshes.
    import repro.launch.mesh as m

    assert callable(m.make_production_mesh)
