"""Device-backend contract tests: fused f64 pipeline parity, Pallas
shape-bucket sweeps (interpret mode — no TPU needed), winner-selection
tie-breaking, the ledger mirror's journal/sync protocol, the compile
cache, and the auto-selection rule."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.timeslot import TimeSlotLedger, TransferPlan
from repro.core.topology import two_tier_fabric
from repro.kernels import ts_plan, ts_plan_device


@pytest.fixture(autouse=True)
def _device_backend():
    """Force the device dispatch path and an enabled mirror for every test
    here; restore the process-wide defaults afterwards."""
    prev = ts_plan.get_backend()
    ts_plan.set_backend("pallas")
    ts_plan_device.set_mirror(True)
    yield
    ts_plan.set_backend(prev)
    ts_plan_device.set_mirror(None)


def _inputs(seed, n, L, W, dyadic=False):
    rng = np.random.default_rng(seed)
    if dyadic:
        booked = rng.integers(0, 9, size=(n, L, W)) / 8.0
        caps = 2.0 ** rng.integers(0, 5, size=n)
        secs = np.ones((n, W))
        secs[:, 0] = 0.5
        sizes = rng.integers(1, 40, size=n).astype(np.float64)
    else:
        booked = rng.random((n, L, W))
        caps = rng.uniform(1.0, 37.0, size=n)
        secs = rng.uniform(0.0, 1.3, size=(n, W))
        sizes = rng.uniform(0.5, 60.0, size=n)
    return booked, caps, secs, sizes


def _assert_same(ref, got):
    for name, r, g in zip(("resid", "bw", "cum", "hit"), ref, got):
        assert np.array_equal(
            np.asarray(r, np.float64), np.asarray(g, np.float64)
        ), name


# -- fused f64 pipeline: bit-exact on arbitrary inputs -----------------------


@pytest.mark.parametrize("n", [1, 7, 8, 9, 33])
@pytest.mark.parametrize("L", [1, 8, 9])
@pytest.mark.parametrize("W", [1, 64, 200])
def test_f64_pipeline_bitwise_any_input(n, L, W):
    booked, caps, secs, sizes = _inputs(3 * n + L + W, n, L, W)
    ref = ts_plan.plan_scan_numpy(booked, caps, secs, sizes)
    got = ts_plan_device.plan_scan(booked, caps, secs, sizes)
    _assert_same(ref, got)


@pytest.mark.parametrize("cap", [None, 16.0, 3.7])
def test_f64_pipeline_overlay_and_cap_combos(cap):
    booked, caps, secs, sizes = _inputs(11, 9, 3, 48)
    rng = np.random.default_rng(99)
    overlay = (rng.random(booked.shape) < 0.2).astype(np.float64)
    ref = ts_plan.plan_scan_numpy(booked, caps, secs, sizes, cap, overlay)
    got = ts_plan_device.plan_scan(booked, caps, secs, sizes, cap, overlay)
    _assert_same(ref, got)


# -- Pallas kernel (interpret): shape buckets on float64-safe inputs ---------


@pytest.mark.parametrize(
    "n,L,W",
    [
        (7, 3, 127),   # below every pad boundary
        (8, 8, 128),   # exactly on the BN / L-pad / lane boundaries
        (9, 9, 129),   # just past all three
        (24, 4, 256),  # multi-block grid, two full lanes
    ],
)
@pytest.mark.parametrize("cap", [None, 16.0])
def test_pallas_kernel_shape_buckets(n, L, W, cap):
    booked, caps, secs, sizes = _inputs(n + L + W, n, L, W, dyadic=True)
    ref = ts_plan.plan_scan_numpy(booked, caps, secs, sizes, cap)
    got = ts_plan.plan_scan_pallas(
        booked, caps, secs, sizes, cap, interpret=True
    )
    _assert_same(ref, got)


def test_pallas_kernel_overlay_bitwise():
    booked, caps, secs, sizes = _inputs(21, 9, 3, 130, dyadic=True)
    overlay = np.zeros_like(booked)
    overlay[::2, 0, ::3] = 1.0
    ref = ts_plan.plan_scan_numpy(booked, caps, secs, sizes, None, overlay)
    got = ts_plan.plan_scan_pallas(
        booked, caps, secs, sizes, None, overlay, interpret=True
    )
    _assert_same(ref, got)


# -- satellites: _pad_to fast path, searchsorted hit, compile cache ----------


def test_pad_to_identity_fast_path():
    x = np.ones((4, 5))
    assert ts_plan._pad_to(x, (4, 5)) is x
    y = ts_plan._pad_to(x, (6, 5))
    assert y.shape == (6, 5) and (y[4:] == 0).all()


@pytest.mark.parametrize(
    "n,W", [(1, 4096), (2, 300), (8, 64), (40, 16), (7, 1)]
)
def test_hit_count_matches_historical_full_count(n, W):
    # Both _hit_count regimes (per-row searchsorted for few long rows,
    # vectorized count otherwise) must pin the pre-optimization counts.
    booked, caps, secs, sizes = _inputs(n * W, n, 2, W)
    sizes = np.concatenate([sizes[: n - 1], [1e9]])  # one never-fitting row
    _r, _b, cum, hit = ts_plan.plan_scan_numpy(booked, caps, secs, sizes)
    legacy = (cum < (sizes - ts_plan.EPS)[:, None]).sum(axis=1)
    assert np.array_equal(hit, legacy)


def test_compile_cache_buckets_trace_once():
    ts_plan_device.reset_cache()
    booked, caps, secs, sizes = _inputs(1, 5, 2, 32)
    ts_plan_device.plan_scan(booked, caps, secs, sizes)
    t1 = ts_plan_device.stats["traces"]
    assert t1 == 1
    ts_plan_device.plan_scan(booked * 0.5, caps, secs, sizes)
    assert ts_plan_device.stats["traces"] == t1  # same bucket: no retrace
    assert ts_plan_device.stats["cache_hits"] >= 1
    ts_plan_device.plan_scan(booked[:, :, :16], caps, secs[:, :16], sizes)
    assert ts_plan_device.stats["traces"] == t1 + 1  # new W bucket


# -- winner selection: tie-breaking parity -----------------------------------


def test_wave_select_tie_parity():
    rng = np.random.default_rng(7)
    counts = [1, 2, 5, 8, 3]
    nc = sum(counts)
    # Exact float ties on purpose: draw ends from a tiny dyadic pool.
    end = rng.integers(0, 3, size=nc) / 4.0
    end[4] = np.inf  # whole-segment unfit ties on rank alone
    end[5] = np.inf
    lens = rng.integers(1, 4, size=nc)
    srcs = rng.integers(0, 3, size=nc).astype(str)
    ranks = np.empty(nc, dtype=np.int64)
    expect = []
    pos = 0
    for cnt in counts:
        order = sorted(
            range(cnt), key=lambda c: (lens[pos + c], srcs[pos + c], c)
        )
        for r, c in enumerate(order):
            ranks[pos + c] = r
        expect.append(
            min(
                range(cnt),
                key=lambda c: (
                    end[pos + c], lens[pos + c], srcs[pos + c], c
                ),
            )
        )
        pos += cnt
    host = ts_plan.wave_select_numpy(end, ranks, counts)
    dev = ts_plan_device.wave_select(end, ranks, counts)
    assert np.array_equal(host, np.array(expect))
    assert np.array_equal(dev, np.array(expect))


# -- ledger mirror: journal/sync protocol ------------------------------------


def _ledger(horizon=64):
    fab = two_tier_fabric(2, 4, 100.0, 100.0)
    return TimeSlotLedger(fab, 1.0, horizon)


def _plan(led, rows, slot_fracs):
    start = slot_fracs[0][0] * led.slot_duration
    end = (slot_fracs[-1][0] + 1) * led.slot_duration
    return TransferPlan(tuple(rows), start, end, tuple(slot_fracs))


def _check(mirror, led):
    mirror.sync()
    assert np.array_equal(mirror.host_view(), led.reserved)


def test_mirror_tracks_api_mutations():
    led = _ledger()
    mirror = led.device_mirror()
    rows = led.path_rows("H0", "H5")
    _check(mirror, led)  # initial upload

    p1 = _plan(led, rows, [(2, 0.5), (3, 0.25)])
    led.commit(p1)
    p2 = _plan(led, rows, [(4, 1.0)])  # scalar fast path
    led.commit(p2)
    _check(mirror, led)
    assert ts_plan_device.stats["mirror_cells"] > 0

    led.occupy(rows[:2], 6.0, 9.0, 0.25)
    _check(mirror, led)

    led.release(p1)
    _check(mirror, led)

    p3 = _plan(led, rows, [(5, 0.5), (6, 0.5), (7, 0.5)])
    led.commit(p3)
    led.release_after(p3, 6.0)
    _check(mirror, led)

    other = led.path_rows("H1", "H6")
    led.commit_batch(
        [_plan(led, other, [(8, 0.5)]), _plan(led, rows, [(9, 0.25)])]
    )
    _check(mirror, led)


def test_mirror_survives_growth_and_origin_shift():
    led = _ledger(16)
    mirror = led.device_mirror()
    rows = led.path_rows("H0", "H5")
    led.commit(_plan(led, rows, [(3, 0.5)]))
    _check(mirror, led)

    led.commit(_plan(led, rows, [(40, 0.5)]))  # grows the window
    _check(mirror, led)

    led.commit(_plan(led, rows, [(700, 0.25)]))  # beyond the 256 bucket
    _check(mirror, led)

    led.retire_to(39)  # partial retire: origin shift, no invalidation
    led.commit(_plan(led, rows, [(41, 0.125)]))
    _check(mirror, led)
    assert mirror.base == 39

    led.retire_to(2000)  # full-past: reset through the setter → re-upload
    up0 = ts_plan_device.stats["mirror_uploads"]
    _check(mirror, led)
    assert ts_plan_device.stats["mirror_uploads"] == up0 + 1


def test_mirror_invalidated_by_direct_assignment():
    led = _ledger()
    mirror = led.device_mirror()
    rows = led.path_rows("H0", "H5")
    led.commit(_plan(led, rows, [(2, 0.5)]))
    _check(mirror, led)
    snap = led.reserved.copy()
    led.commit(_plan(led, rows, [(3, 0.5)]))
    led.reserved = snap  # controller restore(): setter must invalidate
    _check(mirror, led)
    led.reserved[list(rows), 5] = 0.5  # out-of-contract direct write...
    led.mirror_invalidate()            # ...declared, as reroute's paths do
    _check(mirror, led)


def test_wave_and_col_scan_parity_through_mirror():
    led = _ledger()
    rng = np.random.default_rng(5)
    rows_a = led.path_rows("H0", "H5")
    rows_b = led.path_rows("H2", "H7")
    for s in range(12):
        led.commit(_plan(led, rows_a, [(s, float(rng.integers(1, 7)) / 8.0)]))
    pad = np.array([rows_a, rows_b, rows_b], dtype=np.intp)
    caps = np.array([100.0, 50.0, 100.0])
    sz = np.array([0, 2, 5], dtype=np.int64)
    t0c = np.array([0.0, 2.25, 5.0])
    sizes = np.array([120.0, 60.0, 0.0])
    first = np.array([1.0, 0.75, 1.0])
    w = 16
    ref = ts_plan.wave_scan_numpy(led, pad, caps, sz, t0c, sizes, w, first)
    got = ts_plan_device.wave_scan(led, pad, caps, sz, t0c, sizes, w, first)
    for name, r, g in zip(("resid", "bw", "cum", "hit", "end"), ref, got):
        assert np.array_equal(np.asarray(r), np.asarray(g)), name

    cols = np.array(
        [[0, 1, 5, 9, 13], [2, 3, 4, 8, 20], [5, 6, 7, 30, 31]],
        dtype=np.int64,
    )
    secs = np.ones((3, 5))
    booked = led.reserved[pad[:, :, None], (cols - led.base_slot)[:, None, :]]
    ref = ts_plan.plan_scan_numpy(booked, caps, secs, sizes + 1.0)
    got = ts_plan_device.col_scan(led, pad, cols, caps, secs, sizes + 1.0)
    _assert_same(ref, got)


# -- auto rule ---------------------------------------------------------------


def test_auto_rule_resolution(monkeypatch):
    monkeypatch.setattr(ts_plan, "_backend", "auto")
    # Small calls never probe: numpy without touching jax.
    monkeypatch.setattr(ts_plan, "_auto", None)
    assert not ts_plan._use_device(ts_plan._AUTO_PROBE_CELLS - 1)
    assert ts_plan._auto is None
    # On CPU the resolved answer is numpy...
    if ts_plan_device.platform() == "cpu":
        assert not ts_plan._use_device(1 << 20)
        assert ts_plan._auto == (False, 0)
        # ...unless REPRO_TS_PLAN_AUTO_CELLS opts big calls in.
        monkeypatch.setenv("REPRO_TS_PLAN_AUTO_CELLS", "100000")
        monkeypatch.setattr(ts_plan, "_auto", None)
        assert ts_plan._use_device(1 << 20)
        assert not ts_plan._use_device(50_000)
    # Forced backends bypass the probe entirely.
    monkeypatch.setattr(ts_plan, "_backend", "numpy")
    assert not ts_plan._use_device(1 << 30)
    monkeypatch.setattr(ts_plan, "_backend", "pallas")
    assert ts_plan._use_device(1)
