"""Per-arch smoke tests (assignment requirement): instantiate the REDUCED
config of each family and run one forward/train step on CPU, asserting
output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch.steps import make_train_step
from repro.models.model import Model
from repro.optim import AdamW, constant


def make_batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_loss_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)
    assert bool(jnp.isfinite(metrics["ce"]))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_updates_params(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt = AdamW(lr=constant(1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, accum=1))
    batch = make_batch(cfg, key)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # at least the embedding moved
    delta = jnp.abs(
        new_params["embed"].astype(jnp.float32) - params["embed"].astype(jnp.float32)
    ).max()
    assert float(delta) > 0
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["qwen3-32b", "jamba-v0.1-52b", "falcon-mamba-7b", "whisper-base"])
def test_logits_shape(arch):
    cfg = get_config(arch, smoke=True).with_(remat=False)
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    b, s = 2, 12
    batch = make_batch(cfg, key, b, s)
    logits, caches = jax.jit(lambda p, bt: model.prefill(p, bt, 24))(params, batch)
    assert logits.shape == (b, cfg.vocab_size)


def test_full_configs_match_assignment():
    """Published numbers straight from the assignment block."""
    c = get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        88, 12288, 96, 8, 28672, 32768,
    )
    c = get_config("qwen3-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        64, 5120, 64, 8, 25600, 151936,
    ) and c.qk_norm
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_experts, c.top_k, c.d_ff, c.vocab_size) == (64, 6, 1408, 163840)
    c = get_config("jamba-v0.1-52b")
    assert (c.attn_period, c.n_experts, c.top_k, c.ssm_state) == (8, 16, 2, 16)
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.ssm_state) == (
        64, 4096, 0, 0, 16,
    )
    c = get_config("internvl2-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab_size) == (
        24, 896, 14, 2, 151655,
    )
