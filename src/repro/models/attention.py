"""GQA attention: full (train/prefill), decode-with-cache, and cross-attn.

Pure-jnp implementation (the XLA path used by the dry-run — it exposes real
FLOPs/bytes to ``cost_analysis``).  ``cfg.attn_impl == "pallas"`` routes the
full-sequence path through the fused Pallas kernel (TPU) instead; the two
are assert-allclose'd against each other in ``tests/test_kernels.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, rms_norm
from .params import P

NEG_INF = -1e30


def attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    defs = {
        "w_q": P((d, nq, hd), ("d_model", "heads", "head_dim")),
        "w_k": P((d, nkv, hd), ("d_model", "kv_heads", "head_dim")),
        "w_v": P((d, nkv, hd), ("d_model", "kv_heads", "head_dim")),
        "w_o": P((nq, hd, d), ("heads", "head_dim", "d_model")),
    }
    if cfg.qk_norm and not cross:
        defs["q_norm"] = P((hd,), ("head_dim",), "ones")
        defs["k_norm"] = P((hd,), ("head_dim",), "ones")
    return defs


def _qk_normalize(p: dict, q: jax.Array, k: jax.Array, cfg: ModelConfig):
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k


def _gqa_scores_out(
    q: jax.Array,          # [B, Sq, nq, hd]
    k: jax.Array,          # [B, Sk, nkv, hd]
    v: jax.Array,          # [B, Sk, nkv, hd]
    mask: Optional[jax.Array],  # broadcastable to [B, 1, 1, Sq, Sk] or None
) -> jax.Array:
    b, sq, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // max(nkv, 1)
    qg = q.reshape(b, sq, nkv, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, nq, hd)


def _chunked_attention(
    q: jax.Array,          # [B, Sq, nq, hd]
    k: jax.Array,
    v: jax.Array,
    causal: bool,
    chunk: int,
) -> jax.Array:
    """XLA-path attention scanning over query chunks so the materialized
    score block is [B, nkv, g, chunk, Sk] instead of O(Sq·Sk) — this is what
    makes the 32k-prefill and 4k-train cells fit HBM without the Pallas
    kernel (which replaces this entirely on real TPUs)."""
    b, sq, nq, hd = q.shape
    sk = k.shape[1]
    cq = chunk
    while cq > 0 and sq % cq:
        cq //= 2
    if cq <= 0 or cq >= sq:
        mask = causal_mask(sq, sk) if causal else None
        return _gqa_scores_out(q, k, v, mask)
    nc = sq // cq
    qc = jnp.moveaxis(q.reshape(b, nc, cq, nq, hd), 1, 0)   # [nc, B, cq, nq, hd]

    def body(_, inp):
        i, qi = inp
        if causal:
            qpos = i * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, sk), 0)
            kpos = jax.lax.broadcasted_iota(jnp.int32, (cq, sk), 1)
            mask = (kpos <= qpos)[None, None, None]
        else:
            mask = None
        return None, _gqa_scores_out(qi, k, v, mask)

    with jax.named_scope("scan_qchunk"):
        _, out = jax.lax.scan(body, None, (jnp.arange(nc), qc))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, nq, hd)


def causal_mask(sq: int, sk: int, offset: int = 0) -> jax.Array:
    """[1,1,1,Sq,Sk] True where attendable; query i sees keys ≤ i+offset."""
    qi = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
    return (ki <= qi + offset)[None, None, None]


def full_attention(
    p: dict,
    x: jax.Array,                       # [B, S, d]
    cfg: ModelConfig,
    rope: Optional[Tuple[jax.Array, jax.Array]],
    causal: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Self-attention over the whole sequence → (out, (k, v) for caching).

    Sharding: the residual stream arrives sequence-sharded over ``model``;
    q/k/v are constrained to *head*-sharding so XLA lowers a cheap
    all-to-all (seq→heads) and the whole softmax runs local per head —
    without this the chunked score loop re-gathers K/V every iteration
    (§Perf iteration 1).  kv_heads that don't divide the axis stay
    replicated (free: they're the small tensors).
    """
    from ..distributed.actctx import constrain

    q = jnp.einsum("bsd,dnh->bsnh", x, p["w_q"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["w_k"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["w_v"])
    q = constrain(q, ("batch", None, "heads", None), require_axis="heads")
    k = constrain(k, ("batch", None, "kv_heads", None), require_axis="heads")
    v = constrain(v, ("batch", None, "kv_heads", None), require_axis="heads")
    q, k = _qk_normalize(p, q, k, cfg)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if cfg.attn_impl == "pallas" and causal:
        from ..kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=True)
    else:
        out = _chunked_attention(q, k, v, causal, cfg.attn_chunk)
    out = constrain(out, ("batch", None, "heads", None), require_axis="heads")
    y = jnp.einsum("bsnh,nhd->bsd", out, p["w_o"])
    # contraction over model-sharded heads → partial sums; constraining the
    # result back to seq-sharding lets GSPMD emit a reduce-scatter instead
    # of all-reduce + slice (halves the o-proj wire bytes).
    y = constrain(y, ("batch", "seq", None))
    return y, (k, v)


def decode_attention(
    p: dict,
    x: jax.Array,                       # [B, 1, d]
    cfg: ModelConfig,
    rope: Optional[Tuple[jax.Array, jax.Array]],
    k_cache: jax.Array,                 # [B, S_max, nkv, hd]
    v_cache: jax.Array,
    pos: jax.Array,                     # scalar int32 — next position to write
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: write k/v at ``pos``, attend over positions ≤ pos."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["w_q"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["w_k"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["w_v"])
    q, k = _qk_normalize(p, q, k, cfg)
    if rope is not None:
        cos, sin = rope                 # tables for position `pos`: [1, hd/2]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
    s_max = k_cache.shape[1]
    ki = jax.lax.broadcasted_iota(jnp.int32, (1, s_max), 1)
    mask = (ki <= pos)[None, None, None, :, :].reshape(1, 1, 1, 1, s_max)
    out = _gqa_scores_out(q, k_cache, v_cache, mask)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["w_o"])
    return y, k_cache, v_cache


def cross_attention(
    p: dict,
    x: jax.Array,                       # [B, Sq, d]
    k: jax.Array,                       # [B, Sk, nkv, hd] (precomputed enc K)
    v: jax.Array,
    cfg: ModelConfig,
) -> jax.Array:
    q = jnp.einsum("bsd,dnh->bsnh", x, p["w_q"])
    out = _gqa_scores_out(q, k, v, None)
    return jnp.einsum("bsnh,nhd->bsd", out, p["w_o"])


def cross_kv(p: dict, enc: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dnh->bsnh", enc, p["w_k"])
    v = jnp.einsum("bsd,dnh->bsnh", enc, p["w_v"])
    return k, v
