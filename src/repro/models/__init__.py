"""Pure-JAX model zoo for the assigned architectures."""
from .model import Model, build_model
from .params import P, abstract_params, count_params, init_params, param_axes

__all__ = [
    "Model",
    "P",
    "abstract_params",
    "build_model",
    "count_params",
    "init_params",
    "param_axes",
]
