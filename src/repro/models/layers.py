"""Shared layer primitives: RMSNorm, RoPE, sinusoidal positions, MLPs."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import P


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_tables(
    positions: jax.Array, head_dim: int, theta: float
) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for integer ``positions`` [...,] → [..., head_dim//2]."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, n_heads, head_dim]; cos/sin: [S, head_dim//2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # cos/sin broadcast over the heads axis: [S, 1, half]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    dtype = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1
    ).astype(dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings for integer positions [...,]."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10_000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, width: Optional[int] = None) -> dict:
    d, f = cfg.d_model, width or cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "w_gate": P((d, f), ("d_model", "d_ff")),
            "w_up": P((d, f), ("d_model", "d_ff")),
            "w_down": P((f, d), ("d_ff", "d_model")),
        }
    return {
        "w_in": P((d, f), ("d_model", "d_ff")),
        "w_out": P((f, d), ("d_ff", "d_model")),
    }


def mlp_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Gated/plain MLP.

    Sharding (§Perf iteration 4): the hidden activation is constrained to
    d_ff-sharding over ``model``.  With seq-sharded inputs XLA would
    otherwise *fully gather the weights* on every call (each seq shard
    needs all d_ff columns — 0.9 TB/device/step on the 123 B config);
    constraining ``h`` makes it gather the much smaller activations
    (Megatron MLP: AG(x) → column-parallel → row-parallel → RS(y))."""
    from ..distributed.actctx import constrain

    hspec = ("batch", None, "d_ff")
    cst = lambda t: constrain(t, hspec, require_axis="d_ff")
    if cfg.mlp_kind == "swiglu":
        g = cst(jnp.einsum("...d,df->...f", x, p["w_gate"]))
        u = cst(jnp.einsum("...d,df->...f", x, p["w_up"]))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = jnp.einsum("...f,fd->...d", h, p["w_down"])
    else:
        h = cst(jnp.einsum("...d,df->...f", x, p["w_in"]))
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        y = jnp.einsum("...f,fd->...d", h, p["w_out"])
    return constrain(y, ("batch", "seq", None))
