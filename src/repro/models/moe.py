"""Capacity-based top-k MoE with sort-based dispatch (GShard-style, static
shapes, expert-parallel over the ``model`` mesh axis).

Dispatch: flatten tokens, take top-k experts per token, argsort the expert
ids, compute each entry's position within its expert (arange − segment
start), drop entries beyond capacity ``C = ceil(T·k/E · capacity_factor)``,
scatter into an ``[E, C, d]`` buffer, run per-expert MLPs as one batched
einsum, and combine back with the router weights.  Dropped tokens fall
through on the residual path (standard capacity-factor semantics).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import P


def moe_defs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    defs = {
        "router": P((d, e), ("d_model", "experts")),
    }
    if cfg.mlp_kind == "swiglu":
        defs.update(
            w_gate=P((e, d, f), ("experts", "d_model", "d_ff")),
            w_up=P((e, d, f), ("experts", "d_model", "d_ff")),
            w_down=P((e, f, d), ("experts", "d_ff", "d_model")),
        )
    else:
        defs.update(
            w_in=P((e, d, f), ("experts", "d_model", "d_ff")),
            w_out=P((e, f, d), ("experts", "d_ff", "d_model")),
        )
    return defs


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,d] → (y [B,S,d], aux_loss scalar).  Dispatch impl per
    ``cfg.moe_impl``: "gather" (global sort/scatter — simple, but its
    collectives cross the full token sharding) or "a2a" (shard_map
    expert-parallel: local routing + bucketed all-to-alls along the
    ``model`` axis — §Perf iteration 2)."""
    if cfg.moe_impl == "a2a":
        from ..distributed import actctx

        ctx = actctx.active()
        if ctx is not None and _a2a_applicable(cfg, ctx[0]):
            return _moe_block_a2a(p, x, cfg, ctx[0], ctx[1])
    return _moe_block_gather(p, x, cfg)


def _a2a_applicable(cfg: ModelConfig, mesh) -> bool:
    return "model" in mesh.shape and cfg.n_experts % mesh.shape["model"] == 0


def _moe_block_gather(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # [T,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )                                                         # renormalize

    # Load-balancing auxiliary loss (Switch-style): E · Σ_e f_e · p̄_e.
    me = probs.mean(axis=0)                                   # [E]
    one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)
    aux = e * jnp.sum(me * ce)

    cap = capacity(cfg, t)
    flat_e = gate_idx.reshape(-1)                             # [T*k]
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)                   # [E]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)     # overflow slot

    tok_idx = order // k                                      # source token
    xs = xt[tok_idx]                                          # [T*k, d]
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xs)
    buf = buf[: e * cap].reshape(e, cap, d)

    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(buf.dtype)
        out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"])

    out_flat = out_buf.reshape(e * cap, d)
    ys = jnp.where(keep[:, None], out_flat[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    w = gate_vals.reshape(-1)[order].astype(ys.dtype)         # [T*k]
    y = jnp.zeros((t, d), ys.dtype).at[tok_idx].add(ys * w[:, None])
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# §Perf iteration 2 — expert-parallel dispatch via shard_map + all-to-all
# ---------------------------------------------------------------------------

def _shard_map():
    try:
        from jax import shard_map as sm          # jax ≥ 0.7 public API
        return sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        return sm


_CHECK_KW = None


def _replication_check_kw(sm) -> str:
    """jax ≥ 0.7 spells the replication-check kwarg check_vma; older
    check_rep.  Probed once, cached for every a2a call."""
    global _CHECK_KW
    if _CHECK_KW is None:
        import inspect

        _CHECK_KW = (
            "check_vma"
            if "check_vma" in inspect.signature(sm).parameters
            else "check_rep"
        )
    return _CHECK_KW


def _moe_block_a2a(
    p: dict, x: jax.Array, cfg: ModelConfig, mesh, rules
) -> Tuple[jax.Array, jax.Array]:
    """Bucketed expert-parallel dispatch.

    Per device (inside shard_map): route the *local* tokens, bucket the
    (token, choice) pairs by global expert with per-expert capacity
    ``c_e = ceil(t_loc·k/E · cf)``, all-to-all the [E, c_e, d] buffer along
    the ``model`` axis (each peer owns E/n contiguous experts), run the
    local experts as one batched einsum, all-to-all back, combine with the
    router weights.  Wire per device ≈ 2·t_loc·k·cf·d·2 B per layer — vs the
    gather implementation whose scatter/gather collectives cross the full
    global token sharding (the dominant term of the baseline roofline for
    every MoE arch).
    """
    from jax.sharding import PartitionSpec as P

    shard_map = _shard_map()
    n_model = mesh.shape["model"]
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // n_model

    b, s, d = x.shape
    dp = rules.get("batch", ("data",))
    if isinstance(dp, list):
        dp = dp[0]
    dp = tuple(a for a in (dp if isinstance(dp, tuple) else (dp,)) if a in mesh.shape)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    seq_sharded = rules.get("seq") == "model" and s % n_model == 0
    if b % dp_size:
        dp = ()
        dp_size = 1
    x_spec = P(dp if dp else None, "model" if seq_sharded else None, None)

    t_loc = (b // dp_size) * (s // (n_model if seq_sharded else 1))
    c_e = max(4, -(-int(t_loc * k * cfg.capacity_factor) // e // 4) * 4)

    # Parameter specs mirror PARAM_RULES (see distributed.sharding).
    router_spec = P("data", "model")
    w_in_spec = P("model", "data", None)     # [E, d, f]
    w_out_spec = P("model", None, "data")    # [E, f, d]
    swiglu = cfg.mlp_kind == "swiglu"

    def body(x_loc, router_loc, *weights):
        bl, sl, _ = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        router = jax.lax.all_gather(router_loc, "data", axis=0, tiled=True)
        router = jax.lax.all_gather(router, "model", axis=1, tiled=True)

        logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        # Load-balance aux over the *global* token population.
        axes = dp + ("model",) if seq_sharded else dp
        me_sum = probs.sum(axis=0)
        ce_sum = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32).sum(axis=0)
        n_tok = jnp.float32(t)
        if axes:
            me_sum = jax.lax.psum(me_sum, axes)
            ce_sum = jax.lax.psum(ce_sum, axes)
            n_tok = jax.lax.psum(n_tok, axes)
        aux = e * jnp.sum((me_sum / n_tok) * (ce_sum / n_tok))

        # Local bucketing by global expert (stable sort + capacity drop).
        flat_e = gate_idx.reshape(-1)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
        )
        pos = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
        keep = pos < c_e
        dest = jnp.where(keep, sorted_e * c_e + pos, e * c_e)
        tok_idx = order // k
        xbuf = jnp.zeros((e * c_e + 1, d), xt.dtype).at[dest].set(xt[tok_idx])
        payload = xbuf[: e * c_e].reshape(n_model, e_loc * c_e, d)

        recv = jax.lax.all_to_all(payload, "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        # [n_model, e_loc*c_e, d] → [e_loc, n_model*c_e, d]
        toks = (
            recv.reshape(n_model, e_loc, c_e, d)
            .transpose(1, 0, 2, 3)
            .reshape(e_loc, n_model * c_e, d)
        )

        if swiglu:
            wg, wu, wd = weights
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
            g = jnp.einsum("ecd,edf->ecf", toks, wg)
            u = jnp.einsum("ecd,edf->ecf", toks, wu)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(toks.dtype) * u
            out = jnp.einsum("ecf,efd->ecd", h, wd)
        else:
            wi, wo = weights
            wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
            h = jnp.einsum("ecd,edf->ecf", toks, wi)
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(toks.dtype)
            out = jnp.einsum("ecf,efd->ecd", h, wo)

        back = (
            out.reshape(e_loc, n_model, c_e, d)
            .transpose(1, 0, 2, 3)
            .reshape(n_model, e_loc * c_e, d)
        )
        outbuf = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                                    tiled=False).reshape(e * c_e, d)
        ys = jnp.where(keep[:, None], outbuf[jnp.clip(dest, 0, e * c_e - 1)], 0.0)
        w = gate_vals.reshape(-1)[order].astype(ys.dtype)
        y = jnp.zeros((t, d), ys.dtype).at[tok_idx].add(ys * w[:, None])
        return y.reshape(bl, sl, d), aux

    weights = (
        (p["w_gate"], p["w_up"], p["w_down"]) if swiglu else (p["w_in"], p["w_out"])
    )
    w_specs = (
        (w_in_spec, w_in_spec, w_out_spec) if swiglu else (w_in_spec, w_out_spec)
    )
    check_kw = _replication_check_kw(shard_map)
    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, router_spec) + w_specs,
        out_specs=(x_spec, P()),
        **{check_kw: False},
    )(x, p["router"], *weights)
    return y, aux
