"""Unified model API over every assigned family.

``Model(cfg)`` exposes pure functions:

* ``defs()`` / ``init(key)`` / ``abstract()`` — parameter declaration
* ``loss(params, batch)``       — next-token CE (+ MoE aux), f32
* ``prefill(params, batch, s_max)`` — full pass → (last logits, caches)
* ``decode(params, token, pos, caches)`` — one-token step
* ``cache_defs(batch, s_max)``  — decode-state declaration (for sharding)

Batch keys by family: ``tokens`` (all LM), ``vision_embeds`` (vlm stub),
``frames`` (audio stub), optional ``loss_mask``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.actctx import constrain
from . import encdec as ed
from .layers import rms_norm, rope_tables
from .params import Tree, abstract_params, init_params, param_axes
from .transformer import (
    apply_stack_decode,
    apply_stack_full,
    cache_defs as tf_cache_defs,
    model_defs,
)


def _cache_init_dtype(cfg: ModelConfig, leaf_name: str) -> jnp.dtype:
    return jnp.float32 if leaf_name == "h" else jnp.dtype(cfg.compute_dtype)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters -----------------------------------------------------------
    def defs(self) -> Tree:
        if self.cfg.family == "encdec":
            return ed.encdec_defs(self.cfg)
        return model_defs(self.cfg)

    def init(self, key: jax.Array) -> Tree:
        return init_params(self.defs(), key, jnp.dtype(self.cfg.param_dtype))

    def abstract(self) -> Tree:
        return abstract_params(self.defs(), jnp.dtype(self.cfg.param_dtype))

    def axes(self) -> Tree:
        return param_axes(self.defs())

    # -- embedding / head -------------------------------------------------------
    def _embed(self, params: Tree, tokens: jax.Array) -> jax.Array:
        x = params["embed"][tokens]
        return x.astype(jnp.dtype(self.cfg.compute_dtype))

    def _head(self, params: Tree, x: jax.Array) -> jax.Array:
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return constrain(logits.astype(jnp.float32), ("batch", "seq", "vocab"))

    def _rope(self, positions: jax.Array):
        if not self.cfg.use_rope or self.cfg.n_heads == 0:
            return None
        return rope_tables(positions, self.cfg.resolved_head_dim, self.cfg.rope_theta)

    def _assemble_input(self, params: Tree, batch: Dict[str, jax.Array]) -> jax.Array:
        """Token embeddings with modality-stub prefixes prepended."""
        x = self._embed(params, batch["tokens"])
        if self.cfg.family == "vlm":
            vis = batch["vision_embeds"].astype(x.dtype)   # [B, n_vis, d]
            x = jnp.concatenate([vis, x], axis=1)
        return constrain(x, ("batch", "seq", None))

    # -- training loss -----------------------------------------------------------
    def loss(
        self, params: Tree, batch: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        if cfg.family == "encdec":
            enc = ed.encode(params, batch["frames"], cfg)
            logits, _ = ed.decode_full(params, batch["tokens"], enc, cfg)
            aux = jnp.zeros((), jnp.float32)
            n_prefix = 0
        else:
            x = self._assemble_input(params, batch)
            rope = self._rope(jnp.arange(x.shape[1]))
            x, aux, _ = apply_stack_full(cfg, params["stack"], x, rope)
            logits = self._head(params, x)
            n_prefix = cfg.n_vision_tokens if cfg.family == "vlm" else 0

        tokens = batch["tokens"]
        # predict token t+1 from position (n_prefix + t)
        pred = logits[:, n_prefix : n_prefix + tokens.shape[1] - 1]
        tgt = tokens[:, 1:]
        logz = jax.nn.logsumexp(pred, axis=-1)
        gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = batch.get("loss_mask")
        if mask is not None:
            m = mask[:, 1:].astype(jnp.float32)
            ce = (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
        else:
            ce = nll.mean()
        total = ce + cfg.aux_loss_weight * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving ---------------------------------------------------------------
    def cache_defs(self, batch: int, s_max: int) -> Tree:
        if self.cfg.family == "encdec":
            return ed.encdec_cache_defs(self.cfg, batch, s_max)
        return tf_cache_defs(self.cfg, batch, s_max)

    def init_caches(self, batch: int, s_max: int) -> Tree:
        from .params import P, tree_map_defs

        def mk(p: P):
            name = p.axes[-1] if p.axes else None
            dt = jnp.float32 if (p.shape and p.axes and "ssm_state" in p.axes) else jnp.dtype(
                self.cfg.compute_dtype
            )
            return jnp.zeros(p.shape, dt)

        return tree_map_defs(mk, self.cache_defs(batch, s_max))

    def prefill(
        self, params: Tree, batch: Dict[str, jax.Array], s_max: int
    ) -> Tuple[jax.Array, Tree]:
        """Full pass over the prompt → (logits at last position, caches)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc = ed.encode(params, batch["frames"], cfg)
            logits, states = ed.decode_full(
                params, batch["tokens"], enc, cfg, collect_state=True
            )
            caches = self._pad_states(states, s_max)
            return logits[:, -1], caches

        x = self._assemble_input(params, batch)
        rope = self._rope(jnp.arange(x.shape[1]))
        x, _, states = apply_stack_full(
            cfg, params["stack"], x, rope, collect_state=True
        )
        logits = self._head(params, x[:, -1:])[:, 0]
        return logits, self._pad_states(states, s_max)

    def _pad_states(self, states: Tree, s_max: int) -> Tree:
        """Place prefill k/v (length S) into zero caches of length s_max."""

        def pad(leaf_path, arr):
            if leaf_path in ("k", "v"):
                # [L, B, S, nkv, hd] → [L, B, s_max, nkv, hd]
                pad_len = s_max - arr.shape[2]
                if pad_len <= 0:
                    return arr[:, :, :s_max]
                zeros = jnp.zeros(
                    arr.shape[:2] + (pad_len,) + arr.shape[3:], arr.dtype
                )
                return jnp.concatenate([arr, zeros], axis=2)
            return arr

        return _map_named(pad, states)

    def decode(
        self,
        params: Tree,
        token: jax.Array,            # [B, 1] int32
        pos: jax.Array,              # scalar int32: position being written
        caches: Tree,
    ) -> Tuple[jax.Array, Tree]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return ed.decode_step(params, token, pos, caches, cfg)
        x = self._embed(params, token)
        rope = self._rope(pos[None]) if jnp.ndim(pos) == 0 else self._rope(pos)
        x, new_caches = apply_stack_decode(
            cfg, params["stack"], x, rope, caches, pos
        )
        logits = self._head(params, x)[:, 0]
        return logits, new_caches


def _map_named(fn, tree):
    """tree_map passing the leaf's dict key (cache trees are dict-leaved)."""
    if isinstance(tree, dict):
        return {k: (_map_named(fn, v) if isinstance(v, dict) else fn(k, v)) for k, v in tree.items()}
    return tree


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
