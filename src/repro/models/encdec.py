"""Whisper-style encoder-decoder.

Encoder: bidirectional self-attention over precomputed frame embeddings
(the conv/log-mel frontend is a stub per the assignment — ``input_specs``
feeds ``[B, enc_seq, d_model]``).  Decoder: causal self-attention +
cross-attention + MLP.  Positions are sinusoidal, added at the embedding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.actctx import constrain
from .attention import (
    attn_defs,
    cross_attention,
    cross_kv,
    decode_attention,
    full_attention,
)
from .layers import mlp_block, mlp_defs, rms_norm, sinusoidal_positions
from .params import P, Tree
from .transformer import _attn_cache_defs, _stack


def encdec_defs(cfg: ModelConfig) -> Tree:
    d, v = cfg.d_model, cfg.vocab_size
    enc_layer = {
        "ln1": P((d,), ("d_model",), "ones"),
        "attn": attn_defs(cfg),
        "ln2": P((d,), ("d_model",), "ones"),
        "mlp": mlp_defs(cfg),
    }
    dec_layer = {
        "ln1": P((d,), ("d_model",), "ones"),
        "attn": attn_defs(cfg),
        "ln_x": P((d,), ("d_model",), "ones"),
        "xattn": attn_defs(cfg, cross=True),
        "ln2": P((d,), ("d_model",), "ones"),
        "mlp": mlp_defs(cfg),
    }
    return {
        "embed": P((v, d), ("vocab", "d_model")),
        "enc_in": P((d, d), ("d_model", None)),  # frame-embedding adapter stub
        "encoder": _stack(enc_layer, cfg.n_enc_layers),
        "ln_enc": P((d,), ("d_model",), "ones"),
        "decoder": _stack(dec_layer, cfg.n_layers),
        "ln_f": P((d,), ("d_model",), "ones"),
        "lm_head": P((d, v), ("d_model", "vocab")),
    }


def encode(params: Tree, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames [B, enc_seq, d] → encoder output [B, enc_seq, d]."""
    pos = sinusoidal_positions(jnp.arange(frames.shape[1]), cfg.d_model)
    x = jnp.einsum("bsd,de->bse", frames, params["enc_in"])
    x = (x + pos[None].astype(x.dtype)).astype(jnp.dtype(cfg.compute_dtype))

    def body(xc, lp):
        xc = constrain(xc, ("batch", "seq", None))
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        y, _ = full_attention(lp["attn"], h, cfg, rope=None, causal=False)
        xc = xc + y
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp_block(lp["mlp"], h, cfg)
        return xc, None

    if not cfg.scan_layers:
        for li in range(cfg.n_enc_layers):
            lp = jax.tree_util.tree_map(lambda a: a[li], params["encoder"])
            fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = fn(x, lp)
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)
    if cfg.remat:
        body = jax.checkpoint(body)
    with jax.named_scope("scan_layers"):
        x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def decode_full(
    params: Tree,
    tokens: jax.Array,          # [B, S]
    enc_out: jax.Array,         # [B, enc_seq, d]
    cfg: ModelConfig,
    collect_state: bool = False,
):
    """Teacher-forced decoder pass → (logits [B,S,V], states | None)."""
    s = tokens.shape[1]
    pos = sinusoidal_positions(jnp.arange(s), cfg.d_model)
    x = params["embed"][tokens] + pos[None].astype(params["embed"].dtype)
    x = x.astype(jnp.dtype(cfg.compute_dtype))

    def body(xc, lp):
        xc = constrain(xc, ("batch", "seq", None))
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        y, (k, v) = full_attention(lp["attn"], h, cfg, rope=None, causal=True)
        xc = xc + y
        h = rms_norm(xc, lp["ln_x"], cfg.norm_eps)
        ek, ev = cross_kv(lp["xattn"], enc_out)
        xc = xc + cross_attention(lp["xattn"], h, ek, ev, cfg)
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp_block(lp["mlp"], h, cfg)
        st = {"k": k, "v": v, "ek": ek, "ev": ev} if collect_state else None
        return xc, st

    if not cfg.scan_layers:
        sts = []
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[li], params["decoder"])
            fn = jax.checkpoint(body) if (cfg.remat and not collect_state) else body
            x, st = fn(x, lp)
            sts.append(st)
        states = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sts)
            if collect_state else None
        )
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
        return logits, states
    if cfg.remat and not collect_state:
        body = jax.checkpoint(body)
    with jax.named_scope("scan_layers"):
        x, states = jax.lax.scan(body, x, params["decoder"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits, states


def decode_step(
    params: Tree,
    token: jax.Array,           # [B, 1]
    pos_id: jax.Array,          # scalar int32
    caches: Dict[str, jax.Array],
    cfg: ModelConfig,
):
    """Single-token decode with self-KV + precomputed cross-KV caches."""
    pos = sinusoidal_positions(pos_id[None], cfg.d_model)     # [1, d]
    x = params["embed"][token] + pos[None].astype(params["embed"].dtype)
    x = x.astype(jnp.dtype(cfg.compute_dtype))

    def body(xc, scanned):
        lp, cc = scanned
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        y, k_c, v_c = decode_attention(
            lp["attn"], h, cfg, None, cc["k"], cc["v"], pos_id
        )
        xc = xc + y
        h = rms_norm(xc, lp["ln_x"], cfg.norm_eps)
        xc = xc + cross_attention(lp["xattn"], h, cc["ek"], cc["ev"], cfg)
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp_block(lp["mlp"], h, cfg)
        return xc, {**cc, "k": k_c, "v": v_c}

    if not cfg.scan_layers:
        ncs = []
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[li], params["decoder"])
            cc = jax.tree_util.tree_map(lambda a: a[li], caches)
            x, nc = body(x, (lp, cc))
            ncs.append(nc)
        new_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
    else:
        with jax.named_scope("scan_layers"):
            x, new_caches = jax.lax.scan(body, x, (params["decoder"], caches))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits, new_caches


def encdec_cache_defs(cfg: ModelConfig, batch: int, s_max: int) -> Tree:
    hd = cfg.resolved_head_dim
    one = dict(_attn_cache_defs(cfg, batch, s_max))
    one["ek"] = P((batch, cfg.enc_seq, cfg.n_kv_heads, hd),
                  ("batch", None, "kv_heads", "head_dim"), "zeros")
    one["ev"] = P((batch, cfg.enc_seq, cfg.n_kv_heads, hd),
                  ("batch", None, "kv_heads", "head_dim"), "zeros")
    return _stack(one, cfg.n_layers)
