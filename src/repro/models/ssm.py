"""Mamba1 selective-SSM block (falcon-mamba, jamba's mamba layers).

Recurrence (per channel c, state dim n):
    h_t = exp(Δ_t A) ⊙ h_{t-1} + (Δ_t x_t) B_t
    y_t = C_t · h_t + D x_t
with Δ = softplus(x W_dt W_dtproj + b), (B, C) = x W_bc, gated by silu(z)
and preceded by a depthwise causal conv (width ``ssm_conv``).

The XLA path scans over time with an O(B·d_inner·N) carry — memory-light and
compile-friendly at 524 288 tokens.  ``cfg.ssm_impl == "pallas"`` uses the
chunked TPU kernel in ``repro.kernels.mamba_scan`` for the full-sequence
path instead.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import P


def mamba_defs(cfg: ModelConfig) -> dict:
    d, d_in = cfg.d_model, cfg.d_inner
    n, r, k = cfg.ssm_state, cfg.resolved_dt_rank, cfg.ssm_conv
    return {
        "w_in_x": P((d, d_in), ("d_model", "d_inner")),
        "w_in_z": P((d, d_in), ("d_model", "d_inner")),
        "conv_w": P((d_in, k), ("d_inner", "conv")),
        "conv_b": P((d_in,), ("d_inner",), "zeros"),
        "w_dt": P((d_in, r), ("d_inner", "dt_rank")),
        "dt_proj": P((r, d_in), ("dt_rank", "d_inner")),
        "dt_bias": P((d_in,), ("d_inner",), "zeros"),
        "w_b": P((d_in, n), ("d_inner", "ssm_state")),
        "w_c": P((d_in, n), ("d_inner", "ssm_state")),
        "a_log": P((d_in, n), ("d_inner", "ssm_state"), "mamba_a"),
        "d_skip": P((d_in,), ("d_inner",), "ones"),
        "w_out": P((d_in, d), ("d_inner", "d_model")),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x [B,S,d_in], w [d_in,k] → causal depthwise conv, same length."""
    k = w.shape[-1]
    xt = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))          # left pad
    out = jax.lax.conv_general_dilated(
        xt,
        w[:, None, :],                                       # [d_in, 1, k]
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "OIH", "NHC"),
        feature_group_count=w.shape[0],
    )
    return out + b


def _ssm_inputs(p: dict, x: jax.Array, cfg: ModelConfig):
    """Shared pre-scan projections: returns (xc, dt, B, C) with silu applied."""
    xc = jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(
        (jnp.einsum("...i,ir->...r", xc, p["w_dt"]) @ p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )                                                        # [..., d_in] f32
    b_mat = jnp.einsum("...i,in->...n", xc, p["w_b"]).astype(jnp.float32)
    c_mat = jnp.einsum("...i,in->...n", xc, p["w_c"]).astype(jnp.float32)
    return xc, dt, b_mat, c_mat


def mamba_block(
    p: dict, x: jax.Array, cfg: ModelConfig, return_state: bool = False
):
    """Full-sequence forward: x [B,S,d] → [B,S,d] (+ final (conv, h) state).

    The returned state slots straight into :func:`mamba_decode` so prefill →
    decode hand-off is exact.
    """
    xp_raw = jnp.einsum("bsd,di->bsi", x, p["w_in_x"])
    z = jnp.einsum("bsd,di->bsi", x, p["w_in_z"])
    xp = _causal_depthwise_conv(xp_raw, p["conv_w"], p["conv_b"])
    xc, dt, b_mat, c_mat = _ssm_inputs(p, xp, cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # [d_in, N]

    bsz, d_in = xc.shape[0], xc.shape[-1]
    h0 = jnp.zeros((bsz, d_in, cfg.ssm_state), jnp.float32)
    if cfg.ssm_impl == "pallas" and not return_state:
        from ..kernels import ops as kops

        y = kops.mamba_scan(xc.astype(jnp.float32), dt, a, b_mat, c_mat)
        h_final = h0  # not used
    else:
        def step(h, inp):
            xt, dtt, bt, ct = inp                             # [B,d_in] [B,d_in] [B,N] [B,N]
            da = jnp.exp(dtt[..., None] * a)                  # [B,d_in,N]
            h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
            y = jnp.einsum("bin,bn->bi", h, ct)
            return h, y

        xs = (
            jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(b_mat, 1, 0),
            jnp.moveaxis(c_mat, 1, 0),
        )
        with jax.named_scope("scan_time"):
            h_final, ys = jax.lax.scan(step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1)                            # [B,S,d_in]

    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    if not return_state:
        return out
    k = cfg.ssm_conv
    conv_state = xp_raw[:, -(k - 1):, :].astype(jnp.dtype(cfg.compute_dtype))
    return out, {"conv": conv_state, "h": h_final}


def mamba_decode(
    p: dict,
    x: jax.Array,                      # [B, 1, d]
    cfg: ModelConfig,
    conv_state: jax.Array,             # [B, k-1, d_in] — last k-1 conv inputs
    h: jax.Array,                      # [B, d_in, N] f32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token state update — O(1) in sequence length."""
    xp = jnp.einsum("bsd,di->bsi", x, p["w_in_x"])            # [B,1,d_in]
    z = jnp.einsum("bsd,di->bsi", x, p["w_in_z"])
    window = jnp.concatenate([conv_state, xp], axis=1)        # [B,k,d_in]
    new_conv_state = window[:, 1:]
    xconv = jnp.einsum("bki,ik->bi", window, p["conv_w"]) + p["conv_b"]
    xconv = xconv[:, None, :]                                  # [B,1,d_in]
    xc, dt, b_mat, c_mat = _ssm_inputs(p, xconv, cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dtt, xt = dt[:, 0], xc[:, 0].astype(jnp.float32)           # [B,d_in]
    bt, ct = b_mat[:, 0], c_mat[:, 0]                          # [B,N]
    da = jnp.exp(dtt[..., None] * a)
    h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
    y = jnp.einsum("bin,bn->bi", h, ct) + p["d_skip"].astype(jnp.float32) * xt
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, p["w_out"])[:, None, :]
    return out, new_conv_state, h
