"""Decoder-only stacks: dense / MoE / SSM / hybrid (+ VLM prepend).

The stack is declared per repeating unit and scanned (``lax.scan``) so HLO
depth is O(1) in layer count — an 88-layer 123 B model lowers to the same
program size as a 2-layer smoke config.  Hybrid (jamba) scans over
*periods*: the 8-slot pattern (attention at slot 4, MoE on odd slots) is
unrolled inside the scan body with per-slot stacked params.

Decode state is a pytree of per-layer caches stacked on the scan axis; the
decode step scans over layers with the cache as both xs and ys.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.actctx import constrain
from .attention import attn_defs, decode_attention, full_attention
from .layers import mlp_block, mlp_defs, rms_norm, rope_tables
from .moe import moe_block, moe_defs
from .params import P, Tree, tree_map_defs
from .ssm import mamba_block, mamba_decode, mamba_defs

Cache = Any


# ---------------------------------------------------------------------------
# Definitions
# ---------------------------------------------------------------------------

def _slot_kind(cfg: ModelConfig, layer: int) -> Tuple[str, str]:
    """(mixer, ffn) kind for absolute layer index."""
    mixer = "attn" if cfg.is_attn_layer(layer) else "mamba"
    if cfg.d_ff == 0:
        ffn = "none"
    elif cfg.is_moe_layer(layer):
        ffn = "moe"
    else:
        ffn = "mlp"
    return mixer, ffn


def _one_layer_defs(cfg: ModelConfig, mixer: str, ffn: str) -> dict:
    d = cfg.d_model
    defs: dict = {"ln1": P((d,), ("d_model",), "ones")}
    defs[mixer] = attn_defs(cfg) if mixer == "attn" else mamba_defs(cfg)
    if ffn != "none":
        defs["ln2"] = P((d,), ("d_model",), "ones")
        defs[ffn] = mlp_defs(cfg) if ffn == "mlp" else moe_defs(cfg)
    return defs


def _stack(defs: Tree, n: int, axis: str = "layers") -> Tree:
    return tree_map_defs(
        lambda p: P((n,) + p.shape, (axis,) + p.axes, p.init, p.stddev), defs
    )


def stack_defs(cfg: ModelConfig) -> Tree:
    """Layer-stack parameter declaration (see module docstring)."""
    if cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_period
        period = {}
        for s in range(cfg.attn_period):
            mixer, ffn = _slot_kind(cfg, s)
            period[f"slot{s}"] = _one_layer_defs(cfg, mixer, ffn)
        return _stack(period, n_periods, "period")
    mixer, ffn = _slot_kind(cfg, 0)
    return _stack(_one_layer_defs(cfg, mixer, ffn), cfg.n_layers)


def model_defs(cfg: ModelConfig) -> Tree:
    d, v = cfg.d_model, cfg.vocab_size
    defs: Tree = {
        "embed": P((v, d), ("vocab", "d_model")),
        "stack": stack_defs(cfg),
        "ln_f": P((d,), ("d_model",), "ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = P((d, v), ("d_model", "vocab"))
    return defs


# ---------------------------------------------------------------------------
# Layer application (single layer, given its params)
# ---------------------------------------------------------------------------

def _apply_layer_full(
    lp: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rope,
    mixer: str,
    ffn: str,
    collect_state: bool,
):
    """→ (x, aux, state) where state is the layer's cache contribution:
    attn: {"k","v"} over the S positions seen; mamba: {"conv","h"} final."""
    state = None
    x = constrain(x, ("batch", "seq", None))
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    # §Perf it.5 (Megatron blocks): gather the *normed bf16* activation once
    # per block — otherwise GSPMD gathers the f32 pre-norm tensor at every
    # projection einsum (4× the wire bytes, several times per layer).
    h = constrain(h, ("batch", None, None), only_if="megatron_blocks")
    if mixer == "attn":
        y, (k, v) = full_attention(lp["attn"], h, cfg, rope, causal=True)
        if collect_state:
            state = {"k": k, "v": v}
    else:
        if collect_state:
            y, state = mamba_block(lp["mamba"], h, cfg, return_state=True)
        else:
            y = mamba_block(lp["mamba"], h, cfg)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if ffn == "moe":
            y, aux = moe_block(lp["moe"], h, cfg)
        else:
            h = constrain(h, ("batch", None, None), only_if="megatron_blocks")
            y = mlp_block(lp["mlp"], h, cfg)
        x = x + y
    return x, aux, state


def _apply_layer_decode(
    lp: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rope,
    mixer: str,
    ffn: str,
    cache: Dict[str, jax.Array],
    pos: jax.Array,
):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_cache = dict(cache)
    if mixer == "attn":
        y, k_c, v_c = decode_attention(
            lp["attn"], h, cfg, rope, cache["k"], cache["v"], pos
        )
        new_cache["k"], new_cache["v"] = k_c, v_c
    else:
        y, conv_c, h_c = mamba_decode(lp["mamba"], h, cfg, cache["conv"], cache["h"])
        new_cache["conv"], new_cache["h"] = conv_c, h_c
    x = x + y
    if ffn != "none":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if ffn == "moe":
            y, _ = moe_block(lp["moe"], h, cfg)
        else:
            y = mlp_block(lp["mlp"], h, cfg)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------

def apply_stack_full(
    cfg: ModelConfig,
    stack: Tree,
    x: jax.Array,
    rope,
    collect_state: bool = False,
):
    """Full-sequence pass → (x, aux_loss, states_stacked | None)."""
    if not cfg.scan_layers:
        return _apply_stack_full_unrolled(cfg, stack, x, rope, collect_state)

    if cfg.family == "hybrid":
        def body(carry, pp):
            xc, aux = carry
            states = {}
            for s in range(cfg.attn_period):
                mixer, ffn = _slot_kind(cfg, s)
                xc, a, st = _apply_layer_full(
                    pp[f"slot{s}"], xc, cfg, rope, mixer, ffn, collect_state
                )
                aux = aux + a
                if collect_state:
                    states[f"slot{s}"] = st
            return (xc, aux), (states if collect_state else None)

        if cfg.remat and not collect_state:
            body = jax.checkpoint(body)
        with jax.named_scope("scan_layers"):
            (x, aux), states = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), stack
            )
        return x, aux, states

    mixer, ffn = _slot_kind(cfg, 0)

    def body(carry, lp):
        xc, aux = carry
        xc, a, st = _apply_layer_full(lp, xc, cfg, rope, mixer, ffn, collect_state)
        return (xc, aux + a), st

    if cfg.remat and not collect_state:
        body = jax.checkpoint(body)
    with jax.named_scope("scan_layers"):
        (x, aux), states = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
    return x, aux, states


def _index_tree(tree: Tree, i: int) -> Tree:
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _apply_stack_full_unrolled(cfg, stack, x, rope, collect_state):
    """Python-loop layer application (``scan_layers=False``) — used by the
    roofline's FLOP-accounting artifact so every layer's ops appear in the
    HLO exactly once (HLO cost analysis does not multiply loop trip counts).
    Remat is applied per layer so the accounting includes recompute waste,
    matching the scanned training artifact."""
    aux = jnp.zeros((), jnp.float32)
    states = []

    def run_layer(lp, x, mixer, ffn):
        fn = lambda lp, x: _apply_layer_full(
            lp, x, cfg, rope, mixer, ffn, collect_state
        )
        if cfg.remat and not collect_state:
            fn = jax.checkpoint(fn)
        return fn(lp, x)

    if cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_period
        for pi in range(n_periods):
            pp = _index_tree(stack, pi)
            st_p = {}
            for s in range(cfg.attn_period):
                mixer, ffn = _slot_kind(cfg, s)
                x, a, st = run_layer(pp[f"slot{s}"], x, mixer, ffn)
                aux = aux + a
                st_p[f"slot{s}"] = st
            states.append(st_p)
    else:
        mixer, ffn = _slot_kind(cfg, 0)
        for li in range(cfg.n_layers):
            x, a, st = run_layer(_index_tree(stack, li), x, mixer, ffn)
            aux = aux + a
            states.append(st)
    if not collect_state:
        return x, aux, None
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
    return x, aux, stacked


def apply_stack_decode(
    cfg: ModelConfig,
    stack: Tree,
    x: jax.Array,
    rope,
    caches: Cache,
    pos: jax.Array,
):
    """One-token pass threading caches → (x, new_caches)."""
    if not cfg.scan_layers:
        return _apply_stack_decode_unrolled(cfg, stack, x, rope, caches, pos)

    if cfg.family == "hybrid":
        def body(xc, scanned):
            pp, cc = scanned
            new_cc = {}
            for s in range(cfg.attn_period):
                mixer, ffn = _slot_kind(cfg, s)
                xc, nc = _apply_layer_decode(
                    pp[f"slot{s}"], xc, cfg, rope, mixer, ffn, cc[f"slot{s}"], pos
                )
                new_cc[f"slot{s}"] = nc
            return xc, new_cc

        with jax.named_scope("scan_layers"):
            x, new_caches = jax.lax.scan(body, x, (stack, caches))
        return x, new_caches

    mixer, ffn = _slot_kind(cfg, 0)

    def body(xc, scanned):
        lp, cc = scanned
        xc, nc = _apply_layer_decode(lp, xc, cfg, rope, mixer, ffn, cc, pos)
        return xc, nc

    with jax.named_scope("scan_layers"):
        x, new_caches = jax.lax.scan(body, x, (stack, caches))
    return x, new_caches


def _apply_stack_decode_unrolled(cfg, stack, x, rope, caches, pos):
    new_states = []
    if cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_period
        for pi in range(n_periods):
            pp = _index_tree(stack, pi)
            cc = _index_tree(caches, pi)
            new_cc = {}
            for s in range(cfg.attn_period):
                mixer, ffn = _slot_kind(cfg, s)
                x, nc = _apply_layer_decode(
                    pp[f"slot{s}"], x, cfg, rope, mixer, ffn, cc[f"slot{s}"], pos
                )
                new_cc[f"slot{s}"] = nc
            new_states.append(new_cc)
    else:
        mixer, ffn = _slot_kind(cfg, 0)
        for li in range(cfg.n_layers):
            x, nc = _apply_layer_decode(
                _index_tree(stack, li), x, cfg, rope, mixer, ffn,
                _index_tree(caches, li), pos,
            )
            new_states.append(nc)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_states)
    return x, stacked


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _attn_cache_defs(cfg: ModelConfig, batch: int, s_max: int) -> Dict[str, P]:
    hd = cfg.resolved_head_dim
    return {
        "k": P((batch, s_max, cfg.n_kv_heads, hd),
               ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
        "v": P((batch, s_max, cfg.n_kv_heads, hd),
               ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
    }


def _mamba_cache_defs(cfg: ModelConfig, batch: int) -> Dict[str, P]:
    return {
        "conv": P((batch, cfg.ssm_conv - 1, cfg.d_inner),
                  ("batch", None, "d_inner"), "zeros"),
        "h": P((batch, cfg.d_inner, cfg.ssm_state),
               ("batch", "d_inner", "ssm_state"), "zeros"),
    }


def cache_defs(cfg: ModelConfig, batch: int, s_max: int) -> Tree:
    """Declaration of the decode cache pytree (P descriptors, f32 states)."""
    if cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_period
        period = {}
        for s in range(cfg.attn_period):
            mixer, _ = _slot_kind(cfg, s)
            period[f"slot{s}"] = (
                _attn_cache_defs(cfg, batch, s_max)
                if mixer == "attn"
                else _mamba_cache_defs(cfg, batch)
            )
        return _stack(period, n_periods, "period")
    mixer, _ = _slot_kind(cfg, 0)
    one = (
        _attn_cache_defs(cfg, batch, s_max)
        if mixer == "attn"
        else _mamba_cache_defs(cfg, batch)
    )
    return _stack(one, cfg.n_layers)


def cache_dtype(cfg: ModelConfig, path_leaf: str) -> jnp.dtype:
    # mamba ssm state `h` carries f32; kv and conv window follow compute dtype.
    return jnp.float32 if path_leaf == "h" else jnp.dtype(cfg.compute_dtype)
