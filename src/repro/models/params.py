"""Parameter definition machinery.

A model is declared once as a pytree of :class:`P` descriptors (shape +
*logical axis names* + initializer).  Everything else derives from that
single declaration:

* ``init_params``       — real arrays (smoke tests, the e2e example)
* ``abstract_params``   — ``ShapeDtypeStruct`` stand-ins (the dry-run never
  allocates a full-size model)
* ``partition_specs``   — logical axes → mesh ``PartitionSpec`` via the rule
  table in ``repro.distributed.sharding``

Logical axis vocabulary: ``layers period vocab d_model heads kv_heads
head_dim d_ff experts d_inner ssm_state dt_rank conv``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    stddev: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Tree = Any  # nested dict of P / arrays


def tree_map_defs(fn: Callable[[P], Any], defs: Tree) -> Tree:
    return jax.tree_util.tree_map(
        fn, defs, is_leaf=lambda x: isinstance(x, P)
    )


def init_params(defs: Tree, key: jax.Array, dtype: jnp.dtype) -> Tree:
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, P)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        elif p.init == "normal":
            out.append(
                (jax.random.normal(k, p.shape, jnp.float32) * p.stddev).astype(dtype)
            )
        elif p.init == "mamba_a":
            # A_log init: log of 1..N broadcast over channels (mamba1).
            n = p.shape[-1]
            a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), p.shape[:-1] + (1,))
            out.append(a.astype(dtype))
        else:
            raise ValueError(f"unknown init {p.init!r}")
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs: Tree, dtype: jnp.dtype) -> Tree:
    return tree_map_defs(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), defs)


def param_axes(defs: Tree) -> Tree:
    """Same-structure tree of logical-axis tuples."""
    return tree_map_defs(lambda p: p.axes, defs)


def count_params(defs: Tree) -> int:
    leaves = jax.tree_util.tree_leaves(
        tree_map_defs(lambda p: int(np.prod(p.shape)), defs)
    )
    return int(sum(leaves))
