"""Sharded, async, fault-tolerant checkpointing.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, mesh, step
        shard_<host>.npz       # this host's param/optimizer shard payloads
    <root>/LATEST              # atomic pointer (written last)

Properties required at fleet scale:

* **Sharded writes** — each host serializes only the array shards it owns
  (``addressable_shards``), so checkpoint traffic scales with 1/hosts.
* **Async** — ``save()`` snapshots device arrays to host memory, then a
  background thread does the (slow) file/object-store I/O; training resumes
  immediately.  The BASS QoS class for this traffic is Q3 (background) —
  the controller schedules the DCN slots so checkpoint pushes never starve
  gradient sync (``core.qos``).
* **Atomic** — ``LATEST`` is only flipped after every shard landed + fsync;
  a crash mid-write leaves the previous checkpoint intact.
* **Elastic restore** — ``restore()`` reassembles from the manifest onto a
  *possibly different* mesh: global arrays are rebuilt host-shard by
  host-shard and re-sharded via ``jax.device_put`` with the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


def _flat_with_paths(tree: Tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Tree, blocking: bool = False) -> None:
        """Snapshot to host, write in the background (unless blocking)."""
        self.wait()  # one in-flight checkpoint at a time
        host_shards: Dict[str, np.ndarray] = {}
        meta: Dict[str, dict] = {}
        for key, leaf in _flat_with_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == jnp.bfloat16:
                host_shards[key] = arr.view(np.uint16)
                meta[key] = {"shape": list(arr.shape), "dtype": "bfloat16"}
            else:
                host_shards[key] = arr
                meta[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}

        def write():
            d = self.root / f"step_{step:09d}"
            tmp = self.root / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "shard_host0.npz", **host_shards)
            (tmp / "manifest.json").write_text(
                json.dumps({"step": step, "leaves": meta, "hosts": 1})
            )
            if d.exists():
                shutil.rmtree(d)
            os.replace(tmp, d)
            latest_tmp = self.root / ".LATEST.tmp"
            latest_tmp.write_text(d.name)
            os.replace(latest_tmp, self.root / "LATEST")
            self._gc()

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.root.glob("step_*"))
        for old in steps[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = self.root / "LATEST"
        if not ptr.exists():
            return None
        return int(ptr.read_text().strip().split("_")[-1])

    def restore(
        self,
        template: Tree,
        step: Optional[int] = None,
        shardings: Optional[Tree] = None,
    ) -> Tuple[int, Tree]:
        """Rebuild ``template``-shaped tree; re-shard onto ``shardings``."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        payload = np.load(d / "shard_host0.npz")

        flat_t = _flat_with_paths(template)
        flat_s = _flat_with_paths(shardings) if shardings is not None else None
        leaves = []
        for i, (key, tmpl) in enumerate(flat_t):
            raw = payload[key]
            info = manifest["leaves"][key]
            if info["dtype"] == "bfloat16":
                arr = jnp.asarray(raw.view(np.uint16)).view(jnp.bfloat16)
            else:
                arr = jnp.asarray(raw)
            if flat_s is not None:
                arr = jax.device_put(arr, flat_s[i][1])
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
