from .checkpointer import Checkpointer
