"""Unified observability: counters, gauges, spans, traces, snapshots.

See :mod:`repro.obs.registry` for the primitives and DESIGN.md §9 for how
the controller, wavefront, reroute, ledger, device-kernel, and telemetry
layers report through one :meth:`Registry.snapshot`.  stdlib-only — this
package must never import jax (or numpy): it is imported by
``repro.core`` and by the device-kernel module at load time.
"""
from .registry import (
    Counter,
    CounterGroup,
    FlightRecorder,
    Gauge,
    Registry,
    Span,
    default_registry,
)

__all__ = [
    "Counter",
    "CounterGroup",
    "FlightRecorder",
    "Gauge",
    "Registry",
    "Span",
    "default_registry",
]
