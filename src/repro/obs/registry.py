"""Unified observability registry — counters, gauges, spans, flight recorder.

Every layer of the scheduler (controller event loop, wavefront planner,
reroute engine, TS ledger, device kernels, telemetry monitor) used to keep
its own ad-hoc stats dict.  This module gives them one home:

* :class:`Counter` / :class:`Gauge` — single named values.
* :class:`CounterGroup` — a ``MutableMapping[str, int|float]`` over named
  counters.  It is a drop-in replacement for the old plain dicts
  (``group["hits"] += 1``, ``dict(group)``, iteration, ``.get``) so the
  existing call sites and test assertions keep working unchanged.
* :class:`Span` — cumulative wall-clock timing with a context manager.
* :class:`FlightRecorder` — a bounded ring of structured decision events,
  dumpable to JSONL.  Disabled by default so the scheduling hot path pays
  one attribute read per decision.
* :class:`Registry` — the per-controller container with a single
  :meth:`Registry.snapshot` that folds in lazily-evaluated *providers*
  (ledger occupancy, job metrics, kernel compile-cache stats, telemetry
  monitor state) alongside the registered counters.

The module is stdlib-only: importing it (and anything that imports it)
must never pull in jax — ``tests/test_obs.py`` enforces that in a
subprocess.
"""
from __future__ import annotations

import json
import time
from collections import deque
from collections.abc import MutableMapping
from typing import Callable, Dict, Iterable, Iterator, List, Optional


class Counter:
    """A single monotonically-adjustable numeric cell."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: float = 0):
        self.name = name
        self.value = value

    def inc(self, delta: float = 1) -> None:
        self.value += delta

    def set(self, value: float) -> None:
        self.value = value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counter({self.name}={self.value})"


class Gauge:
    """A last-write-wins numeric cell (queue depths, horizon widths...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: float = 0.0):
        self.name = name
        self.value = value

    def set(self, value: float) -> None:
        self.value = value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Gauge({self.name}={self.value})"


class CounterGroup(MutableMapping):
    """Named counters behaving exactly like the stats dicts they replace.

    ``group["x"] += 1`` routes through ``__getitem__``/``__setitem__`` onto
    the underlying :class:`Counter` cells, so code written against the old
    plain-dict stats keeps working, while the registry snapshot sees live
    values.  New keys may be created by assignment, as with a dict.
    """

    __slots__ = ("prefix", "_cells")

    def __init__(self, keys: Iterable[str] = (), prefix: str = ""):
        self.prefix = prefix
        self._cells: Dict[str, Counter] = {
            k: Counter(f"{prefix}.{k}" if prefix else k) for k in keys
        }

    def __getitem__(self, key: str):
        return self._cells[key].value

    def __setitem__(self, key: str, value) -> None:
        cell = self._cells.get(key)
        if cell is None:
            name = f"{self.prefix}.{key}" if self.prefix else key
            self._cells[key] = Counter(name, value)
        else:
            cell.value = value

    def __delitem__(self, key: str) -> None:
        del self._cells[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._cells)

    def __len__(self) -> int:
        return len(self._cells)

    def inc(self, key: str, delta: float = 1) -> None:
        self._cells[key].inc(delta)

    def reset(self) -> None:
        for cell in self._cells.values():
            cell.value = 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"CounterGroup({self.prefix!r}, {dict(self)!r})"


class Span:
    """Cumulative wall-clock timing for a named code region.

    Use as a context manager::

        with obs.span("controller.drain"):
            ...

    ``count`` is the number of completed entries, ``total_s`` the summed
    wall time.  Reentrant use nests naively (each exit adds its own
    elapsed time); the scheduler only uses it non-reentrantly.
    """

    __slots__ = ("name", "count", "total_s", "_t0")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total_s = 0.0
        self._t0 = 0.0

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.total_s += time.perf_counter() - self._t0
        self.count += 1

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Span({self.name}: {self.count}x {self.total_s:.6f}s)"


class FlightRecorder:
    """Bounded ring buffer of structured scheduling-decision events.

    Disabled by default: the scheduling hot path checks ``enabled`` (one
    attribute read) before building the event dict, so an idle recorder
    costs nothing.  When enabled, each :meth:`record` appends a plain dict
    ``{"kind": kind, **fields}``; the ring keeps the most recent
    ``capacity`` events.
    """

    __slots__ = ("enabled", "capacity", "events", "dropped")

    def __init__(self, capacity: int = 4096, enabled: bool = False):
        self.enabled = enabled
        self.capacity = capacity
        self.events: deque = deque(maxlen=capacity)
        self.dropped = 0

    def enable(self) -> "FlightRecorder":
        self.enabled = True
        return self

    def disable(self) -> "FlightRecorder":
        self.enabled = False
        return self

    def record(self, kind: str, **fields) -> None:
        if not self.enabled:
            return
        if len(self.events) == self.capacity:
            self.dropped += 1
        ev = {"kind": kind}
        ev.update(fields)
        self.events.append(ev)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0

    def tail(self, n: int = 50) -> List[dict]:
        return list(self.events)[-n:]

    def dump_jsonl(self, path) -> int:
        """Write the buffered events as JSON Lines; returns the count."""
        with open(path, "w") as fh:
            for ev in self.events:
                fh.write(json.dumps(ev) + "\n")
        return len(self.events)


class Registry:
    """Per-controller container for counters, gauges, spans and the trace.

    ``snapshot()`` is the single machine-readable view: registered scalar
    metrics plus any *provider* sections — zero-argument callables
    evaluated lazily at snapshot time (ledger occupancy, per-job metrics,
    kernel cache stats...).  Provider failures are captured in-place
    rather than propagated, so one broken layer cannot take down the
    whole snapshot.
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._groups: Dict[str, CounterGroup] = {}
        self._spans: Dict[str, Span] = {}
        self._providers: Dict[str, Callable[[], object]] = {}
        self.trace = FlightRecorder()

    # -- construction / lookup ------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def group(self, prefix: str, keys: Iterable[str] = ()) -> CounterGroup:
        g = self._groups.get(prefix)
        if g is None:
            g = self._groups[prefix] = CounterGroup(keys, prefix=prefix)
        return g

    def span(self, name: str) -> Span:
        s = self._spans.get(name)
        if s is None:
            s = self._spans[name] = Span(name)
        return s

    def register_provider(self, name: str, fn: Callable[[], object]) -> None:
        """Attach a lazily-evaluated snapshot section (last write wins)."""
        self._providers[name] = fn

    # -- serialization (controller crash-recovery) ----------------------
    def dump_values(self) -> dict:
        """Plain-data dump of every counter, gauge and group cell for
        controller snapshots (DESIGN.md §11).  Spans and the flight
        recorder are deliberately excluded: they measure wall-clock and
        debugging artifacts of *this* process, not replayable scheduler
        behavior, so recovery equivalence is not defined over them."""
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {n: g.value for n, g in self._gauges.items()},
            "groups": {prefix: dict(g) for prefix, g in self._groups.items()},
        }

    def load_values(self, state: dict) -> None:
        """Restore a :meth:`dump_values` dump in place.

        Writes through :meth:`group`/:meth:`counter`/:meth:`gauge`, so
        cells already registered by the restoring controller's constructor
        are updated rather than duplicated, and later ``group()`` calls
        (e.g. a telemetry monitor re-attaching its stats group) observe the
        restored values.
        """
        for prefix, cells in state["groups"].items():
            g = self.group(prefix)
            for key, value in cells.items():
                g[key] = value
        for name, value in state["counters"].items():
            self.counter(name).value = value
        for name, value in state["gauges"].items():
            self.gauge(name).value = value

    # -- reporting ------------------------------------------------------
    def snapshot(self, trace_tail: int = 200) -> dict:
        counters = {c.name: c.value for c in self._counters.values()}
        for g in self._groups.values():
            for cell in g._cells.values():
                counters[cell.name] = cell.value
        snap: dict = {
            "counters": counters,
            "gauges": {g.name: g.value for g in self._gauges.values()},
            "spans": {
                s.name: {"count": s.count, "total_s": s.total_s}
                for s in self._spans.values()
            },
            "trace": self.trace.tail(trace_tail),
        }
        for name, fn in self._providers.items():
            try:
                snap[name] = fn()
            except Exception as exc:  # one broken layer must not kill the snapshot
                snap[name] = {"error": f"{type(exc).__name__}: {exc}"}
        return snap


_DEFAULT: Optional[Registry] = None


def default_registry() -> Registry:
    """Process-wide registry for module-global stats (device kernels)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Registry()
    return _DEFAULT
