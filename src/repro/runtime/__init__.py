from .ft import HeartbeatMonitor, RestartEvent, TrainSupervisor, elastic_mesh_shape
from .progress import ProgressTracker, TaskProgress
