"""Fault tolerance: heartbeats, failure handling, elastic re-meshing.

The control flow a 1000+-node fleet needs:

1. ``HeartbeatMonitor`` — hosts report liveness; misses ≥ ``grace`` mark a
   host dead (in-process this is driven by the launcher's event loop; on a
   real fleet the reports arrive over the coordinator service).
2. On failure the ``TrainSupervisor`` (a) pauses stepping, (b) rebuilds the
   mesh from the survivors via ``elastic_mesh_shape`` (largest (data×model)
   grid that divides the remaining chip count while keeping the ``model``
   axis intact — TP degree is a property of the checkpoint layout),
   (c) re-lowers the step, (d) restores the latest checkpoint re-sharded
   onto the new mesh, and (e) resumes from the checkpointed step — the data
   pipeline is stateless-addressable so no samples are replayed or skipped.
3. Stragglers (ProgressRate, §V.A) trigger *speculative shard re-dispatch*
   through BASS rather than whole-job restarts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclass
class HostState:
    name: str
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    """Liveness by heartbeat age.

    ``clock`` is the time source consulted whenever a call omits ``now``;
    it defaults to ``time.monotonic`` for the real launcher, but any
    controller integration must inject a *sim-time* clock (see
    ``ClusterController.attach_heartbeats``) — wall-clock sweeps inside a
    discrete-event loop are nondeterministic by construction.
    """

    def __init__(self, hosts: Sequence[str], grace_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        now = clock()
        self.grace_s = grace_s
        self.hosts: Dict[str, HostState] = {
            h: HostState(h, now) for h in hosts
        }

    def beat(self, host: str, now: Optional[float] = None) -> None:
        now = self.clock() if now is None else now
        st = self.hosts[host]
        st.last_beat = now
        st.alive = True

    def revive(self, host: str, now: Optional[float] = None) -> None:
        """Re-admit a recovered host (a beat on a dead host also revives)."""
        self.beat(host, now)

    def suspend_accrual(self, dt: float, now: Optional[float] = None) -> None:
        """Forgive ``dt`` seconds of missed-beat accrual on every live host.

        A dead *controller* hears no heartbeats: when it comes back after a
        ``dt``-second outage, every healthy host looks ``dt`` seconds stale
        and a naive sweep would mass-declare the fleet dead.  Shifting
        ``last_beat`` forward by the outage (capped at ``now`` — a beat
        cannot come from the future) makes the first post-recovery sweep
        judge hosts only on staleness accrued while the controller could
        actually hear them.  Hosts already marked dead stay dead — the
        outage is not evidence of recovery.
        """
        if dt <= 0:
            return
        now = self.clock() if now is None else now
        for st in self.hosts.values():
            if st.alive:
                st.last_beat = min(st.last_beat + dt, now)

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """→ newly-dead hosts."""
        now = self.clock() if now is None else now
        dead = []
        for st in self.hosts.values():
            if st.alive and now - st.last_beat > self.grace_s:
                st.alive = False
                dead.append(st.name)
        return dead

    def alive(self) -> List[str]:
        return [h for h, st in self.hosts.items() if st.alive]


def elastic_mesh_shape(
    n_chips: int, model_axis: int, prefer_pods: Optional[int] = None
) -> Tuple[int, ...]:
    """Largest usable (data, model) grid after losing chips.

    The ``model`` axis is pinned (checkpoint TP layout); we shrink ``data``
    to the largest value with data×model ≤ n_chips.  Returns () if not even
    one model group survives.
    """
    if n_chips < model_axis:
        return ()
    data = n_chips // model_axis
    if prefer_pods and prefer_pods > 1 and data % prefer_pods == 0:
        return (prefer_pods, data // prefer_pods, model_axis)
    return (data, model_axis)


@dataclass
class RestartEvent:
    step: int
    reason: str
    lost_hosts: Tuple[str, ...]
    new_mesh: Tuple[int, ...]


class TrainSupervisor:
    """Deterministic restart policy driven by injected callbacks — unit
    testable without devices; the real launcher wires jax/mesh/checkpoint
    implementations in (see ``launch.train``)."""

    def __init__(
        self,
        monitor: HeartbeatMonitor,
        chips_per_host: int,
        model_axis: int,
        rebuild: Callable[[Tuple[int, ...]], None],
        restore: Callable[[], int],
    ):
        self.monitor = monitor
        self.chips_per_host = chips_per_host
        self.model_axis = model_axis
        self.rebuild = rebuild
        self.restore = restore
        self.events: List[RestartEvent] = []

    def on_tick(self, step: int, now: Optional[float] = None) -> Optional[RestartEvent]:
        dead = self.monitor.sweep(now)
        if not dead:
            return None
        alive = self.monitor.alive()
        shape = elastic_mesh_shape(
            len(alive) * self.chips_per_host, self.model_axis
        )
        if not shape:
            raise RuntimeError(
                f"unrecoverable: {len(alive)} hosts cannot hold one model group"
            )
        self.rebuild(shape)
        restored_step = self.restore()
        ev = RestartEvent(restored_step, "heartbeat-loss", tuple(dead), shape)
        self.events.append(ev)
        return ev
