"""ProgressRate estimation — paper §V.A, verbatim.

``ProgressRate = ProgressScore / T`` (score ∈ [0,1], T = running time) and
``ΥI = (1 − ProgressScore) / ProgressRate`` estimates when a node frees up.
The paper uses it to feed ``ΥI_j`` into BASS; we use it identically for the
data-ingest backlog *and* as the straggler detector: a worker whose
estimated remaining time exceeds ``straggler_factor ×`` the median is
flagged, and its unfinished shards are re-dispatched through BASS Case 2
(locality starvation → best remote with a TS reservation).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class TaskProgress:
    task_id: int
    worker: str
    started_at: float
    score: float = 0.0               # ProgressScore ∈ [0, 1]
    updated_at: float = 0.0


class ProgressTracker:
    def __init__(self, straggler_factor: float = 2.0):
        self.straggler_factor = straggler_factor
        self._tasks: Dict[int, TaskProgress] = {}

    def start(self, task_id: int, worker: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        self._tasks[task_id] = TaskProgress(task_id, worker, now, 0.0, now)

    def update(self, task_id: int, score: float, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        tp = self._tasks[task_id]
        tp.score = min(max(score, 0.0), 1.0)
        tp.updated_at = now

    def finish(self, task_id: int) -> None:
        self._tasks.pop(task_id, None)

    # -- paper formulas -------------------------------------------------------
    def remaining(self, task_id: int, now: Optional[float] = None) -> float:
        """ΥI = (1 − ProgressScore) / ProgressRate."""
        now = time.monotonic() if now is None else now
        tp = self._tasks[task_id]
        t = max(now - tp.started_at, 1e-6)
        rate = tp.score / t
        if rate <= 0:
            return float("inf")
        return (1.0 - tp.score) / rate

    def worker_idle_times(self, now: Optional[float] = None) -> Dict[str, float]:
        """ΥI_j per worker = max remaining over its running tasks."""
        now = time.monotonic() if now is None else now
        out: Dict[str, float] = {}
        for tp in self._tasks.values():
            r = self.remaining(tp.task_id, now)
            out[tp.worker] = max(out.get(tp.worker, 0.0), r)
        return out

    def stragglers(self, now: Optional[float] = None) -> List[int]:
        """Tasks whose estimated remaining time ≫ the median (speculative
        re-execution candidates)."""
        now = time.monotonic() if now is None else now
        rem = {tid: self.remaining(tid, now) for tid in self._tasks}
        finite = [v for v in rem.values() if np.isfinite(v)]
        if len(finite) < 2:
            return []
        med = float(np.median(finite))
        if med <= 0:
            return []
        return [tid for tid, v in rem.items() if v > self.straggler_factor * med]
