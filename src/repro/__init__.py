"""BASS (Qin et al., 2014) reproduced and deployed as the control plane of a
multi-pod JAX training/serving framework.  See README.md and DESIGN.md."""
__version__ = "0.1.0"
