"""LR schedules: linear warmup → cosine decay (the usual pretraining shape)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
