from .adamw import AdamW, AdamWState, global_norm
from .schedule import constant, warmup_cosine

__all__ = ["AdamW", "AdamWState", "constant", "global_norm", "warmup_cosine"]
