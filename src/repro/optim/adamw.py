"""AdamW with decoupled weight decay, f32 state over bf16 params.

Built from scratch (no optax dependency): ``init`` returns (m, v, count),
``update`` consumes grads and returns new params + state.  Moments inherit
the parameter sharding (same tree structure ⇒ same NamedSharding), so the
optimizer adds 8 bytes/param *per shard* (ZeRO-style, since params are FSDP
sharded on d_model).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any


class AdamWState(NamedTuple):
    m: Tree
    v: Tree
    count: jax.Array


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def init(self, params: Tree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def _lr(self, count: jax.Array) -> jax.Array:
        return self.lr(count) if callable(self.lr) else jnp.float32(self.lr)

    def update(
        self, grads: Tree, state: AdamWState, params: Tree
    ) -> Tuple[Tree, AdamWState, jax.Array]:
        """→ (new_params, new_state, global_grad_norm).

        Clip scaling is folded into the per-leaf update (never materializes
        a second full-precision gradient tree — at 123 B params that tree
        is 1.9 GiB *per device*).
        """
        gnorm = global_norm(grads)
        if self.grad_clip is not None:
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        else:
            scale = jnp.float32(1.0)

        count = state.count + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1.0 - self.b1) * g
            v = self.b2 * v + (1.0 - self.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            step = mh / (jnp.sqrt(vh) + self.eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (step + self.weight_decay * pf)
            return pf.astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return new_p, AdamWState(new_m, new_v, count), gnorm


def global_norm(tree: Tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
