"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd wrapper
in ``ops.py``; tests sweep shapes/dtypes in interpret mode on CPU.

Submodules load lazily (PEP 562): ``ts_plan`` is imported by the numpy
scheduling core on every controller start, and must not drag jax in —
``ops``/``ref`` (which import jax at module scope) materialize only when
first touched.
"""
import importlib

__all__ = ["ops", "ref", "ts_plan", "ts_plan_device"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
