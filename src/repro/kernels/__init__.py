"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd wrapper
in ``ops.py``; tests sweep shapes/dtypes in interpret mode on CPU.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
