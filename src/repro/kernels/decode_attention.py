"""Flash-decode — single-query attention against a long KV cache.

Memory-bound by design (arithmetic intensity ≈ 1 flop/byte): the kernel's
job is to stream K/V through VMEM exactly once at full HBM bandwidth.  Grid
``(B, nq, S/bk)`` with the KV axis innermost; the query tile (one token per
batch×head) stays resident in VMEM scratch along with the online-softmax
state.  Positions beyond ``pos`` are masked with a length word passed as a
``[1,1]`` int32 operand (scalar-prefetch/SMEM is the further TPU
refinement; a VMEM scalar keeps interpret and Mosaic paths identical).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30
_LANES = 128


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, bk):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0, 0]

    @pl.when(j * bk <= pos)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)                    # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                       # [1, bk]
        kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kj <= pos, s, NEG_INF)
        m_prev = m_ref[0, 0]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.exp(s - m_new)                                  # [1, bk]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum()
        v = v_ref[0, 0].astype(jnp.float32)                    # [bk, hd]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )                                                       # [1, hd]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.full_like(m_ref, m_new)
        l_ref[...] = jnp.full_like(l_ref, l_new)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[0, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_decode_bhsd(
    q: jax.Array,            # [B, nq, 1, hd]
    k: jax.Array,            # [B, nkv, S, hd]
    v: jax.Array,            # [B, nkv, S, hd]
    pos: jax.Array,          # scalar int32 — last valid position
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, nq, _, hd = q.shape
    nkv, sk = k.shape[1], k.shape[2]
    g = nq // nkv
    bk = min(block_k, sk)
    assert sk % bk == 0, (sk, bk)
    grid = (b, nq, sk // bk)
    scale = 1.0 / (hd ** 0.5)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1, 1))

    kernel = functools.partial(_kernel, scale=scale, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (0, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, _LANES), jnp.float32),
            pltpu.VMEM((1, _LANES), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos_arr, q, k, v)
