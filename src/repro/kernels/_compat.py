"""Pallas-TPU version shims.

``pltpu.CompilerParams`` is the modern spelling; before jax 0.5 the same
dataclass was exported as ``TPUCompilerParams``.  Kernels import the alias
from here so one source tree runs on both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
