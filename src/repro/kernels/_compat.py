"""Pallas-TPU version shims and cached jax platform probes.

``pltpu.CompilerParams`` is the modern spelling; before jax 0.5 the same
dataclass was exported as ``TPUCompilerParams``.  Kernels import the alias
from here so one source tree runs on both.

This module imports jax at module scope — only the device-side kernels
may import it, and only lazily from inside their entry points, so the
numpy scheduling path never pays the jax import.
"""
from typing import Optional

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_default_backend: Optional[str] = None


def default_backend() -> str:
    """``jax.default_backend()``, resolved once per process — the first
    call initializes the platform client, so callers on a hot path must
    not re-derive it per invocation."""
    global _default_backend
    if _default_backend is None:
        import jax

        _default_backend = jax.default_backend()
    return _default_backend


__all__ = ["CompilerParams", "default_backend"]
