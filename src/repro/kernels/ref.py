"""Pure-jnp oracles for every kernel (the ``assert_allclose`` targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,            # [B, nq, Sq, hd]
    k: jax.Array,            # [B, nkv, Sk, hd]
    v: jax.Array,            # [B, nkv, Sk, hd]
    causal: bool = True,
    pos: jax.Array | None = None,
) -> jax.Array:
    b, nq, sq, hd = q.shape
    nkv, sk = k.shape[1], k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, nkv, g, sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) / (hd ** 0.5)
    if causal:
        qi = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
        ki = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        s = jnp.where((ki <= qi)[None, None, None], s, NEG_INF)
    if pos is not None:
        ki = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        s = jnp.where((ki <= pos)[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return out.reshape(b, nq, sq, hd).astype(q.dtype)


def decode_ref(q, k, v, pos):
    """q [B,nq,1,hd] vs cache [B,nkv,S,hd], valid positions ≤ pos."""
    return attention_ref(q, k, v, causal=False, pos=pos)


def mamba_scan_ref(
    x: jax.Array,            # [B, S, d_in] f32
    dt: jax.Array,           # [B, S, d_in] f32
    a: jax.Array,            # [d_in, N] f32
    b_mat: jax.Array,        # [B, S, N] f32
    c_mat: jax.Array,        # [B, S, N] f32
) -> jax.Array:
    bsz, s, d_in = x.shape
    n = a.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * a)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bin,bn->bi", h, ct)
        return h, y

    h0 = jnp.zeros((bsz, d_in, n), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_mat, 1, 0),
        jnp.moveaxis(c_mat, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
