"""Device-side fused planning pipelines and the ledger mirror (jax).

This module is the implementation behind ``ts_plan``'s device backend —
it is only ever imported lazily, from inside ``ts_plan`` entry points or
directly by device-contract tests, so the numpy scheduling path never
pays the jax import.

Three layers live here:

* **Compile cache** (:func:`_cached`): every jitted pipeline is built
  once per *shape bucket* — candidate counts round up to the next power
  of two (≥ 8), window widths arrive already exact (the engines escalate
  in powers of 4) — and reused for the rest of the process.  ``stats``
  counts built buckets (``traces``) vs reuses (``cache_hits``);
  ``bench_sched_scale`` reports the hit rate.

* **Fused float64 pipelines**: residue → bandwidth → sequential-scan
  cumsum → searchsorted, optionally fused with the wavefront plan-end
  extraction (:func:`wave_scan`) and the per-wave winner selection
  (:func:`wave_select`), or with the reroute compressed-column gather
  (:func:`col_scan`).  The cumsum is a ``lax.scan`` — a strict
  sequential accumulation, which together with IEEE-exact elementwise
  ops makes every output **bit-identical to the numpy reference on any
  float64 input**.  Freshly padded input buffers are donated
  (``donate_argnums``); the mirror array is donated only by the
  operations that consume it (reindex/scatter), never by gathers.

* **Ledger mirror** (:class:`DeviceMirror`): a device-resident copy of
  ``TimeSlotLedger.reserved`` kept in step by a journal of cell writes
  (the ledger's mutators call ``note_*`` with *final* cell values), so
  per-wave gathers read device memory instead of re-uploading the
  window.  See DESIGN.md §8 for the sync/invalidation contract.

On a real TPU the float32 Pallas kernel (:func:`pallas_scan`, also
compile-cached and jitted here) services ``plan_scan``; the fused f64
XLA pipelines service every platform, and are what the forced
``pallas`` backend runs off-TPU so that tier-1 parity holds bit-exactly.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import numpy as np

from ..obs import default_registry
from . import ts_plan

EPS = ts_plan.EPS

#: Built buckets / reuses of the compile cache, plus mirror traffic.
#: A live ``repro.obs`` counter group in the process-wide registry —
#: dict-style access (`stats["traces"] += 1`, iteration, ``dict(stats)``)
#: is unchanged from the plain dict it replaced.
stats = default_registry().group(
    "ts_plan_device",
    ("traces", "cache_hits", "mirror_syncs", "mirror_cells", "mirror_uploads"),
)

_cache: dict = {}
_platform: Optional[str] = None
_mirror_flag: Optional[bool] = None


def available() -> bool:
    try:
        import jax  # noqa: F401
    except ImportError:
        return False
    return True


def platform() -> str:
    """The default jax platform, resolved once per process."""
    global _platform
    if _platform is None:
        from ._compat import default_backend

        _platform = default_backend()
    return _platform


def set_mirror(value: Optional[bool]) -> None:
    """Force the ledger mirror on/off (``None`` = re-derive from
    ``REPRO_TS_PLAN_MIRROR`` / platform)."""
    global _mirror_flag
    _mirror_flag = value


def mirror_enabled() -> bool:
    if _mirror_flag is not None:
        return _mirror_flag
    env = os.environ.get("REPRO_TS_PLAN_MIRROR")
    if env is not None:
        return env not in ("", "0")
    # On CPU a device_put is a real copy, so the mirror only pays off
    # where device memory is actually separate (and gathers are fast).
    return platform() != "cpu"


def reset_cache() -> None:
    """Drop compiled buckets and zero the counters (tests/benchmarks)."""
    _cache.clear()
    for k in stats:
        stats[k] = 0


def _cached(key, build):
    fn = _cache.get(key)
    if fn is None:
        fn = _cache[key] = build()
        stats["traces"] += 1
    else:
        stats["cache_hits"] += 1
    return fn


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _x64():
    from jax.experimental import enable_x64

    return enable_x64()


def _seq_cumsum(d):
    """Bit-exact sequential inclusive cumsum along axis 1 (``jnp.cumsum``
    reduces in tree order and is *not* bit-identical to numpy)."""
    import jax
    import jax.numpy as jnp

    def step(c, x):
        c = c + x
        return c, c

    _, cum = jax.lax.scan(step, jnp.zeros(d.shape[0], d.dtype), d.T)
    return cum.T


# -- fused float64 scan pipelines -------------------------------------------


def _scan_tail(bk, cp, sc, szv, cap, has_cap):
    import jax.numpy as jnp

    resid = 1.0 - jnp.max(bk, axis=1)
    bw = resid * cp[:, None]
    if has_cap:
        bw = jnp.minimum(bw, cap)
    cum = _seq_cumsum(bw * sc)
    hit = jnp.sum(cum < (szv - EPS)[:, None], axis=1)
    return resid, bw, cum, hit


def _end_tail(cum, bw, hit, szslot, szv, t0, dur, w):
    import jax.numpy as jnp

    ar = jnp.arange(cum.shape[0])
    hidx = jnp.minimum(hit, w - 1)
    before = jnp.where(hit > 0, cum[ar, jnp.maximum(hit - 1, 0)], 0.0)
    t_in = jnp.maximum(t0, (szslot + hit) * dur)
    end = t_in + (szv - before) / bw[ar, hidx]
    end = jnp.where(hit < w, end, jnp.inf)
    end = jnp.where(szv <= 0, t0, end)
    return end


def _donate():
    # Donating the freshly padded gather buffer saves an allocation on a
    # real device; on CPU jax cannot use np-backed donations and warns.
    return (0,) if platform() != "cpu" else ()


def _build_scan(NP, L, W, has_cap):
    import jax

    def f(bk, cp, sc, szv, cap):
        return _scan_tail(bk, cp, sc, szv, cap, has_cap)

    return jax.jit(f, donate_argnums=_donate())


def _build_wave(NP, WL, W, dur):
    import jax

    def f(bk, cp, sc, szv, szslot, t0):
        resid, bw, cum, hit = _scan_tail(bk, cp, sc, szv, 0.0, False)
        end = _end_tail(cum, bw, hit, szslot, szv, t0, dur, W)
        return resid, bw, cum, hit, end

    return jax.jit(f, donate_argnums=_donate())


def _build_wave_mirror(NP, WL, W, Wb, dur):
    import jax
    import jax.numpy as jnp

    def f(M, padp, off, cp, fs, szv, szslot, t0):
        iota = jnp.arange(W)
        bk = M[padp[:, :, None], off[:, None, None] + iota[None, None, :]]
        sc = jnp.full((NP, W), dur)
        sc = sc.at[:, 0].set(fs)
        resid, bw, cum, hit = _scan_tail(bk, cp, sc, szv, 0.0, False)
        end = _end_tail(cum, bw, hit, szslot, szv, t0, dur, W)
        return resid, bw, cum, hit, end

    return jax.jit(f)  # M is the live mirror: never donated by gathers


def _build_col(NP, WL, Wm, Wb):
    import jax
    import jax.numpy as jnp

    def f(M, padp, colp, cp, sc, szv):
        bk = M[padp[:, :, None], colp[:, None, :]]
        return _scan_tail(bk, cp, sc, szv, 0.0, False)

    return jax.jit(f)


def _build_select(NC, NS):
    import jax
    import jax.numpy as jnp

    def f(end, rank, seg):
        emin = jax.ops.segment_min(
            end, seg, num_segments=NS + 1, indices_are_sorted=True
        )
        tie = end == emin[seg]
        big = jnp.iinfo(rank.dtype).max
        rmin = jax.ops.segment_min(
            jnp.where(tie, rank, big),
            seg,
            num_segments=NS + 1,
            indices_are_sorted=True,
        )
        pos = jnp.arange(NC)
        cand = jnp.where(tie & (rank == rmin[seg]), pos, NC)
        return jax.ops.segment_min(
            cand, seg, num_segments=NS + 1, indices_are_sorted=True
        )[:NS]

    return jax.jit(f)


def _pad64(x, shape, dtype=np.float64):
    return ts_plan._pad_to(np.asarray(x, dtype), shape)


def plan_scan(booked, caps, secs, sizes, bandwidth_cap=None, overlay=None):
    """Fused device scan; bit-identical to ``plan_scan_numpy`` off-TPU
    (float64 pipeline), float64-safe-exact on TPU (Pallas kernel)."""
    if overlay is not None:
        booked = np.maximum(booked, overlay)
    if platform() == "tpu":
        return ts_plan.plan_scan_pallas(booked, caps, secs, sizes, bandwidth_cap)
    n, L, W = booked.shape
    NP = _bucket(n)
    bk = _pad64(booked, (NP, L, W))
    cp = _pad64(caps, (NP,))
    sc = _pad64(secs, (NP, W))
    sz = _pad64(sizes, (NP,))
    has_cap = bandwidth_cap is not None
    fn = _cached(
        ("scan", NP, L, W, has_cap), lambda: _build_scan(NP, L, W, has_cap)
    )
    with _x64():
        resid, bw, cum, hit = fn(
            bk, cp, sc, sz, 0.0 if bandwidth_cap is None else float(bandwidth_cap)
        )
        out = (
            np.asarray(resid)[:n],
            np.asarray(bw)[:n],
            np.asarray(cum)[:n],
            np.asarray(hit)[:n],
        )
    return out


def wave_scan(ledger, pad, caps, sz, t0c, sizes, w, first_secs):
    """Device wave pipeline: mirror gather (when live) → scan → plan-end
    extraction, one fused jit call per shape bucket."""
    dur = float(ledger.slot_duration)
    if platform() == "tpu":
        # f32 kernel path: host gather + Pallas scan + host end extraction.
        booked = ledger.booked_window(pad, sz, w)
        n = len(caps)
        secs = np.full((n, w), dur)
        secs[:, 0] = first_secs
        resid, bw, cum, hit = ts_plan.plan_scan_pallas(booked, caps, secs, sizes)
        end = ts_plan._extract_end(
            dur, t0c, sizes, sz, np.asarray(cum, np.float64),
            np.asarray(bw, np.float64), np.asarray(hit, np.int64), w,
        )
        return resid, bw, cum, hit, end
    n, wl = pad.shape
    NP = _bucket(n)
    padp = ts_plan._pad_to(np.asarray(pad, np.int64), (NP, wl))
    szp = ts_plan._pad_to(np.asarray(sz, np.int64), (NP,))
    t0p = _pad64(t0c, (NP,))
    szvp = _pad64(sizes, (NP,))
    cpp = _pad64(caps, (NP,))
    mir = _mirror_for(ledger)
    if mir is not None:
        ledger._ensure(int(szp.max()) + w - 1)
        mir.sync()
        off = np.maximum(szp - mir.base, 0)  # pad rows clamp to in-bounds
        fsp = _pad64(first_secs, (NP,))
        fn = _cached(
            ("wave_m", NP, wl, w, mir.width, dur),
            lambda: _build_wave_mirror(NP, wl, w, mir.width, dur),
        )
        with _x64():
            resid, bw, cum, hit, end = fn(
                mir.arr, padp, off, cpp, fsp, szvp, szp, t0p
            )
            out = tuple(np.asarray(a)[:n] for a in (resid, bw, cum, hit, end))
        return out
    booked = ledger.booked_window(pad, sz, w)
    bk = _pad64(booked, (NP, wl, w))
    secs = np.full((NP, w), dur)
    secs[:n, 0] = first_secs
    fn = _cached(("wave", NP, wl, w, dur), lambda: _build_wave(NP, wl, w, dur))
    with _x64():
        resid, bw, cum, hit, end = fn(bk, cpp, secs, szvp, szp, t0p)
        out = tuple(np.asarray(a)[:n] for a in (resid, bw, cum, hit, end))
    return out


def col_scan(ledger, pad, cols, caps, secs, sizes):
    """Device compressed-column round for the reroute engine."""
    if platform() == "tpu":
        booked = ledger.reserved[
            pad[:, :, None], (cols - ledger.base_slot)[:, None, :]
        ]
        return ts_plan.plan_scan_pallas(booked, caps, secs, sizes)
    mir = _mirror_for(ledger)
    if mir is None:
        booked = ledger.reserved[
            pad[:, :, None], (cols - ledger.base_slot)[:, None, :]
        ]
        return plan_scan(booked, caps, secs, sizes)
    n, wl = pad.shape
    m = cols.shape[1]
    ledger._ensure(int(cols.max()))
    mir.sync()
    NP = _bucket(n)
    padp = ts_plan._pad_to(np.asarray(pad, np.int64), (NP, wl))
    colp = ts_plan._pad_to(np.asarray(cols - mir.base, np.int64), (NP, m))
    cpp = _pad64(caps, (NP,))
    scp = _pad64(secs, (NP, m))
    szvp = _pad64(sizes, (NP,))
    fn = _cached(
        ("col", NP, wl, m, mir.width), lambda: _build_col(NP, wl, m, mir.width)
    )
    with _x64():
        resid, bw, cum, hit = fn(mir.arr, padp, colp, cpp, scp, szvp)
        out = tuple(np.asarray(a)[:n] for a in (resid, bw, cum, hit))
    return out


def wave_select(
    end: np.ndarray, rank: np.ndarray, counts: Sequence[int]
) -> np.ndarray:
    """Fused per-segment argmin of ``(end, rank)`` — three sorted
    ``segment_min`` passes (min end; min rank among exact-float end ties;
    the unique position carrying both minima).  Exactly the host loop:
    float equality is exact and ranks are unique within a segment."""
    nc = len(end)
    ns = len(counts)
    NC = _bucket(nc)
    NS = _bucket(ns)
    seg = np.full(NC, NS, np.int64)
    seg[:nc] = np.repeat(np.arange(ns, dtype=np.int64), counts)
    ep = np.full(NC, np.inf)
    ep[:nc] = end
    rp = np.full(NC, np.iinfo(np.int64).max, np.int64)
    rp[:nc] = rank
    fn = _cached(("sel", NC, NS), lambda: _build_select(NC, NS))
    with _x64():
        win = np.asarray(fn(ep, rp, seg))[:ns]
    starts = np.zeros(ns, np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    return win - starts


# -- Pallas kernel (float32), compile-cached --------------------------------


def _build_pallas(NP, LP, WP, W, cap, interpret):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from ._compat import CompilerParams

    BN = 8

    def kernel(bk_ref, cp_ref, sc_ref, sz_ref, resid_ref, bw_ref, cum_ref, hit_ref):
        resid = 1.0 - jnp.max(bk_ref[...], axis=1)
        bw = resid * cp_ref[...]
        if cap is not None:
            bw = jnp.minimum(bw, cap)
        cum = bw * sc_ref[...]
        k = 1
        while k < WP:  # Hillis–Steele inclusive prefix sum along the lanes
            shifted = jnp.concatenate(
                [jnp.zeros((BN, k), jnp.float32), cum[:, : WP - k]], axis=1
            )
            cum = cum + shifted
            k *= 2
        lane = jax.lax.broadcasted_iota(jnp.int32, (BN, WP), 1)
        below = (cum < (sz_ref[...] - np.float32(EPS))) & (lane < W)
        resid_ref[...] = resid
        bw_ref[...] = bw
        cum_ref[...] = cum
        hit_ref[...] = jnp.sum(below.astype(jnp.int32), axis=1, keepdims=True)

    call = pl.pallas_call(
        kernel,
        grid=(NP // BN,),
        in_specs=[
            pl.BlockSpec((BN, LP, WP), lambda i: (i, 0, 0)),
            pl.BlockSpec((BN, 1), lambda i: (i, 0)),
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NP, WP), jnp.float32),
            jax.ShapeDtypeStruct((NP, WP), jnp.float32),
            jax.ShapeDtypeStruct((NP, WP), jnp.float32),
            jax.ShapeDtypeStruct((NP, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )
    # jit so each bucket traces once (interpret mode re-runs the python
    # kernel body per call otherwise — the dominant per-call cost).
    return jax.jit(call)


def pallas_scan(booked, caps, secs, sizes, bandwidth_cap, interpret):
    """Padded, compile-cached entry behind ``ts_plan.plan_scan_pallas``.
    ``bandwidth_cap`` is baked into the kernel body as a static constant,
    so its value is part of the cache key."""
    n, L, W = booked.shape
    BN, LP = 8, max(8, L)
    WP = max(128, -(-W // 128) * 128)
    NP = -(-n // BN) * BN
    bk = ts_plan._pad_to(np.asarray(booked, np.float32), (NP, LP, WP))
    cp = ts_plan._pad_to(np.asarray(caps, np.float32)[:, None], (NP, 1))
    sc = ts_plan._pad_to(np.asarray(secs, np.float32), (NP, WP))
    sz = ts_plan._pad_to(np.asarray(sizes, np.float32)[:, None], (NP, 1))
    cap = None if bandwidth_cap is None else float(bandwidth_cap)
    fn = _cached(
        ("pallas", NP, LP, WP, W, cap, bool(interpret)),
        lambda: _build_pallas(NP, LP, WP, W, cap, interpret),
    )
    resid, bw, cum, hit = fn(bk, cp, sc, sz)
    return (
        np.asarray(resid)[:n, :W],
        np.asarray(bw)[:n, :W],
        np.asarray(cum)[:n, :W],
        np.asarray(hit)[:n, 0],
    )


# -- device-resident ledger mirror ------------------------------------------


def _build_reindex(Win, Wb):
    import jax
    import jax.numpy as jnp

    def f(a, drop):
        return jnp.take(
            a, drop + jnp.arange(Wb), axis=1, mode="fill", fill_value=0.0
        )

    # The old mirror array is consumed here: donate it (off-CPU).
    return jax.jit(f, donate_argnums=_donate())


def _build_scatter(Wb, K):
    import jax

    def f(a, r, c, v):
        return a.at[r, c].set(v, mode="drop")

    # The old mirror array is consumed here: donate it (off-CPU).
    return jax.jit(f, donate_argnums=_donate())


def _mirror_for(ledger):
    if not mirror_enabled():
        return None
    return ledger.device_mirror()


class DeviceMirror:
    """Device-resident copy of a ledger's live ``reserved`` window.

    The ledger's mutators journal every cell write (``note_flat`` /
    ``note_grid``) with the *final* post-clamp value; :meth:`sync` folds
    the journal into the device array with one keep-last dedup and one
    donated scatter, re-basing for origin shifts (DESIGN.md §7) with a
    donated ``take``.  Direct writes that bypass the mutators must call
    :meth:`invalidate` (``TimeSlotLedger.mirror_invalidate``) — the next
    sync then re-uploads the full window.  See DESIGN.md §8.
    """

    def __init__(self, ledger):
        self._ledger = ledger
        self._arr = None
        self._base = 0
        self._width = 0  # device width (pow-2 bucket of the ledger width)
        self._rows: list = []
        self._slots: list = []
        self._vals: list = []
        self._cells = 0
        self._stale = True

    @property
    def base(self) -> int:
        return self._base

    @property
    def width(self) -> int:
        return self._width

    @property
    def arr(self):
        return self._arr

    # -- journal hooks (ledger mutators; slots are absolute) ----------------
    def note_flat(self, rows, slots, vals) -> None:
        if self._stale:
            return
        rows = np.asarray(rows, np.int64).ravel()
        self._rows.append(rows)
        self._slots.append(np.asarray(slots, np.int64).ravel())
        self._vals.append(np.asarray(vals, np.float64).ravel())
        self._cells += rows.size
        # Pressure valve: past a quarter of the window, one upload is
        # cheaper than the journal bookkeeping.
        if self._cells * 4 > self._ledger.reserved.size:
            self.invalidate()

    def note_grid(self, rows, slots, vals) -> None:
        """An outer-product write: ``reserved[rows][:, slots] = vals``
        with ``vals`` of shape ``[len(rows), len(slots)]``."""
        if self._stale:
            return
        rows = np.asarray(rows, np.int64).ravel()
        slots = np.asarray(slots, np.int64).ravel()
        self.note_flat(
            np.repeat(rows, slots.size),
            np.tile(slots, rows.size),
            np.asarray(vals, np.float64).ravel(),
        )

    def invalidate(self) -> None:
        self._rows.clear()
        self._slots.clear()
        self._vals.clear()
        self._cells = 0
        self._stale = True

    # -- sync ---------------------------------------------------------------
    def sync(self) -> None:
        """Bring the device window up to date with the ledger (journal
        replay, or full re-upload after invalidation / shrink)."""
        import jax  # noqa: F401

        led = self._ledger
        res = led.reserved
        nrows, W = res.shape
        base = led.base_slot
        Wb = _bucket(W, 256)
        stats["mirror_syncs"] += 1
        if (
            self._stale
            or self._arr is None
            or Wb < self._width
            or self._arr.shape[0] != nrows
            or base < self._base
        ):
            self._upload(res, Wb, base)
            return
        arr = self._arr
        if base != self._base or Wb != self._width:
            drop = base - self._base
            fn = _cached(
                ("reidx", self._width, Wb),
                lambda: _build_reindex(self._width, Wb),
            )
            with _x64():
                arr = fn(arr, np.int64(drop))
        if self._rows:
            rows = np.concatenate(self._rows)
            cc = np.concatenate(self._slots) - base
            vals = np.concatenate(self._vals)
            keep = cc >= 0  # retired cells fell off the window
            if not keep.all():
                rows, cc, vals = rows[keep], cc[keep], vals[keep]
            if rows.size:
                # Keep-last dedup: the journal holds final values, so the
                # latest note for a cell wins.
                keys = rows * np.int64(Wb) + cc
                _u, idx = np.unique(keys[::-1], return_index=True)
                sel = keys.size - 1 - idx
                K = _bucket(sel.size, 64)
                rp = np.zeros(K, np.int64)
                cp = np.full(K, Wb, np.int64)  # pad cols drop in-scatter
                vp = np.zeros(K, np.float64)
                rp[: sel.size] = rows[sel]
                cp[: sel.size] = cc[sel]
                vp[: sel.size] = vals[sel]
                fn = _cached(
                    ("scat", Wb, K), lambda: _build_scatter(Wb, K)
                )
                with _x64():
                    arr = fn(arr, rp, cp, vp)
                stats["mirror_cells"] += int(sel.size)
            self._rows.clear()
            self._slots.clear()
            self._vals.clear()
            self._cells = 0
        self._arr = arr
        self._base = base
        self._width = Wb

    def _upload(self, res, Wb, base) -> None:
        import jax

        buf = np.zeros((res.shape[0], Wb))
        buf[:, : res.shape[1]] = res
        with _x64():
            self._arr = jax.device_put(buf)
        self._base = base
        self._width = Wb
        self._rows.clear()
        self._slots.clear()
        self._vals.clear()
        self._cells = 0
        self._stale = False
        stats["mirror_uploads"] += 1

    def host_view(self) -> np.ndarray:
        """Host copy of the device window, trimmed to the ledger width
        (test hook: must equal ``ledger.reserved`` after ``sync``)."""
        W = self._ledger.reserved.shape[1]
        return np.asarray(self._arr)[:, :W]
