"""Jit'd public wrappers around the Pallas kernels.

The model code calls these with model-native layouts ([B, S, H, hd]); the
wrappers transpose to the kernels' [B, H, S, hd] blocked layout, pick block
sizes, and default ``interpret`` to True off-TPU so the same call sites work
on CPU (tests) and TPU (production).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .decode_attention import flash_decode_bhsd
from .flash_attention import flash_attention_bhsd
from .mamba_scan import mamba_scan_blocked


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,            # [B, S, nq, hd]
    k: jax.Array,            # [B, S, nkv, hd]
    v: jax.Array,            # [B, S, nkv, hd]
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = _default_interpret() if interpret is None else interpret
    out = flash_attention_bhsd(
        jnp.swapaxes(q, 1, 2),
        jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2),
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(
    q: jax.Array,            # [B, 1, nq, hd]
    k_cache: jax.Array,      # [B, S, nkv, hd]
    v_cache: jax.Array,      # [B, S, nkv, hd]
    pos: jax.Array,          # scalar int32
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = _default_interpret() if interpret is None else interpret
    out = flash_decode_bhsd(
        jnp.swapaxes(q, 1, 2),
        jnp.swapaxes(k_cache, 1, 2),
        jnp.swapaxes(v_cache, 1, 2),
        pos,
        block_k=block_k,
        interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def mamba_scan(
    x: jax.Array,            # [B, S, d_in] f32
    dt: jax.Array,
    a: jax.Array,            # [d_in, N] f32
    b_mat: jax.Array,        # [B, S, N]
    c_mat: jax.Array,
    block_d: int = 512,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = _default_interpret() if interpret is None else interpret
    d_in, s = x.shape[-1], x.shape[1]
    bd = block_d
    while d_in % bd:
        bd //= 2
    ck = chunk
    while s % ck:
        ck //= 2
    return mamba_scan_blocked(
        x, dt, a, b_mat, c_mat, block_d=bd, chunk=ck, interpret=interpret
    )
