"""Fused causal GQA flash attention — Pallas TPU kernel.

Grid ``(B, n_q_heads, S/bq, S/bk)`` with the key axis innermost (sequential);
online-softmax state (m, l, acc) lives in f32 VMEM scratch that persists
across the key axis.  GQA is free: the k/v BlockSpec index maps query head
``h`` to kv head ``h // group`` — no materialized head expansion.  Block
shapes keep the MXU dims at multiples of 128 (q/k tiles × head_dim) and the
working set ≈ (bq + 2·bk) · hd · 4 B + bq·bk·4 B ≤ a few MB of VMEM.

Causal blocks strictly above the diagonal are skipped via ``pl.when`` — with
bq = bk this halves the compute relative to a dense sweep.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30
_LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, bq, bk, causal):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (j * bk < (i + 1) * bq) if causal else (j >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                    # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                       # [bq, bk]
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_ref[:, 0]                                    # [bq]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])                         # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                         # [bq]
        l_new = alpha * l_prev + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)                    # [bk, hd]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,            # [B, nq, S, hd]
    k: jax.Array,            # [B, nkv, S, hd]
    v: jax.Array,            # [B, nkv, S, hd]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, nq, sq, hd = q.shape
    nkv, sk = k.shape[1], k.shape[2]
    assert nq % nkv == 0, (nq, nkv)
    g = nq // nkv
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    grid = (b, nq, sq // bq, sk // bk)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, bq=bq, bk=bk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
