"""Chunked selective-scan (mamba1) — Pallas TPU kernel.

Layout puts ``d_inner`` on the lane axis and the small state dim ``N`` on
sublanes: the recurrent state ``h`` is an ``[N, bd]`` f32 VMEM scratch that
persists across the sequential chunk axis.  Grid ``(B, d_inner/bd, S/ck)``
— batch and channel blocks are embarrassingly parallel (the recurrence only
couples time), chunks run in order carrying ``h``.

Per time step inside a chunk (vector ops only, no MXU):
    h   = exp(Δ_t ⊗ A) ⊙ h + (Δ_t x_t) ⊗ B_t
    y_t = Σ_n C_t[n] · h[n, :]
VMEM working set ≈ (3·ck·bd + 2·ck·N + 2·N·bd) · 4 B — with ck = 256,
bd = 512 that is ~1.7 MB, well inside a v5e core's 16 MB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *, ck):
    t0 = pl.program_id(2)

    @pl.when(t0 == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]                                           # [N, bd] f32

    def step(t, h):
        xt = x_ref[0, t]                                     # [bd]
        dtt = dt_ref[0, t]                                   # [bd]
        bt = b_ref[0, t]                                     # [N]
        ct = c_ref[0, t]                                     # [N]
        da = jnp.exp(dtt[None, :] * a)                       # [N, bd]
        h = da * h + bt[:, None] * (dtt * xt)[None, :]
        y_ref[0, t] = (h * ct[:, None]).sum(axis=0)
        return h

    h_ref[...] = jax.lax.fori_loop(0, ck, step, h_ref[...])


def mamba_scan_blocked(
    x: jax.Array,            # [B, S, d_in] f32 (post-conv, silu'd)
    dt: jax.Array,           # [B, S, d_in] f32
    a: jax.Array,            # [d_in, N] f32 (negative)
    b_mat: jax.Array,        # [B, S, N] f32
    c_mat: jax.Array,        # [B, S, N] f32
    *,
    block_d: int = 512,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    bsz, s, d_in = x.shape
    n = a.shape[-1]
    bd = min(block_d, d_in)
    ck = min(chunk, s)
    assert d_in % bd == 0 and s % ck == 0, (d_in, bd, s, ck)
    a_t = a.T                                                # [N, d_in]
    grid = (bsz, d_in // bd, s // ck)

    kernel = functools.partial(_kernel, ck=ck)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, ck, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((n, bd), lambda b, d, t: (0, d)),
            pl.BlockSpec((1, ck, n), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, ck, n), lambda b, d, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, ck, bd), lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d_in), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, a_t, b_mat, c_mat)
