"""Time-slot planning scan — the ledger/wavefront inner kernel.

The greedy paper-policy transfer plan (``TimeSlotLedger.plan_transfer``)
reduces, per candidate path, to a fixed four-step scan over a slot window:

1. **residue**   ``resid = 1 - max over path links of booked``  (path residue
   per slot — the "cummax" over the link axis of the gathered window),
2. **bandwidth** ``bw = resid * bottleneck_capacity`` (optionally capped),
3. **cumsum**    ``cum = cumsum(bw * secs)`` (cumulative deliverable,
   first slot possibly partial),
4. **searchsorted** ``hit = #{j : cum[j] < size - EPS}`` (first slot at
   which the transfer completes; ``hit == W`` means "does not fit").

:func:`plan_scan` runs that scan for *every* candidate in one array pass
over a ``[n_cand, n_links_padded, window]`` gather of the ledger; the
fused entry points :func:`wave_scan` (gather → scan → plan-end extraction
→ winner selection, the wavefront engine's per-wave pipeline) and
:func:`col_scan` (compressed-column gather → scan, the reroute engine's
escalation rounds) extend the same contract to whole pipelines.

**Backends.**

* ``numpy`` (the **reference**): bit-identical to a ``plan_transfer``
  loop — ``repro.core`` relies on this for the paper-semantics guarantee,
  and every other backend is property-tested against it.
* ``pallas`` (the **device** backend, forced): a shape-bucketed,
  compile-cached jax pipeline (``ts_plan_device``).  Off-TPU it runs the
  fused float64 XLA pipeline (``lax.scan`` sequential cumsum), which is
  **bit-identical to numpy on any input** — f64 add/mul/div/max are
  exactly rounded and evaluated in the same order.  On TPU it runs the
  float32 Pallas kernel (Hillis–Steele prefix sum), which agrees bit-wise
  on *float64-safe* inputs — values and intermediates exactly
  representable at both precisions (dyadic fractions of moderate
  magnitude, pow-2 capacities, integer sizes); under exact arithmetic the
  summation-order difference between sequential and tree prefix sums
  vanishes.  ``tests/test_wavefront.py`` and
  ``tests/test_ts_plan_device.py`` pin both contracts in interpret mode.
* ``auto`` (the **default**): resolves lazily, and only once a call is
  large enough (≥ ``_AUTO_PROBE_CELLS`` cells) to possibly justify a
  device round-trip — smaller calls answer through numpy without ever
  importing jax.  When a non-CPU jax backend is present the device
  pipeline becomes the default; on CPU the reference numpy kernel stays
  (XLA-on-one-socket cannot beat it), unless
  ``REPRO_TS_PLAN_AUTO_CELLS=<n>`` opts calls of ≥ n cells in.  With no
  importable jax, ``auto`` degrades to ``numpy`` silently; ``pallas``
  raises at first use.

Select with ``set_backend(...)`` or ``REPRO_TS_PLAN_BACKEND=...``.
``REPRO_TS_PLAN_MIRROR=1/0`` forces the device-resident ledger mirror on
or off (default: on for non-CPU platforms — see DESIGN.md §8), and
``REPRO_TS_PLAN_INTERPRET=1/0`` pins the Pallas kernel's interpret mode
(default: interpret off-TPU).

Both backends are **origin-free**: ``booked`` arrives as an already-
gathered window (or absolute slots translated against ``base_slot`` right
at the gather), so the rolling-horizon coordinate map (DESIGN.md §7) is
applied entirely by the callers, and a compacted ledger feeds
bit-identical windows to either backend.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import numpy as np

EPS = 1e-9  # must equal repro.core.timeslot._EPS


def _hit_count(cum: np.ndarray, sizes: np.ndarray) -> np.ndarray:
    """``hit[k] = #{j : cum[k, j] < sizes[k] - EPS}`` — searchsorted-left
    on each row.  Rows are nondecreasing by construction (reserved
    fractions ≤ 1 ⇒ ``bw ≥ 0``, and ``secs ≥ 0``), so a per-row binary
    search returns the identical count; it wins when the batch is a few
    long rows (escalated windows), while the vectorized count wins when
    the batch is wide and the rows short (wave scans).  A regression test
    pins the two bit-identical on both regimes."""
    n, w = cum.shape
    targets = sizes - EPS
    if n * 8 <= w:
        out = np.empty(n, dtype=np.int64)
        for k in range(n):
            out[k] = np.searchsorted(cum[k], targets[k])
        return out
    return (cum < targets[:, None]).sum(axis=1)


def plan_scan_numpy(
    booked: np.ndarray,        # [n_cand, L, W] reserved fractions (gathered)
    caps: np.ndarray,          # [n_cand] bottleneck capacity per candidate
    secs: np.ndarray,          # [n_cand, W] usable seconds per slot
    sizes: np.ndarray,         # [n_cand] bytes (capacity-units·sec) to move
    bandwidth_cap: Optional[float] = None,
    overlay: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Reference scan; row ``k`` is bit-identical to ``plan_transfer`` run
    on candidate ``k`` alone (same expressions, numpy ``cumsum`` is a
    sequential accumulation per row).

    ``overlay`` (same shape as ``booked``, or broadcastable) is an extra
    reserved-fraction layer folded in as an elementwise max — a masked
    scan for callers that want cells priced as busier than the ledger
    records without mutating it (liveness masks, what-if overlays).
    ``max`` is exact in floating point, so an overlay of 0/1 cells
    reproduces the overlaid ledger bit-for-bit.  (The reroute engine
    ultimately prices its phantom-full view by *enumerating* only
    owner-clean columns — see ``core/reroute.py`` — so nothing in the
    scheduling core depends on this parameter; it is contract-tested on
    both backends.)
    """
    if overlay is not None:
        booked = np.maximum(booked, overlay)
    resid = 1.0 - booked.max(axis=1)
    bw = resid * caps[:, None]
    if bandwidth_cap is not None:
        bw = np.minimum(bw, bandwidth_cap)
    cum = np.cumsum(bw * secs, axis=1)
    hit = _hit_count(cum, sizes)
    return resid, bw, cum, hit


def _pad_to(x: np.ndarray, shape) -> np.ndarray:
    if tuple(x.shape) == tuple(shape):
        return x  # already aligned: no copy
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    return np.pad(x, pads)


# -- Pallas kernel interpret mode (cached once per process) ------------------

_INTERPRET: Optional[bool] = None


def set_interpret(value: Optional[bool]) -> None:
    """Pin the Pallas kernel's interpret mode (``None`` = re-derive from
    the jax backend / ``REPRO_TS_PLAN_INTERPRET`` on next use)."""
    global _INTERPRET
    _INTERPRET = value


def _interpret_default() -> bool:
    # jax.default_backend() initializes the platform client — not free,
    # so the answer is resolved once per process instead of per call.
    global _INTERPRET
    if _INTERPRET is None:
        env = os.environ.get("REPRO_TS_PLAN_INTERPRET")
        if env is not None:
            _INTERPRET = env not in ("", "0")
        else:
            from ._compat import default_backend

            _INTERPRET = default_backend() != "tpu"
    return _INTERPRET


def plan_scan_pallas(
    booked: np.ndarray,
    caps: np.ndarray,
    secs: np.ndarray,
    sizes: np.ndarray,
    bandwidth_cap: Optional[float] = None,
    overlay: Optional[np.ndarray] = None,
    interpret: Optional[bool] = None,
):
    """Pallas-TPU kernel (float32).  Agrees with :func:`plan_scan_numpy`
    bit-wise on float64-safe inputs (module docstring); lazy jax import so
    the numpy scheduling path never touches jax.  Each padded
    ``(NP, LP, WP)`` shape bucket lowers and compiles **once** (the
    ``ts_plan_device`` compile cache) and the interpret default is cached
    module-level; the ``overlay`` layer is folded in on the host (one
    exact elementwise max) — it feeds the same padded gather, so the
    kernel body is unchanged."""
    from . import ts_plan_device

    if interpret is None:
        interpret = _interpret_default()
    if overlay is not None:
        booked = np.maximum(booked, overlay)
    return ts_plan_device.pallas_scan(
        booked, caps, secs, sizes, bandwidth_cap, interpret
    )


# -- backend selection -------------------------------------------------------

_VALID_BACKENDS = ("numpy", "pallas", "auto")
_backend = os.environ.get("REPRO_TS_PLAN_BACKEND", "auto")

#: ``auto`` probes jax only once a call is big enough to possibly justify
#: a device round-trip; smaller calls answer through numpy without ever
#: importing jax (keeps the PEP 562 laziness of ``repro.kernels``).
_AUTO_PROBE_CELLS = 1 << 15
_auto: Optional[Tuple[bool, int]] = None  # (use device?, min cells)


def set_backend(name: str) -> None:
    if name not in _VALID_BACKENDS:
        raise ValueError(
            f"unknown ts_plan backend {name!r} (want {sorted(_VALID_BACKENDS)})"
        )
    global _backend
    _backend = name


def get_backend() -> str:
    return _backend


def _resolve_auto() -> Tuple[bool, int]:
    try:
        from . import ts_plan_device

        plat = ts_plan_device.platform()
    except Exception:  # noqa: BLE001 — no jax: auto degrades to numpy
        return (False, 0)
    if plat != "cpu":
        return (True, 0)
    env = os.environ.get("REPRO_TS_PLAN_AUTO_CELLS")
    if env:
        return (True, int(env))
    # XLA on the host CPU cannot beat the numpy kernel it would stand in
    # for: the reference stays the default off-accelerator.
    return (False, 0)


def _use_device(cells: int) -> bool:
    if _backend == "numpy":
        return False
    if _backend == "pallas":
        return True
    global _auto
    if _auto is None:
        if cells < _AUTO_PROBE_CELLS:
            return False
        _auto = _resolve_auto()
    dev, floor = _auto
    return dev and cells >= floor


def device_stats() -> dict:
    """Compile-cache / mirror counters of the device backend (empty when
    it was never engaged) — reported by ``bench_sched_scale``."""
    import sys

    mod = sys.modules.get(__package__ + ".ts_plan_device")
    return dict(mod.stats) if mod is not None else {}


def plan_scan(booked, caps, secs, sizes, bandwidth_cap=None, overlay=None):
    """Dispatch to the selected backend (module docstring: the auto rule)."""
    if _use_device(booked.size):
        from . import ts_plan_device

        return ts_plan_device.plan_scan(
            booked, caps, secs, sizes, bandwidth_cap, overlay
        )
    return plan_scan_numpy(booked, caps, secs, sizes, bandwidth_cap, overlay)


# -- fused pipelines ---------------------------------------------------------


def _extract_end(dur, t0c, sizes, sz, cum, bw, hit, w):
    """Plan-end extraction from scan curves — the exact tail arithmetic of
    ``plan_transfer`` vectorized over candidates (``end = t_in +
    remaining / bw[hit]``; unfit rows → inf, empty transfers → t0)."""
    n = len(sizes)
    ar = np.arange(n)
    hidx = np.minimum(hit, w - 1)
    before = np.where(hit > 0, cum[ar, np.maximum(hit - 1, 0)], 0.0)
    t_in = np.maximum(t0c, (sz + hit) * dur)
    with np.errstate(divide="ignore", invalid="ignore"):
        end = t_in + (sizes - before) / bw[ar, hidx]
    end = np.where(hit < w, end, np.inf)
    end = np.where(sizes <= 0, t0c, end)
    return end


def wave_scan_numpy(ledger, pad, caps, sz, t0c, sizes, w, first_secs):
    """Reference wave pipeline: host gather (``booked_window``) → scan →
    plan-end extraction.  ``sz`` is the per-candidate (frontier-skipped)
    absolute scan-base slot, ``first_secs`` the usable seconds of each
    candidate's first scanned slot."""
    booked = ledger.booked_window(pad, sz, w)
    n = len(caps)
    secs = np.full((n, w), ledger.slot_duration)
    secs[:, 0] = first_secs
    resid, bw, cum, hit = plan_scan_numpy(booked, caps, secs, sizes)
    end = _extract_end(ledger.slot_duration, t0c, sizes, sz, cum, bw, hit, w)
    return resid, bw, cum, hit, end


def wave_scan(ledger, pad, caps, sz, t0c, sizes, w, first_secs):
    """The wavefront engine's fused per-wave pipeline: gather the
    ``[n_cand, L, w]`` window (device-side from the ledger mirror when one
    is live), scan, and extract plan ends — one call per wave.  Returns
    ``(resid, bw, cum, hit, end)``, bit-identical across backends."""
    if _use_device(pad.shape[0] * pad.shape[1] * w):
        from . import ts_plan_device

        return ts_plan_device.wave_scan(
            ledger, pad, caps, sz, t0c, sizes, w, first_secs
        )
    return wave_scan_numpy(ledger, pad, caps, sz, t0c, sizes, w, first_secs)


def col_scan(ledger, pad, cols, caps, secs, sizes):
    """The reroute engine's compressed-column round: gather each
    candidate's collected joint columns (``cols`` holds *absolute* slots)
    and scan.  Device path gathers from the ledger mirror; the numpy path
    is the reference gather expression, bit for bit."""
    if _use_device(pad.shape[0] * pad.shape[1] * cols.shape[1]):
        from . import ts_plan_device

        return ts_plan_device.col_scan(ledger, pad, cols, caps, secs, sizes)
    booked = ledger.reserved[
        pad[:, :, None], (cols - ledger.base_slot)[:, None, :]
    ]
    return plan_scan_numpy(booked, caps, secs, sizes)


def wave_select_numpy(
    end: np.ndarray, rank: np.ndarray, counts: Sequence[int]
) -> np.ndarray:
    """Per-segment argmin of ``(end, rank)`` — the host winner loop.
    ``rank`` is each candidate's precomputed position in its segment's
    tie-break order, so minimizing ``(end, rank)`` equals minimizing the
    scorer's full lexicographic key ``(end, hops, src, index)`` exactly
    (float equality is exact; ranks are unique within a segment).
    Returns the winner's *local* index per segment."""
    out = np.empty(len(counts), dtype=np.int64)
    pos = 0
    for s, cnt in enumerate(counts):
        best = pos
        for c in range(pos + 1, pos + cnt):
            if end[c] < end[best] or (
                end[c] == end[best] and rank[c] < rank[best]
            ):
                best = c
        out[s] = best - pos
        pos += cnt
    return out


def wave_select(
    end: np.ndarray, rank: np.ndarray, counts: Sequence[int]
) -> np.ndarray:
    """Winner selection over a wave's candidate segments — fused on
    device (three ``segment_min`` passes) when the device backend is
    forced, the host loop otherwise; tie-breaking parity is
    contract-tested."""
    if _use_device(len(end)):
        from . import ts_plan_device

        return ts_plan_device.wave_select(end, rank, counts)
    return wave_select_numpy(end, rank, counts)
