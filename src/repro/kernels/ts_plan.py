"""Time-slot planning scan — the ledger/wavefront inner kernel.

The greedy paper-policy transfer plan (``TimeSlotLedger.plan_transfer``)
reduces, per candidate path, to a fixed four-step scan over a slot window:

1. **residue**   ``resid = 1 - max over path links of booked``  (path residue
   per slot — the "cummax" over the link axis of the gathered window),
2. **bandwidth** ``bw = resid * bottleneck_capacity`` (optionally capped),
3. **cumsum**    ``cum = cumsum(bw * secs)`` (cumulative deliverable,
   first slot possibly partial),
4. **searchsorted** ``hit = #{j : cum[j] < size - EPS}`` (first slot at
   which the transfer completes; ``hit == W`` means "does not fit").

:func:`plan_scan` runs that scan for *every* candidate in one array pass
over a ``[n_cand, n_links_padded, window]`` gather of the ledger.  Two
backends exist:

* ``numpy`` (default, the **reference**): bit-identical to a
  ``plan_transfer`` loop — ``repro.core`` relies on this for the
  paper-semantics guarantee, so it stays the default everywhere.
* ``pallas``: a JAX/Pallas TPU kernel (float32, Hillis–Steele prefix sum)
  for fleet-scale controllers co-located with accelerators.  Backends
  **agree bit-wise on float64-safe inputs** — inputs whose values and all
  intermediates are exactly representable at both precisions (dyadic
  fractions of moderate magnitude, e.g. ledger fractions in 1/2^k, pow-2
  capacities, integer sizes); under exact arithmetic the summation-order
  difference between sequential and tree prefix sums vanishes.
  ``tests/test_wavefront.py`` pins this contract in interpret mode.

Select with ``set_backend("pallas")`` or ``REPRO_TS_PLAN_BACKEND=pallas``.

Both backends are **origin-free**: ``booked`` arrives as an already-
gathered window, so the rolling-horizon coordinate map (the ledger's
``base_slot`` origin, DESIGN.md §7) is applied entirely by the callers —
``TimeSlotLedger.booked_window`` and the wavefront/reroute gathers
translate absolute slots to physical columns before the kernel ever runs,
and a compacted ledger feeds bit-identical windows to either backend.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np

EPS = 1e-9  # must equal repro.core.timeslot._EPS


def plan_scan_numpy(
    booked: np.ndarray,        # [n_cand, L, W] reserved fractions (gathered)
    caps: np.ndarray,          # [n_cand] bottleneck capacity per candidate
    secs: np.ndarray,          # [n_cand, W] usable seconds per slot
    sizes: np.ndarray,         # [n_cand] bytes (capacity-units·sec) to move
    bandwidth_cap: Optional[float] = None,
    overlay: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Reference scan; row ``k`` is bit-identical to ``plan_transfer`` run
    on candidate ``k`` alone (same expressions, numpy ``cumsum`` is a
    sequential accumulation per row).

    ``overlay`` (same shape as ``booked``, or broadcastable) is an extra
    reserved-fraction layer folded in as an elementwise max — a masked
    scan for callers that want cells priced as busier than the ledger
    records without mutating it (liveness masks, what-if overlays).
    ``max`` is exact in floating point, so an overlay of 0/1 cells
    reproduces the overlaid ledger bit-for-bit.  (The reroute engine
    ultimately prices its phantom-full view by *enumerating* only
    owner-clean columns — see ``core/reroute.py`` — so nothing in the
    scheduling core depends on this parameter; it is contract-tested on
    both backends.)
    """
    if overlay is not None:
        booked = np.maximum(booked, overlay)
    resid = 1.0 - booked.max(axis=1)
    bw = resid * caps[:, None]
    if bandwidth_cap is not None:
        bw = np.minimum(bw, bandwidth_cap)
    cum = np.cumsum(bw * secs, axis=1)
    # searchsorted-left on each nondecreasing row: first j with cum[j] >= v.
    hit = (cum < (sizes - EPS)[:, None]).sum(axis=1)
    return resid, bw, cum, hit


def _pad_to(x: np.ndarray, shape) -> np.ndarray:
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    return np.pad(x, pads)


def plan_scan_pallas(
    booked: np.ndarray,
    caps: np.ndarray,
    secs: np.ndarray,
    sizes: np.ndarray,
    bandwidth_cap: Optional[float] = None,
    overlay: Optional[np.ndarray] = None,
    interpret: Optional[bool] = None,
):
    """Pallas-TPU backend (float32).  Agrees with :func:`plan_scan_numpy`
    bit-wise on float64-safe inputs (module docstring); lazy jax import so
    the numpy scheduling path never touches jax.  The ``overlay`` layer is
    folded in on the host (one exact elementwise max) — it feeds the same
    padded gather, so the kernel body is unchanged."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from ._compat import CompilerParams

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    if overlay is not None:
        booked = np.maximum(booked, overlay)
    n, L, W = booked.shape
    BN, LP = 8, max(8, L)
    WP = max(128, -(-W // 128) * 128)
    NP = -(-n // BN) * BN
    bk = _pad_to(np.asarray(booked, np.float32), (NP, LP, WP))
    cp = _pad_to(np.asarray(caps, np.float32)[:, None], (NP, 1))
    sc = _pad_to(np.asarray(secs, np.float32), (NP, WP))
    sz = _pad_to(np.asarray(sizes, np.float32)[:, None], (NP, 1))
    cap = None if bandwidth_cap is None else float(bandwidth_cap)

    def kernel(bk_ref, cp_ref, sc_ref, sz_ref, resid_ref, bw_ref, cum_ref, hit_ref):
        resid = 1.0 - jnp.max(bk_ref[...], axis=1)
        bw = resid * cp_ref[...]
        if cap is not None:
            bw = jnp.minimum(bw, cap)
        cum = bw * sc_ref[...]
        k = 1
        while k < WP:  # Hillis–Steele inclusive prefix sum along the lanes
            shifted = jnp.concatenate(
                [jnp.zeros((BN, k), jnp.float32), cum[:, : WP - k]], axis=1
            )
            cum = cum + shifted
            k *= 2
        lane = jax.lax.broadcasted_iota(jnp.int32, (BN, WP), 1)
        below = (cum < (sz_ref[...] - np.float32(EPS))) & (lane < W)
        resid_ref[...] = resid
        bw_ref[...] = bw
        cum_ref[...] = cum
        hit_ref[...] = jnp.sum(below.astype(jnp.int32), axis=1, keepdims=True)

    grid = (NP // BN,)
    resid, bw, cum, hit = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BN, LP, WP), lambda i: (i, 0, 0)),
            pl.BlockSpec((BN, 1), lambda i: (i, 0)),
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, WP), lambda i: (i, 0)),
            pl.BlockSpec((BN, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NP, WP), jnp.float32),
            jax.ShapeDtypeStruct((NP, WP), jnp.float32),
            jax.ShapeDtypeStruct((NP, WP), jnp.float32),
            jax.ShapeDtypeStruct((NP, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(bk, cp, sc, sz)
    return (
        np.asarray(resid)[:n, :W],
        np.asarray(bw)[:n, :W],
        np.asarray(cum)[:n, :W],
        np.asarray(hit)[:n, 0],
    )


_BACKENDS = {"numpy": plan_scan_numpy, "pallas": plan_scan_pallas}
_backend = os.environ.get("REPRO_TS_PLAN_BACKEND", "numpy")


def set_backend(name: str) -> None:
    if name not in _BACKENDS:
        raise ValueError(f"unknown ts_plan backend {name!r} (want {sorted(_BACKENDS)})")
    global _backend
    _backend = name


def get_backend() -> str:
    return _backend


def plan_scan(booked, caps, secs, sizes, bandwidth_cap=None, overlay=None):
    """Dispatch to the selected backend (numpy unless opted out)."""
    return _BACKENDS[_backend](booked, caps, secs, sizes, bandwidth_cap,
                               overlay)
