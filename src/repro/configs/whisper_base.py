"""whisper-base [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

6L encoder + 6L decoder, d_model=512 8H (MHA) d_ff=2048 vocab=51865.  The
conv/log-mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (1500 × d_model, Whisper's 30 s at 50 Hz).
Positions are sinusoidal (no table), so arbitrary decode lengths lower
cleanly; Whisper proper caps the decoder at 448 — the assigned decode_32k
cell exercises the *system* (KV plumbing at 32k), noted in DESIGN.md.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2_048,
        vocab_size=51_865,
        head_dim=64,
        mlp_kind="gelu",
        n_enc_layers=6,
        enc_seq=1_500,
        use_rope=False,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="whisper-base-smoke",
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        enc_seq=32,
    )
