"""falcon-mamba-7b [ssm] — pure mamba1 stack, attention-free [arXiv:2410.05355].

64L d_model=4096, d_ff=0 (no MLP blocks — each layer is a single mamba1
block), vocab=65024, ssm_state=16, expand=2 (d_inner=8192), conv=4,
dt_rank=256.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4_096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=65_024,
        ssm_state=16,
        ssm_expand=2,
        ssm_conv=4,
        dt_rank=256,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="falcon-mamba-7b-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        ssm_state=4,
        dt_rank=8,
    )
