"""moonshot-v1-16b-a3b [moe] — kimi/moonlight [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (MHA kv=16) d_ff=1408 (per expert) vocab=163840,
MoE 64 experts top-6 on every layer.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2_048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1_408,
        vocab_size=163_840,
        head_dim=128,
        mlp_kind="swiglu",
        rope_theta=50_000.0,
        n_experts=64,
        top_k=6,
        moe_every=1,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="moonshot-v1-16b-a3b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab_size=256,
        n_experts=8,
        top_k=2,
    )
