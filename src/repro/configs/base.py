"""Model / run configuration shared by every assigned architecture.

A single frozen dataclass describes all families (dense / MoE / hybrid /
SSM / enc-dec / VLM).  Family-specific fields default to "off".  Exact
per-arch values live in ``repro/configs/<arch>.py``; every arch also ships a
``smoke()`` reduction used by the CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free archs)
    n_kv_heads: int
    d_ff: int                        # dense-MLP width (per-expert width for MoE)
    vocab_size: int

    head_dim: int = 0                # 0 → d_model // n_heads
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q/k
    mlp_kind: str = "swiglu"         # swiglu | gelu
    use_rope: bool = True            # jamba/whisper: no rotary embeddings
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # a layer is MoE iff layer % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    # --- SSM (mamba1) -------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    dt_rank: int = 0                 # 0 → ceil(d_model / 16)

    # --- hybrid (jamba) ------------------------------------------------------
    attn_period: int = 0             # 1 attention layer per this many (0 = n/a)
    attn_offset: int = 0             # index of the attn layer within a period

    # --- encoder-decoder (whisper) -------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0                 # encoder positions (whisper-base: 1500)

    # --- modality stubs -------------------------------------------------------
    n_vision_tokens: int = 0         # vlm: precomputed patch embeddings prepended

    # --- numerics / implementation selection ---------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "xla"           # xla | pallas (TPU fused kernel)
    attn_chunk: int = 512            # q-chunk for the XLA path (0 = unchunked)
    ssm_impl: str = "xla"            # xla | pallas
    moe_impl: str = "gather"         # gather | a2a (shard_map expert-parallel)
    remat: bool = True               # checkpoint each layer in train_step
    scan_layers: bool = True         # lax.scan over the layer stack

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.n_heads, f"{self.name}: head_dim undefined for attn-free arch"
        return self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    def is_moe_layer(self, layer: int) -> bool:
        return (
            self.n_experts > 0 and layer % max(self.moe_every, 1) == self.moe_offset
        )

    def is_attn_layer(self, layer: int) -> bool:
        """hybrid: which layers are attention (the rest are mamba)."""
        if self.family == "ssm":
            return False
        if self.family != "hybrid":
            return True
        return layer % self.attn_period == self.attn_offset

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter / FLOP accounting (roofline §Roofline) -----------
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _mlp_params(cfg: ModelConfig, width: int) -> int:
    mult = 3 if cfg.mlp_kind == "swiglu" else 2
    return mult * cfg.d_model * width


def _mamba_params(cfg: ModelConfig) -> int:
    d_in, n, r = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    return (
        cfg.d_model * 2 * d_in            # in_proj
        + d_in * cfg.ssm_conv             # depthwise conv
        + d_in * (r + 2 * n)              # x_proj
        + r * d_in                        # dt_proj
        + d_in * n + d_in                 # A_log, D
        + d_in * cfg.d_model              # out_proj
    )


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model  # lm head
    layers = cfg.n_layers + cfg.n_enc_layers
    for l in range(cfg.n_layers):
        if cfg.is_attn_layer(l):
            total += _attn_params(cfg)
        else:
            total += _mamba_params(cfg)
        if cfg.is_moe_layer(l):
            e = cfg.top_k if active_only else cfg.n_experts
            total += e * _mlp_params(cfg, cfg.d_ff) + cfg.d_model * cfg.n_experts
        else:
            total += _mlp_params(cfg, cfg.d_ff)
    for _ in range(cfg.n_enc_layers):  # whisper encoder (self-attn + mlp)
        total += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
    if cfg.n_enc_layers:  # decoder cross-attention
        total += cfg.n_layers * _attn_params(cfg)
    return total


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                  # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
