"""mistral-nemo-12b [dense] — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        family="dense",
        n_layers=40,
        d_model=5_120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14_336,
        vocab_size=131_072,
        head_dim=128,
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="mistral-nemo-12b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
    )
