"""qwen3-32b [dense] — qk_norm + GQA [hf:Qwen/Qwen3-8B family].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936; per-head RMSNorm on
q/k (qk_norm) and decoupled head_dim=128.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5_120,
        n_heads=64,
        n_kv_heads=8,
        d_ff=25_600,
        vocab_size=151_936,
        head_dim=128,
        qk_norm=True,
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="qwen3-32b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
    )
