"""internvl2-1b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The vision frontend
is a STUB per the assignment: ``input_specs()`` provides precomputed patch
embeddings (``n_vision_tokens`` × d_model) prepended to the text sequence;
the LM backbone below is fully real.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151_655,
        head_dim=64,
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        n_vision_tokens=256,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="internvl2-1b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_vision_tokens=8,
    )
