"""Architecture registry — every assigned arch is selectable via ``--arch``.

``get_config(name)`` returns the exact published configuration;
``get_config(name, smoke=True)`` returns the reduced same-family variant the
CPU smoke tests instantiate for a real forward/train step.
"""
from __future__ import annotations

from typing import Dict, List

from .base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeSpec,
)
from . import (
    falcon_mamba_7b,
    internvl2_1b,
    jamba_v01_52b,
    mistral_large_123b,
    mistral_nemo_12b,
    moonshot_v1_16b_a3b,
    phi35_moe_42b,
    qwen3_32b,
    starcoder2_3b,
    whisper_base,
)

_MODULES = {
    "internvl2-1b": internvl2_1b,
    "mistral-large-123b": mistral_large_123b,
    "starcoder2-3b": starcoder2_3b,
    "qwen3-32b": qwen3_32b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "whisper-base": whisper_base,
    "falcon-mamba-7b": falcon_mamba_7b,
}

ARCH_NAMES: List[str] = list(_MODULES)

# Sub-quadratic archs run the long_500k cell; pure full-attention archs skip
# it (DESIGN.md §5).
LONG_CONTEXT_ARCHS = {"jamba-v0.1-52b", "falcon-mamba-7b"}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = _MODULES[name]
    return mod.smoke() if smoke else mod.full()


def shapes_for(name: str) -> List[ShapeSpec]:
    """The assigned shape cells an arch actually runs (skips per DESIGN.md)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if name in LONG_CONTEXT_ARCHS:
        shapes.append(LONG_500K)
    return shapes


def all_cells() -> List[tuple]:
    return [(a, s) for a in ARCH_NAMES for s in shapes_for(a)]


__all__ = [
    "ALL_SHAPES",
    "ARCH_NAMES",
    "LONG_CONTEXT_ARCHS",
    "ModelConfig",
    "ShapeSpec",
    "all_cells",
    "get_config",
    "shapes_for",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
]
