"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 (per expert) vocab=32064,
MoE 16e top-2 on every layer.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4_096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6_400,
        vocab_size=32_064,
        head_dim=128,
        mlp_kind="swiglu",
        rope_theta=10_000.0,
        n_experts=16,
        top_k=2,
        moe_every=1,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="phi3.5-moe-42b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        n_experts=4,
        top_k=2,
    )
