"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Attention appears once per 8-layer period (offset 4, as in the paper's
block); MoE replaces the MLP on every other layer.  Mamba1 state=16.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4_096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14_336,
        vocab_size=65_536,
        head_dim=128,
        mlp_kind="swiglu",
        n_experts=16,
        top_k=2,
        moe_every=2,
        moe_offset=1,
        ssm_state=16,
        ssm_expand=2,
        ssm_conv=4,
        attn_period=8,
        attn_offset=4,
        use_rope=False,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="jamba-v0.1-52b-smoke",
        n_layers=8,          # one full period: 1 attn + 7 mamba, 4 MoE layers
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=4,
        top_k=2,
        ssm_state=4,
    )
