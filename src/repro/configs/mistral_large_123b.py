"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407.

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12_288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=28_672,
        vocab_size=32_768,
        head_dim=128,
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="mistral-large-123b-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
    )
