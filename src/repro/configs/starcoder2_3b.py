"""starcoder2-3b [dense] — GQA + RoPE [arXiv:2402.19173; hf].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.  StarCoder2 uses a
non-gated GELU MLP (4×d_model).
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3_072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12_288,
        vocab_size=49_152,
        head_dim=128,
        mlp_kind="gelu",
        rope_theta=999_999.0,
    )


def smoke() -> ModelConfig:
    return full().with_(
        name="starcoder2-3b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
    )
