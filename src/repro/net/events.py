"""Network-churn events and reroute records — link failure made schedulable.

The paper's SDN story assumes the controller *reacts*: a link dies, the
global view updates, in-flight transfers move to surviving paths.  These
dataclasses are the vocabulary of that loop.  They flow through
``ClusterController`` like job arrivals do — ``inject_net(LinkDown("Trunk0",
at=12.0))`` queues the failure, and when it fires the controller releases
every affected transfer's unconsumed slots, replans the remaining bytes on
the best surviving candidate path, and appends a :class:`RerouteRecord` to
its ``reroute_log``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Hashable, Optional, Tuple, Union

from .paths import UnroutableError  # noqa: F401  (re-export: routing failure)


@dataclass(frozen=True)
class LinkDown:
    """Link failure at time ``at`` — in-flight transfers on it reroute."""

    link: str
    at: float


@dataclass(frozen=True)
class LinkUp:
    """Link recovery at time ``at`` — suspended raw flows resume."""

    link: str
    at: float


@dataclass(frozen=True)
class SwitchDown:
    """Switch failure: every incident link goes down at ``at``."""

    node: str
    at: float


@dataclass(frozen=True)
class SwitchUp:
    """Switch recovery: incident links return unless individually failed."""

    node: str
    at: float


@dataclass(frozen=True)
class HostDown:
    """Host crash at ``at``: its NIC links die, queued/running tasks are
    killed and re-placed through the normal (bandwidth-aware) policy path."""

    node: str
    at: float


@dataclass(frozen=True)
class HostUp:
    """Host recovery at ``at`` — the worker is re-admitted (unless
    blacklisted) with its idle clock set to the recovery time."""

    node: str
    at: float


@dataclass(frozen=True)
class ControllerDown:
    """Control-plane crash at ``at``: the data plane keeps forwarding on
    installed rules (in-flight transfers complete), but scheduling stops —
    new submissions queue in a bounded mailbox, heartbeat/telemetry chains
    are suspended, and every other event is deferred until recovery."""

    at: float


@dataclass(frozen=True)
class ControllerUp:
    """Control-plane recovery at ``at``: reconcile lapsed rule expiries,
    forgive the heartbeat gap, drain the mailbox in arrival order, and
    re-arm the polling chains."""

    at: float


NetworkEvent = Union[
    LinkDown, LinkUp, SwitchDown, SwitchUp, HostDown, HostUp,
    ControllerDown, ControllerUp,
]


@dataclass(frozen=True)
class RerouteRecord:
    """One successful reroute: what moved, from where, to where, at what cost.

    ``delivered`` is the size already transferred on the dead path (kept —
    its slots before the failure stay consumed); ``remaining`` was replanned
    on ``new_path``.  ``flow`` is the transfer's cookie: ``("job", jid,
    tid)`` for task transfers, the caller's tag for raw flows.
    """

    at: float
    flow: Hashable
    dead_links: Tuple[str, ...]
    src: Optional[str]
    dst: Optional[str]
    old_path: Tuple[str, ...]
    new_path: Tuple[str, ...]
    delivered: float
    remaining: float
    old_end: float
    new_end: float

    def __str__(self) -> str:
        frm = f"{self.src}->{self.dst}" if self.src else str(self.flow)
        return (
            f"[t={self.at:8.2f}] reroute {frm}: dead {sorted(self.dead_links)}"
            f" | {'/'.join(self.old_path)} -> {'/'.join(self.new_path)}"
            f" | {self.delivered:.0f} delivered, {self.remaining:.0f} replanned,"
            f" end {self.old_end:.2f} -> {self.new_end:.2f}"
        )
