"""The SDN data plane: link liveness + path engine + flow tables.

One :class:`DataPlane` sits under each ``ClusterController``.  It owns

* the **liveness overlay** — failed links/switches are state *here*, not
  mutations of the shared ``Fabric`` (the fabric stays the wiring diagram;
  the data plane knows what is currently forwarding);
* the **path engine** — k-shortest-path candidates per endpoint pair,
  filtered through the overlay by :meth:`candidates`;
* the **flow tables** — the per-switch rules of every in-flight transfer.

With no failures injected, :meth:`candidates` returns the cached engine
set whose first element is ``Fabric.path(src, dst)`` verbatim — so a
controller that never sees churn behaves byte-identically to the
pre-data-plane code.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Optional, Sequence, Set, Tuple

from ..core.timeslot import TimeSlotLedger
from ..core.topology import Fabric
from .flowtable import FlowTables
from .paths import Path, PathEngine, UnroutableError


class DataPlane:
    def __init__(self, fabric: Fabric, k: int = 4) -> None:
        self.fabric = fabric
        self.engine = PathEngine(fabric, k=k)
        self.tables = FlowTables(fabric)
        self.dead_links: Set[str] = set()    # individually failed
        self.dead_switches: Set[str] = set()
        self.dead_hosts: Set[str] = set()    # crashed workers/sources
        self._dead_all: Optional[FrozenSet[str]] = None  # overlay cache
        #: Monotone counter bumped on every liveness mutation — cheap cache
        #: key for consumers (the wavefront planner) whose candidate sets
        #: depend on the current dead set.
        self.liveness_version = 0

    # -- liveness overlay ---------------------------------------------------
    def fail_link(self, name: str) -> None:
        self.fabric.link(name)  # KeyError on unknown link
        self.dead_links.add(name)
        self._dead_all = None
        self.liveness_version += 1

    def recover_link(self, name: str) -> None:
        self.dead_links.discard(name)
        self._dead_all = None
        self.liveness_version += 1

    def fail_switch(self, node: str) -> None:
        if not self.fabric.has_node(node):
            raise ValueError(f"unknown node {node!r}")
        self.dead_switches.add(node)
        self._dead_all = None
        self.liveness_version += 1

    def recover_switch(self, node: str) -> None:
        self.dead_switches.discard(node)
        self._dead_all = None
        self.liveness_version += 1

    def fail_host(self, node: str) -> None:
        """Host crash: its NIC links die with it (kept distinct from
        ``dead_switches`` so 'host crashed' is semantically visible)."""
        if not self.fabric.has_node(node):
            raise ValueError(f"unknown node {node!r}")
        self.dead_hosts.add(node)
        self._dead_all = None
        self.liveness_version += 1

    def recover_host(self, node: str) -> None:
        self.dead_hosts.discard(node)
        self._dead_all = None
        self.liveness_version += 1

    def all_dead_links(self) -> FrozenSet[str]:
        """Explicitly failed links plus every link touching a dead switch
        or crashed host."""
        if self._dead_all is None:
            dead = set(self.dead_links)
            for sw in self.dead_switches:
                dead.update(self.fabric.incident_links(sw))
            for h in self.dead_hosts:
                dead.update(self.fabric.incident_links(h))
            self._dead_all = frozenset(dead)
        return self._dead_all

    def has_failures(self) -> bool:
        return bool(self.dead_links or self.dead_switches or self.dead_hosts)

    # -- liveness serialization (controller crash-recovery) -----------------
    def dump_liveness(self) -> dict:
        """Plain-data liveness overlay for controller snapshots (DESIGN.md
        §11).  Sets are dumped sorted so the bytes are deterministic; the
        path engine's caches are pure memoization and are not serialized —
        a restored plane recomputes identical candidates cold."""
        return {
            "dead_links": sorted(self.dead_links),
            "dead_switches": sorted(self.dead_switches),
            "dead_hosts": sorted(self.dead_hosts),
            "version": self.liveness_version,
        }

    def load_liveness(self, state: dict) -> None:
        """Restore a :meth:`dump_liveness` overlay in place."""
        self.dead_links = set(state["dead_links"])
        self.dead_switches = set(state["dead_switches"])
        self.dead_hosts = set(state["dead_hosts"])
        self._dead_all = None
        self.liveness_version = state["version"]

    def link_alive(self, name: str) -> bool:
        return name not in self.all_dead_links()

    def host_alive(self, node: str) -> bool:
        """A host can send/receive iff it is up and has a live incident link."""
        if node in self.dead_switches or node in self.dead_hosts:
            return False
        dead = self.all_dead_links()
        return any(l not in dead for l in self.fabric.incident_links(node))

    # -- routing ------------------------------------------------------------
    def candidates(
        self, src: str, dst: str, k: Optional[int] = None
    ) -> Tuple[Path, ...]:
        """Surviving candidate paths src→dst (raises UnroutableError)."""
        down = self.dead_switches
        if (src in down or dst in down
                or src in self.dead_hosts or dst in self.dead_hosts):
            raise UnroutableError(f"endpoint down: {src!r} -> {dst!r}")
        return self.engine.route(src, dst, self.all_dead_links(), k=k)

    def candidates_batch(
        self, pairs: Sequence[Tuple[str, str]], k: Optional[int] = None
    ) -> Dict[Tuple[str, str], Tuple[Path, ...]]:
        """Surviving candidates for many pairs in one engine pass.

        Pairs with a dead endpoint or no surviving path map to ``()``
        instead of raising — the batched reroute engine drops dead
        replicas per victim and raises only when a victim has none left.
        """
        dead = self.all_dead_links()
        down = self.dead_switches | self.dead_hosts
        live = [p for p in pairs if p[0] not in down and p[1] not in down]
        out = self.engine.route_batch(live, dead, k=k)
        for p in pairs:
            out.setdefault(p, ())
        return out

    def usable(self, src: str, dst: str) -> bool:
        try:
            self.candidates(src, dst, k=1)
            return True
        except UnroutableError:
            return False

    def best_path(
        self, ledger: TimeSlotLedger, src: str, dst: str, t: float,
        k: Optional[int] = None,
    ) -> Path:
        """Best surviving path by residual bandwidth at ``t``."""
        cands = self.candidates(src, dst, k=k)
        return cands[self.engine.best(ledger, cands, t)]
