"""Per-switch flow tables — transfers represented as OpenFlow-style rules.

The TS ledger answers *when/how much*; the flow tables answer *where*: once
the controller picks a path for a transfer, every node along it gets a
match→out-port rule, exactly the artifact an OpenFlow controller would push
to its switches.  A transfer is therefore inspectable as installed state
(``dump``), not just as ledger rows — and rerouting is the literal SDN
operation of uninstalling one rule set and installing another.

Matches are ``(flow src, flow dst)`` endpoint pairs; the cookie is the
installing transfer's id so a reroute can surgically remove its own rules.
Later installs for the same match win on lookup (higher priority), matching
OpenFlow's overlapping-rule semantics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from ..core.topology import Fabric


@dataclass(frozen=True)
class FlowRule:
    """One match→action entry in a node's flow table."""

    node: str                   # switch/host holding the rule
    match: Tuple[str, str]      # (flow src, flow dst) endpoint pair
    out_port: str               # link name the packet is forwarded on
    cookie: Hashable            # installing transfer's id
    priority: int = 0           # later installs win (higher priority)


class FlowTable:
    """A single node's flow table.

    Rules are bucketed by cookie (one transfer = one cookie, and a node
    appears at most once on a path), so the reroute storm's mass
    uninstalls are one dict pop instead of a scan of every rule the node
    carries.  Bucket order is install order, so ``dump``/``lookup``
    iterate rules exactly as the historical flat list did.
    """

    def __init__(self, node: str) -> None:
        self.node = node
        self._rules: Dict[Hashable, List[FlowRule]] = {}
        self._n = 0

    def install(self, rule: FlowRule) -> None:
        self._rules.setdefault(rule.cookie, []).append(rule)
        self._n += 1

    def uninstall(self, cookie: Hashable) -> int:
        gone = len(self._rules.pop(cookie, ()))
        self._n -= gone
        return gone

    def lookup(self, src: str, dst: str) -> Optional[FlowRule]:
        """Highest-priority rule matching the endpoint pair (ties: latest)."""
        hits = [r for rs in self._rules.values() for r in rs
                if r.match == (src, dst)]
        if not hits:
            return None
        return max(enumerate(hits), key=lambda ir: (ir[1].priority, ir[0]))[1]

    def dump(self) -> List[FlowRule]:
        return [r for rs in self._rules.values() for r in rs]

    def __len__(self) -> int:
        return self._n


class FlowTables:
    """All nodes' flow tables + path compilation (the controller's rule base)."""

    def __init__(self, fabric: Fabric) -> None:
        self.fabric = fabric
        self._tables: Dict[str, FlowTable] = {}
        # cookie → nodes holding its rules, so a reroute storm's mass
        # uninstalls touch only the tables that actually carry the cookie
        # instead of scanning every table in the fabric.
        self._cookie_nodes: Dict[Hashable, Tuple[str, ...]] = {}
        self._prio = 0

    def table(self, node: str) -> FlowTable:
        if node not in self._tables:
            if not self.fabric.has_node(node):
                raise ValueError(f"unknown node {node!r}")
            self._tables[node] = FlowTable(node)
        return self._tables[node]

    # -- rule lifecycle -----------------------------------------------------
    def install_path(
        self, cookie: Hashable, src: str, dst: str, links: Sequence[str]
    ) -> List[FlowRule]:
        """Compile a link path into per-hop rules and install them.

        Every node on the path except the destination gets a
        ``(src, dst) → next link`` rule; one transfer = one cookie, so the
        whole set uninstalls atomically.
        """
        nodes = self.fabric.path_nodes(src, links)
        self._prio += 1
        out = []
        for hop, link in zip(nodes[:-1], links):
            rule = FlowRule(hop, (src, dst), link, cookie, priority=self._prio)
            self.table(hop).install(rule)
            out.append(rule)
        held = self._cookie_nodes.get(cookie, ())
        self._cookie_nodes[cookie] = held + tuple(nodes[:-1])
        return out

    def uninstall(self, cookie: Hashable) -> int:
        """Remove every rule the cookie installed; returns the count."""
        nodes = self._cookie_nodes.pop(cookie, ())
        return sum(
            self._tables[n].uninstall(cookie) for n in dict.fromkeys(nodes)
        )

    # -- full-state serialization (controller crash-recovery) ---------------
    def dump_state(self) -> dict:
        """Plain-data serialization of every installed rule, preserving
        table order, per-cookie bucket order and the priority counter, so
        :meth:`load_state` rebuilds tables whose ``dump``/``lookup``/
        ``trace`` answers are byte-identical (DESIGN.md §11)."""
        return {
            "tables": [
                (
                    node,
                    [
                        (cookie, [(r.match, r.out_port, r.priority)
                                  for r in rules])
                        for cookie, rules in t._rules.items()
                    ],
                )
                for node, t in self._tables.items()
            ],
            "cookie_nodes": list(self._cookie_nodes.items()),
            "prio": self._prio,
        }

    def load_state(self, state: dict) -> None:
        """Restore a :meth:`dump_state` dict in place (replaces every
        currently-installed rule)."""
        self._tables = {}
        for node, buckets in state["tables"]:
            t = self.table(node)
            for cookie, rules in buckets:
                for match, out_port, priority in rules:
                    t.install(FlowRule(node, tuple(match), out_port, cookie,
                                       priority=priority))
        self._cookie_nodes = {c: tuple(ns) for c, ns in state["cookie_nodes"]}
        self._prio = state["prio"]

    # -- inspection ---------------------------------------------------------
    def dump(self, node: Optional[str] = None) -> List[FlowRule]:
        if node is not None:
            return self.table(node).dump()
        return [r for n in sorted(self._tables) for r in self._tables[n].dump()]

    def lookup(self, node: str, src: str, dst: str) -> Optional[FlowRule]:
        return self.table(node).lookup(src, dst)

    def trace(self, src: str, dst: str, max_hops: int = 64) -> Tuple[str, ...]:
        """Follow installed rules hop-by-hop from ``src``; returns the link
        sequence actually programmed into the data plane (what a packet
        would traverse).  Raises if the rules don't reach ``dst``."""
        cur, out = src, []
        for _ in range(max_hops):
            if cur == dst:
                return tuple(out)
            rule = self.lookup(cur, src, dst)
            if rule is None:
                raise LookupError(
                    f"no rule for ({src!r}, {dst!r}) at {cur!r} after {out}"
                )
            out.append(rule.out_port)
            cur = self.fabric.link(rule.out_port).other(cur)
        raise LookupError(f"rule loop tracing ({src!r}, {dst!r}): {out}")

    def n_rules(self) -> int:
        return sum(len(t) for t in self._tables.values())
