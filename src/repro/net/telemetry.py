"""SDN telemetry plane: measured-bandwidth belief state.

The paper's BASS scheduler assumes the controller *knows* per-link
available bandwidth; every policy in this repo historically read the
:class:`~repro.core.timeslot.TimeSlotLedger` as oracle ground truth.  A
real SDN controller instead polls switch counters and schedules on noisy,
stale estimates (Aljoby et al., *SDN-Enabled Online and Dynamic Bandwidth
Allocation*: measure → estimate → allocate).  This module is that loop:

* :class:`LinkStatsMonitor` — driven by the ``ClusterController`` event
  loop ("poll" events).  Each poll samples, per link, the instantaneous
  occupancy fraction of the current slot *and* advances cumulative
  byte counters by integrating ``reserved × capacity`` over the elapsed
  interval — the two signals a switch's port counters give you.
* Estimators — :class:`EwmaEstimator` smooths occupancy samples;
  :class:`WindowRateEstimator` differentiates the cumulative byte
  counters over a sliding window.  Both expose a per-link utilization
  vector in ``[0, 1]``.
* :class:`BeliefState` — the controller's picture of the network.  It
  mirrors the ledger's read-side query surface (``residual_fraction``,
  ``path_bandwidth``, ``path_bandwidth_batch``, ``min_path_bandwidth``)
  but answers from the estimated utilization vector: flat in time,
  stale between polls.

Separation contract (DESIGN.md §9): policies opting in via
``BassPolicy(telemetry=True)`` *score* candidates against the belief,
but every commit still plans and books on the true ledger — belief can
misrank, it can never corrupt data-plane state.  With telemetry off the
belief is never consulted and schedules stay byte-identical.

This module must stay importable without jax (numpy + stdlib only).
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Sequence, Union

import numpy as np

_EPS = 1e-9


class BeliefState:
    """Estimated network state mirroring the ledger's read-side queries.

    The belief is a per-link utilization vector ``util`` (fraction of
    capacity in use) plus the static capacity vector — flat in time: the
    monitor's last estimate is assumed to hold for any queried instant.
    Edge semantics (empty paths, float types) match the ledger exactly so
    the zero-staleness limit is *bit*-equal (see tests/test_telemetry.py).
    """

    __slots__ = ("capacity", "util", "as_of", "polls")

    def __init__(self, capacity: Sequence[float]):
        self.capacity = np.asarray(capacity, dtype=float)
        self.util = np.zeros(len(self.capacity))
        self.as_of = float("-inf")  # sim time of the last poll
        self.polls = 0

    # -- ledger read-side surface ---------------------------------------
    def residual_fraction(self, rows: Sequence[int], slot: int) -> float:
        """Believed min residual fraction over ``rows`` (slot-invariant)."""
        if not rows:
            return 1.0
        return float(1.0 - self.util[list(rows)].max())

    def path_bandwidth(self, rows: Sequence[int], t: float) -> float:
        """Believed ``BW_rl`` of a path = min over links of residual bw."""
        if not rows:
            return float("inf")
        idx = list(rows)
        resid = (1.0 - self.util[idx]) * self.capacity[idx]
        return float(resid.min())

    def path_bandwidth_batch(
        self, rows_list: Sequence[Sequence[int]], t: float
    ) -> np.ndarray:
        """Believed ``BW_rl`` for many candidate paths in one numpy pass."""
        n = len(rows_list)
        out = np.full(n, float("inf"))
        live = [i for i in range(n) if rows_list[i]]
        if not live:
            return out
        pad = _padded_rows([rows_list[i] for i in live])
        resid = (1.0 - self.util[pad]) * self.capacity[pad]
        out[live] = resid.min(axis=1)
        return out

    def min_path_bandwidth(self, rows: Sequence[int], t0: float, t1: float) -> float:
        """Flat in time: the window minimum is just the current estimate."""
        return self.path_bandwidth(rows, t0)


def _padded_rows(rows_list: Sequence[Sequence[int]]) -> np.ndarray:
    # Same padding trick as TimeSlotLedger._padded_rows: repeat the
    # candidate's own first link so min-reductions are unaffected.
    width = max(len(r) for r in rows_list)
    pad = np.empty((len(rows_list), width), dtype=np.intp)
    for i, r in enumerate(rows_list):
        pad[i, : len(r)] = r
        pad[i, len(r):] = r[0]
    return pad


class EwmaEstimator:
    """Exponentially-weighted moving average over occupancy samples.

    ``alpha`` is the weight of the newest sample; the first sample primes
    the state exactly, so with ``alpha=1.0`` the estimate always equals
    the last instantaneous occupancy — the zero-staleness identity used
    by the exactness tests.
    """

    name = "ewma"

    def __init__(self, n_links: int, alpha: float = 0.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._u = np.zeros(n_links)
        self._primed = False

    def update(self, t: float, occupancy: np.ndarray, cum_bytes: np.ndarray) -> None:
        if not self._primed:
            self._u = occupancy.astype(float, copy=True)
            self._primed = True
        elif self.alpha == 1.0:
            # exact tracking: copy, don't blend (keeps floats bit-equal)
            self._u[:] = occupancy
        else:
            self._u = self.alpha * occupancy + (1.0 - self.alpha) * self._u

    def utilization(self) -> np.ndarray:
        return self._u

    # -- serialization (controller crash-recovery) ----------------------
    def dump_state(self) -> dict:
        return {
            "kind": self.name,
            "alpha": self.alpha,
            "u": self._u.copy(),
            "primed": self._primed,
        }

    def load_state(self, state: dict) -> None:
        self.alpha = state["alpha"]
        self._u = state["u"].copy()
        self._primed = state["primed"]


class WindowRateEstimator:
    """Sliding-window rate from cumulative byte counters.

    Utilization = (bytes moved over the window) / (capacity × window
    seconds), the way a monitoring loop differentiates port counters.
    Before two samples exist it falls back to the last instantaneous
    occupancy so a cold belief is not blind.
    """

    name = "window"

    def __init__(self, n_links: int, capacity: Sequence[float], window: float = 4.0):
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        self.window = float(window)
        self.capacity = np.asarray(capacity, dtype=float)
        self._samples: deque = deque()  # (t, cum_bytes.copy())
        self._occ = np.zeros(n_links)
        #: Counter discontinuities survived (controller restarts zero the
        #: synthetic port counters; a real switch reboot does the same).
        self.resets = 0

    def update(self, t: float, occupancy: np.ndarray, cum_bytes: np.ndarray) -> None:
        self._occ = occupancy.astype(float, copy=True)
        # Monotonic-counter discontinuity (a counter went *backwards*, e.g.
        # a switch/controller restart zeroed it): differencing across the
        # reset would produce a negative rate, so drop the pre-reset
        # history and start a fresh window from this sample — utilization
        # falls back to instantaneous occupancy until two post-reset
        # samples exist.
        if self._samples and bool(np.any(cum_bytes < self._samples[-1][1] - _EPS)):
            self._samples.clear()
            self.resets += 1
        self._samples.append((t, cum_bytes.copy()))
        # Keep one sample at or before the window edge so the finite
        # difference always spans >= the window once enough history exists.
        while len(self._samples) > 2 and self._samples[1][0] <= t - self.window:
            self._samples.popleft()

    def utilization(self) -> np.ndarray:
        if len(self._samples) < 2:
            return self._occ
        t0, b0 = self._samples[0]
        t1, b1 = self._samples[-1]
        dt = t1 - t0
        if dt <= _EPS:
            return self._occ
        u = (b1 - b0) / (self.capacity * dt)
        return np.clip(u, 0.0, 1.0)

    # -- serialization (controller crash-recovery) ----------------------
    def dump_state(self) -> dict:
        return {
            "kind": self.name,
            "window": self.window,
            "occ": self._occ.copy(),
            "samples": [(t, b.copy()) for t, b in self._samples],
            "resets": self.resets,
        }

    def load_state(self, state: dict) -> None:
        self.window = state["window"]
        self._occ = state["occ"].copy()
        self._samples = deque((t, b.copy()) for t, b in state["samples"])
        self.resets = state["resets"]


ESTIMATORS = {"ewma": EwmaEstimator, "window": WindowRateEstimator}


def make_estimator(
    kind: str, n_links: int, capacity: Sequence[float], **kwargs
) -> Union[EwmaEstimator, WindowRateEstimator]:
    if kind == "ewma":
        return EwmaEstimator(n_links, **kwargs)
    if kind == "window":
        return WindowRateEstimator(n_links, capacity, **kwargs)
    raise ValueError(f"unknown estimator {kind!r} (have: {sorted(ESTIMATORS)})")


class LinkStatsMonitor:
    """Samples per-link counters from the ledger and feeds an estimator.

    The monitor is the data-plane-facing half of the telemetry loop: it
    never *writes* the ledger, it only reads ``reserved``/``capacity`` to
    synthesize what real switch counters would report —

    * instantaneous occupancy of the slot containing the poll instant;
    * cumulative bytes per link, advanced by integrating
      ``reserved × capacity`` over the interval since the previous poll
      (partial slots pro-rated; slots already retired by the rolling
      horizon are skipped and counted in ``stats["missed_slots"]``).

    ``poll(t)`` pushes both signals into the estimator and refreshes the
    attached :class:`BeliefState` in place, so policy code holding a
    reference always sees the newest estimate.
    """

    def __init__(
        self,
        ledger,
        poll_interval: Optional[float] = None,
        estimator: Union[str, object] = "ewma",
        obs=None,
        **est_kwargs,
    ):
        self.ledger = ledger
        self.poll_interval = (
            float(poll_interval) if poll_interval is not None else ledger.slot_duration
        )
        if self.poll_interval <= 0:
            raise ValueError(f"poll_interval must be positive, got {self.poll_interval}")
        n = len(ledger.capacity)
        if isinstance(estimator, str):
            estimator = make_estimator(estimator, n, ledger.capacity, **est_kwargs)
        elif est_kwargs:
            raise TypeError("estimator kwargs only apply when estimator is a name")
        self.estimator = estimator
        self.belief = BeliefState(ledger.capacity)
        self.cum_bytes = np.zeros(n)
        self.last_poll = float("-inf")
        self._last_t: Optional[float] = None
        if obs is not None:
            self.stats = obs.group(
                "telemetry", ("polls", "missed_slots", "samples_dropped")
            )
        else:
            from ..obs import CounterGroup

            self.stats = CounterGroup(
                ("polls", "missed_slots", "samples_dropped"), prefix="telemetry"
            )

    # -- counter synthesis ----------------------------------------------
    def _occupancy(self, t: float) -> np.ndarray:
        led = self.ledger
        res = led.reserved
        p = led.slot_of(t) - led.base_slot
        if p < 0 or p >= res.shape[1]:
            return np.zeros(res.shape[0])
        return res[:, p].copy()

    def _advance_counters(self, t: float) -> None:
        """Integrate reserved×capacity over [last_t, t) into cum_bytes."""
        t0 = self._last_t
        self._last_t = t
        if t0 is None or t <= t0:
            return
        led = self.ledger
        res, cap, dur, base = led.reserved, led.capacity, led.slot_duration, led.base_slot
        width = res.shape[1]
        s0, s1 = led.slot_of(t0), led.slot_of(t)

        def frac_col(s: int) -> Optional[np.ndarray]:
            p = s - base
            if p < 0:
                self.stats["missed_slots"] += 1  # retired before we sampled it
                return None
            if p >= width:
                return None  # beyond the booked horizon: nothing reserved
            return res[:, p]

        if s0 == s1:
            c = frac_col(s0)
            if c is not None:
                self.cum_bytes += c * cap * (t - t0)
            return
        # head partial slot
        c = frac_col(s0)
        if c is not None:
            self.cum_bytes += c * cap * ((s0 + 1) * dur - t0)
        # full interior slots [s0+1, s1)
        lo, hi = s0 + 1, s1
        plo, phi = max(lo - base, 0), min(hi - base, width)
        if lo < base:
            self.stats["missed_slots"] += min(base, hi) - lo
        if phi > plo:
            self.cum_bytes += res[:, plo:phi].sum(axis=1) * cap * dur
        # tail partial slot
        c = frac_col(s1)
        if c is not None:
            self.cum_bytes += c * cap * (t - s1 * dur)

    # -- the poll -------------------------------------------------------
    def poll(self, t: float) -> BeliefState:
        """Sample counters at sim time ``t`` and refresh the belief."""
        self._advance_counters(t)
        occ = self._occupancy(t)
        self.estimator.update(t, occ, self.cum_bytes)
        self.belief.util = self.estimator.utilization()
        self.belief.as_of = t
        self.belief.polls += 1
        self.last_poll = t
        self.stats["polls"] += 1
        return self.belief

    def snapshot(self) -> dict:
        """Obs-registry provider section."""
        return {
            "poll_interval": self.poll_interval,
            "estimator": getattr(self.estimator, "name", type(self.estimator).__name__),
            "polls": self.stats["polls"],
            "missed_slots": self.stats["missed_slots"],
            "last_poll": self.last_poll,
            "belief_as_of": self.belief.as_of,
            "mean_util": float(self.belief.util.mean()) if len(self.belief.util) else 0.0,
            "max_util": float(self.belief.util.max()) if len(self.belief.util) else 0.0,
            "resets": getattr(self.estimator, "resets", 0),
        }

    # -- serialization (controller crash-recovery) ----------------------
    def dump_state(self) -> dict:
        """Plain-data serialization of the telemetry loop (DESIGN.md §11):
        poll cursor, synthesized counters, estimator internals and belief.
        The ledger reference and the obs group are reattached by
        :meth:`load_state` — they belong to the restoring controller."""
        est = self.estimator
        if not hasattr(est, "dump_state"):
            raise TypeError(
                f"estimator {type(est).__name__} does not support dump_state; "
                "snapshotting requires a serializable estimator"
            )
        return {
            "poll_interval": self.poll_interval,
            "estimator": est.dump_state(),
            "cum_bytes": self.cum_bytes.copy(),
            "last_poll": self.last_poll,
            "last_t": self._last_t,
            "belief": {
                "util": self.belief.util.copy(),
                "as_of": self.belief.as_of,
                "polls": self.belief.polls,
            },
        }

    @classmethod
    def load_state(cls, ledger, state: dict, obs=None) -> "LinkStatsMonitor":
        """Rebuild a monitor against ``ledger`` from a :meth:`dump_state`
        dict.  Stats counters live in the obs registry and are restored by
        ``Registry.load_values`` — passing the same ``obs`` here makes the
        rebuilt monitor's group share those cells."""
        est_state = state["estimator"]
        est = make_estimator(
            est_state["kind"], len(ledger.capacity), ledger.capacity
        )
        est.load_state(est_state)
        mon = cls(
            ledger, poll_interval=state["poll_interval"], estimator=est, obs=obs
        )
        mon.cum_bytes = state["cum_bytes"].copy()
        mon.last_poll = state["last_poll"]
        mon._last_t = state["last_t"]
        b = state["belief"]
        mon.belief.util = b["util"].copy()
        mon.belief.as_of = b["as_of"]
        mon.belief.polls = b["polls"]
        return mon
