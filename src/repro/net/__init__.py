"""``repro.net`` — the SDN data plane under the scheduling control plane.

The control plane (``repro.core``) decides *who* computes and *when* bytes
move; this package models *how they get there*: k-shortest-path multipath
routing (``paths``), per-switch flow tables (``flowtable``), link/switch
failure events with failure-aware rerouting (``events``), topology builders
with real path diversity (``fattree``), and the :class:`DataPlane` that
``ClusterController`` drives (``dataplane``).
"""
from .dataplane import DataPlane
from .events import (
    LinkDown,
    LinkUp,
    NetworkEvent,
    RerouteRecord,
    SwitchDown,
    SwitchUp,
)
from .fattree import fat_tree_fabric, oversubscribed_leaf_spine
from .flowtable import FlowRule, FlowTable, FlowTables
from .paths import PathEngine, UnroutableError, k_shortest_paths

__all__ = [
    "DataPlane",
    "FlowRule",
    "FlowTable",
    "FlowTables",
    "LinkDown",
    "LinkUp",
    "NetworkEvent",
    "PathEngine",
    "RerouteRecord",
    "SwitchDown",
    "SwitchUp",
    "UnroutableError",
    "fat_tree_fabric",
    "k_shortest_paths",
    "oversubscribed_leaf_spine",
]
