"""``repro.net`` — the SDN data plane under the scheduling control plane.

The control plane (``repro.core``) decides *who* computes and *when* bytes
move; this package models *how they get there*: k-shortest-path multipath
routing (``paths``), per-switch flow tables (``flowtable``), link/switch
failure events with failure-aware rerouting (``events``), topology builders
with real path diversity (``fattree``), the :class:`DataPlane` that
``ClusterController`` drives (``dataplane``), and the telemetry plane
(``telemetry``): per-link counter polling, EWMA/windowed bandwidth
estimators, and the measured-bandwidth :class:`BeliefState` that
``telemetry=True`` policies schedule against (DESIGN.md §9).
"""
from .dataplane import DataPlane
from .events import (
    HostDown,
    HostUp,
    LinkDown,
    LinkUp,
    NetworkEvent,
    RerouteRecord,
    SwitchDown,
    SwitchUp,
)
from .fattree import fat_tree_fabric, oversubscribed_leaf_spine
from .flowtable import FlowRule, FlowTable, FlowTables
from .paths import PathEngine, UnroutableError, k_shortest_paths
from .telemetry import (
    BeliefState,
    EwmaEstimator,
    LinkStatsMonitor,
    WindowRateEstimator,
)

__all__ = [
    "BeliefState",
    "DataPlane",
    "EwmaEstimator",
    "LinkStatsMonitor",
    "WindowRateEstimator",
    "FlowRule",
    "HostDown",
    "HostUp",
    "FlowTable",
    "FlowTables",
    "LinkDown",
    "LinkUp",
    "NetworkEvent",
    "PathEngine",
    "RerouteRecord",
    "SwitchDown",
    "SwitchUp",
    "UnroutableError",
    "fat_tree_fabric",
    "k_shortest_paths",
    "oversubscribed_leaf_spine",
]
