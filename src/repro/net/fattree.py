"""Multipath topology builders — fabrics with genuine path diversity.

Every builder in ``core.topology`` is a tree: one path per pair, nothing to
load-balance, nothing to fail over to.  These builders produce the
data-center shapes the multipath engine exists for:

* :func:`fat_tree_fabric` — the standard k-ary fat-tree (Al-Fares et al.,
  SIGCOMM'08): ``k`` pods of ``k/2`` edge + ``k/2`` aggregation switches,
  ``(k/2)²`` cores, full bisection bandwidth, ``(k/2)²`` equal-cost paths
  between hosts in different pods.
* :func:`oversubscribed_leaf_spine` — a two-tier Clos where every leaf
  uplinks to every spine; host:uplink capacity ratio sets the wired
  oversubscription, and ``n_spines`` sets the path diversity (ECMP width).

Both are built from raw ``add_link`` edges (they are not trees), so
``Fabric.path`` transparently uses Dijkstra and the k-shortest engine sees
every parallel path.  Naming is deterministic; roles are tagged so
``storage_hosts`` returns exactly the compute endpoints.

:func:`pod_partition` derives the pod structure back *out* of a built
fabric — which links are pod-internal, which cross the core — so the
hierarchical controller (``core.hierarchy``) can shard its ledger and
host ownership along the topology instead of guessing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from ..core.topology import Fabric


def fat_tree_fabric(k: int, link_mbps: float = 100.0) -> Fabric:
    """k-ary fat-tree: ``k`` pods, ``k²/4`` cores, ``k³/4`` hosts.

    Nodes: hosts ``pod<p>/h<e>_<i>``, edge ``pod<p>/edge<e>``, aggregation
    ``pod<p>/agg<a>``, cores ``core<g>_<j>`` (group ``g`` wires to agg
    index ``g`` of every pod).  All links share one capacity — the classic
    rearrangeably-nonblocking configuration.
    """
    if k < 2 or k % 2:
        raise ValueError(f"fat-tree arity must be even and >= 2, got {k}")
    half = k // 2
    f = Fabric()
    for g in range(half):
        for j in range(half):
            f.add_node(f"core{g}_{j}", "switch")
    for p in range(k):
        for a in range(half):
            agg = f"pod{p}/agg{a}"
            f.add_node(agg, "switch")
            for j in range(half):
                f.add_link(f"ac/p{p}a{a}c{j}", agg, f"core{a}_{j}", link_mbps)
        for e in range(half):
            edge = f"pod{p}/edge{e}"
            f.add_node(edge, "switch")
            for a in range(half):
                f.add_link(f"ea/p{p}e{e}a{a}", edge, f"pod{p}/agg{a}", link_mbps)
            for i in range(half):
                host = f"pod{p}/h{e}_{i}"
                f.add_node(host, "host")
                f.add_link(f"eh/p{p}e{e}h{i}", host, edge, link_mbps)
    return f


@dataclass(frozen=True)
class PodPartition:
    """Topology-derived pod structure of a fabric.

    * ``pods`` — pod ids, sorted (the ``podN`` prefix of the node names);
    * ``pod_links[p]`` — link names with *both* endpoints inside pod ``p``
      (edge–host and edge–agg tiers of a fat-tree, host NICs of a DCN pod);
    * ``boundary_links`` — every remaining link: at least one endpoint is a
      core/spine node or the endpoints live in different pods.  Exactly the
      links a cross-pod path must traverse — the root controller's slice;
    * ``pod_hosts[p]`` / ``host_pod`` — host ownership both ways.

    The shard contract (DESIGN.md §12): ``pod_links`` are pairwise disjoint,
    disjoint from ``boundary_links``, and their union is ``fabric.links`` —
    so a per-pod ledger shard plus the boundary shard partition the flat
    ledger's rows with nothing shared and nothing dropped, and any path
    between same-pod hosts stays inside that pod's shard.
    """

    pods: Tuple[str, ...]
    pod_links: Dict[str, Tuple[str, ...]]
    boundary_links: Tuple[str, ...]
    pod_hosts: Dict[str, Tuple[str, ...]]
    host_pod: Dict[str, str]

    def pod_of(self, host: str) -> Optional[str]:
        return self.host_pod.get(host)

    def groups(self) -> Dict[str, Tuple[str, ...]]:
        """Shard name → link names, boundary shard included — the exact
        ``groups`` argument ``timeslot.ShardedLedger`` takes."""
        out = dict(self.pod_links)
        out["__boundary__"] = self.boundary_links
        return out


def _node_pod(name: str) -> Optional[str]:
    """Pod id of a node by naming convention: ``pod<p>/...`` → ``pod<p>``.

    Every pod-structured builder in this repo (``fat_tree_fabric`` here,
    ``tpu_dcn_fabric`` in ``core.topology``) names pod members with a
    ``podN/`` prefix; cores/spines (``core0_1``, ``dcn-core``) carry none.
    """
    if name.startswith("pod"):
        head, sep, _ = name.partition("/")
        if sep:
            return head
    return None


def pod_partition(fabric: Fabric) -> PodPartition:
    """Classify a fabric's links and hosts into pods by topology.

    A link is pod-internal iff both endpoints resolve to the same pod;
    everything else (core uplinks, anything touching an unpodded switch)
    is a boundary link.  Raises ``ValueError`` when the fabric has no pods
    at all — a flat fabric has nothing to shard.
    """
    pod_links: Dict[str, list] = {}
    boundary: list = []
    for name in sorted(fabric.links):
        link = fabric.link(name)
        pa, pb = _node_pod(link.a), _node_pod(link.b)
        if pa is not None and pa == pb:
            pod_links.setdefault(pa, []).append(name)
        else:
            boundary.append(name)
    if not pod_links:
        raise ValueError("fabric has no pod-structured links to partition")
    pod_hosts: Dict[str, list] = {p: [] for p in pod_links}
    host_pod: Dict[str, str] = {}
    for name in sorted(fabric.nodes):
        if fabric.role(name) != "host":
            continue
        p = _node_pod(name)
        if p is not None and p in pod_hosts:
            pod_hosts[p].append(name)
            host_pod[name] = p
    pods = tuple(sorted(pod_links))
    return PodPartition(
        pods=pods,
        pod_links={p: tuple(v) for p, v in pod_links.items()},
        boundary_links=tuple(boundary),
        pod_hosts={p: tuple(v) for p, v in pod_hosts.items()},
        host_pod=host_pod,
    )


def oversubscribed_leaf_spine(
    n_leaves: int,
    n_spines: int,
    hosts_per_leaf: int,
    host_mbps: float = 100.0,
    spine_mbps: float = 400.0,
) -> Fabric:
    """Two-tier Clos with wired oversubscription.

    Hosts ``H<i>`` under leaves ``Leaf<j>``; every leaf connects to every
    spine (``ls/L<j>S<s>``), giving ``n_spines`` equal-cost leaf-to-leaf
    paths.  Oversubscription ratio =
    ``hosts_per_leaf·host_mbps / (n_spines·spine_mbps)``.  Host naming
    matches ``two_tier_fabric`` (``H0..``) so Table-I-style workloads drop
    in unchanged.
    """
    if n_leaves < 1 or n_spines < 1 or hosts_per_leaf < 1:
        raise ValueError("n_leaves, n_spines, hosts_per_leaf must be >= 1")
    f = Fabric()
    for s in range(n_spines):
        f.add_node(f"Spine{s}", "switch")
    for j in range(n_leaves):
        leaf = f"Leaf{j}"
        f.add_node(leaf, "switch")
        for s in range(n_spines):
            f.add_link(f"ls/L{j}S{s}", leaf, f"Spine{s}", spine_mbps)
        for i in range(hosts_per_leaf):
            h = j * hosts_per_leaf + i
            f.add_node(f"H{h}", "host")
            f.add_link(f"up/H{h}", f"H{h}", leaf, host_mbps)
    return f
