"""k-shortest-path engine — the data plane's multipath routing table.

The control plane (``core.topology.Fabric``) resolves *one* min-hop path
per node pair; real SDN data planes hold several candidates per pair so the
controller can load-balance (ECMP), steer around congestion, and fail over
when a link dies.  This module provides:

* :func:`k_shortest_paths` — Yen's algorithm over a ``Fabric`` with
  hop-count metric.  ``k=1`` returns exactly ``Fabric.path(src, dst)``
  (byte-identical — the regression the tier-1 tests pin), so single-path
  callers lose nothing by routing through the engine.
* :class:`PathEngine` — a per-fabric cache of candidate sets keyed on the
  fabric's mutation ``version``, with dead-link-aware :meth:`route` (the
  failure-rerouting entry) and vectorized scoring: candidates materialize
  as ``[n_paths, n_links]`` incidence rows so one
  :meth:`~repro.core.timeslot.TimeSlotLedger.path_bandwidth_batch` pass
  prices every path.

Ties break deterministically everywhere: Dijkstra relaxes links in sorted
name order with a lexicographic node tie-break (same discipline as
``Fabric.path``), and Yen's candidate pool orders by (hop count, link-name
sequence).
"""
from __future__ import annotations

import heapq
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.timeslot import TimeSlotLedger
from ..core.topology import Fabric, UnroutableError  # noqa: F401  (re-export)

Path = Tuple[str, ...]


#: link name -> additive link cost; ``None`` means hop count (cost 1/link),
#: which keeps the historical integer arithmetic bit-for-bit.
LinkCost = Optional[callable]


def _dijkstra(
    fabric: Fabric,
    src: str,
    dst: str,
    banned_links: FrozenSet[str],
    banned_nodes: FrozenSet[str],
    link_cost: LinkCost = None,
) -> Optional[Path]:
    """Min-cost Dijkstra that can exclude links/nodes (Yen spur searches).

    With ``link_cost=None`` (hop metric) this mirrors ``Fabric.path``'s
    relaxation order exactly so that with no exclusions the two agree
    link-for-link; a cost callable generalizes the metric while keeping
    the deterministic tie-breaks (sorted link relaxation, lexicographic
    node order in the heap).
    """
    if src == dst:
        return ()
    inf = float("inf") if link_cost is not None else (1 << 30)
    dist: Dict[str, float] = {src: 0}
    prev: Dict[str, Tuple[str, str]] = {}
    pq: List[Tuple[float, str]] = [(0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if u == dst:
            break
        if d > dist.get(u, inf):
            continue
        for lname in sorted(fabric.incident_links(u)):
            if lname in banned_links:
                continue
            v = fabric.link(lname).other(u)
            if v in banned_nodes:
                continue
            nd = d + (1 if link_cost is None else link_cost(lname))
            if nd < dist.get(v, inf):
                dist[v] = nd
                prev[v] = (u, lname)
                heapq.heappush(pq, (nd, v))
    if dst not in prev:
        return None
    rev: List[str] = []
    node = dst
    while node != src:
        pnode, via = prev[node]
        rev.append(via)
        node = pnode
    return tuple(reversed(rev))


def k_shortest_paths(
    fabric: Fabric,
    src: str,
    dst: str,
    k: int,
    banned_links: Iterable[str] = (),
    banned_nodes: Iterable[str] = (),
    link_cost: LinkCost = None,
) -> Tuple[Path, ...]:
    """Up to ``k`` loop-free min-cost paths src→dst (Yen's algorithm).

    The metric is hop count unless ``link_cost`` gives a per-link additive
    cost.  Fewer than ``k`` paths are returned when the graph holds fewer;
    :class:`UnroutableError` is raised when there is none at all.  With no
    exclusions and the hop metric the first path is
    ``Fabric.path(src, dst)`` verbatim.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    bl, bn = frozenset(banned_links), frozenset(banned_nodes)
    if src == dst:
        return ((),)
    if not bl and not bn and link_cost is None:
        first: Optional[Path] = fabric.path(src, dst)
    else:
        first = _dijkstra(fabric, src, dst, bl, bn, link_cost)
    if first is None:
        raise UnroutableError(f"no surviving path {src!r} -> {dst!r}")

    def path_cost(p: Path) -> float:
        # Hop metric: cost == len(p), so the historical (hops, path) pool
        # key survives as the degenerate case of (cost, hops, path).
        return len(p) if link_cost is None else sum(link_cost(l) for l in p)

    found: List[Path] = [first]
    seen = {first}
    pool: List[Tuple[float, int, Path]] = []  # (cost, hops, path) heap
    while len(found) < k:
        prev_path = found[-1]
        prev_nodes = fabric.path_nodes(src, prev_path)
        for j in range(len(prev_path)):
            spur_node = prev_nodes[j]
            root = prev_path[:j]
            # Paths already found that share this root may not be rediscovered:
            # ban their next link out of the spur node.
            spur_bl = set(bl)
            for p in found:
                if len(p) > j and p[:j] == root:
                    spur_bl.add(p[j])
            spur_bn = bn | set(prev_nodes[:j])
            spur = _dijkstra(fabric, spur_node, dst, frozenset(spur_bl),
                             spur_bn, link_cost)
            if spur is None:
                continue
            cand = root + spur
            if cand not in seen:
                seen.add(cand)
                heapq.heappush(pool, (path_cost(cand), len(cand), cand))
        if not pool:
            break
        _, _, best = heapq.heappop(pool)
        found.append(best)
    return tuple(found)


class PathEngine:
    """Cached k-shortest-path candidate sets over one :class:`Fabric`.

    Caches key on ``(src, dst, k)`` and are dropped wholesale whenever the
    fabric's ``version`` moves (link added) — the engine can never serve a
    pre-mutation path.

    ``cost`` selects the path metric:

    * ``"hop"`` (default) — hop count; byte-identical to the historical
      engine, and ``k=1`` returns ``Fabric.path`` verbatim.
    * ``"ospf"`` — OSPF-style inverse capacity (``ref_bw / capacity``,
      ``ref_bw`` = the fabric's fattest link), static per fabric version.
      On uniform-capacity fabrics every link costs 1.0 and the metric
      degenerates to hop count, tie-breaks included.
    * ``"residual"`` — inverse *residual* bandwidth against a live ledger
      at query time ``self.at`` (``ref_bw / max(residual_bw, eps)``):
      congested links price up and enumeration steers around bookings.
      Requires ``ledger=``; candidate sets are recomputed per call (the
      metric moves with the ledger) so this mode trades the cache for
      freshness — use it for explicit what-if queries, not hot paths.
    """

    COSTS = ("hop", "ospf", "residual")

    def __init__(self, fabric: Fabric, k: int = 4, cost: str = "hop",
                 ledger: Optional[TimeSlotLedger] = None) -> None:
        if cost not in self.COSTS:
            raise ValueError(f"cost must be one of {self.COSTS}, got {cost!r}")
        if cost == "residual" and ledger is None:
            raise ValueError('cost="residual" needs a ledger to read from')
        self.fabric = fabric
        self.k = int(k)
        self.cost = cost
        self.ledger = ledger
        #: Query time for the ``"residual"`` metric (sim seconds).
        self.at = 0.0
        self._cache: Dict[Tuple[str, str, int], Tuple[Path, ...]] = {}
        # Detour results under a specific dead-link set; keyed on the set
        # so liveness changes miss naturally (and the fast path below never
        # consults it).
        self._fail_cache: Dict[
            Tuple[str, str, int, FrozenSet[str]], Tuple[Path, ...]
        ] = {}
        # Surviving-candidate filter results keyed on the dead set — the
        # failure-storm hot path asks for the same pair under the same
        # overlay thousands of times per event.
        self._alive_cache: Dict[
            Tuple[str, str, int, FrozenSet[str]], Tuple[Path, ...]
        ] = {}
        # Dead-set-aware incidence: per candidate set, each path's links as
        # an integer id array (engine-local link index), so one boolean
        # gather prices a whole dead set against every candidate.
        self._link_idx: Dict[str, int] = {
            n: i for i, n in enumerate(sorted(fabric.links))
        }
        self._path_ids: Dict[Tuple[str, str, int], Tuple[np.ndarray, ...]] = {}
        self._version = fabric.version

    def _fresh(self) -> None:
        if self.fabric.version != self._version:
            self._cache.clear()
            self._fail_cache.clear()
            self._alive_cache.clear()
            self._path_ids.clear()
            self._link_idx = {
                n: i for i, n in enumerate(sorted(self.fabric.links))
            }
            self._version = self.fabric.version

    def _ids(self, src: str, dst: str, kk: int) -> Tuple[np.ndarray, ...]:
        """Each cached candidate's links as an id array (incidence rows)."""
        key = (src, dst, kk)
        hit = self._path_ids.get(key)
        if hit is None:
            li = self._link_idx
            hit = tuple(
                np.fromiter((li[n] for n in p), dtype=np.intp, count=len(p))
                for p in self.paths(src, dst, kk)
            )
            self._path_ids[key] = hit
        return hit

    def dead_vector(self, dead_links: Iterable[str]) -> np.ndarray:
        """Boolean liveness vector over the engine's link index."""
        vec = np.zeros(len(self._link_idx), dtype=bool)
        li = self._link_idx
        for n in dead_links:
            i = li.get(n)
            if i is not None:
                vec[i] = True
        return vec

    def _link_cost(self) -> LinkCost:
        """The engine's metric as a per-link cost callable (None = hop)."""
        if self.cost == "hop":
            return None
        fab = self.fabric
        caps = {n: fab.link(n).capacity for n in fab.links}
        ref = max(caps.values())
        if self.cost == "ospf":
            return lambda l: ref / caps[l]
        led, at = self.ledger, self.at
        eps = 1e-9

        def residual(l: str) -> float:
            bw = led.path_bandwidth(led.rows((l,)), at)
            return ref / (bw if bw > eps else eps)

        return residual

    def paths(self, src: str, dst: str, k: Optional[int] = None) -> Tuple[Path, ...]:
        """The cached candidate set (all links assumed alive).

        The ``"residual"`` metric bypasses the cache: its costs move with
        the ledger, so every call re-enumerates at the current ``at``."""
        kk = self.k if k is None else int(k)
        self._fresh()
        if self.cost == "residual":
            return k_shortest_paths(self.fabric, src, dst, kk,
                                    link_cost=self._link_cost())
        key = (src, dst, kk)
        hit = self._cache.get(key)
        if hit is None:
            hit = k_shortest_paths(self.fabric, src, dst, kk,
                                   link_cost=self._link_cost())
            self._cache[key] = hit
        return hit

    def route(
        self,
        src: str,
        dst: str,
        dead_links: Iterable[str] = (),
        k: Optional[int] = None,
    ) -> Tuple[Path, ...]:
        """Surviving candidates src→dst given ``dead_links``.

        Fast path: filter the cached candidate set.  If every cached
        candidate died, re-run Yen with the dead links excluded — a detour
        longer than the cached k-set can still exist.  Raises
        :class:`UnroutableError` when nothing survives.
        """
        dead = frozenset(dead_links)
        cands = self.paths(src, dst, k)
        if not dead:
            return cands
        kk = self.k if k is None else int(k)
        hit = self._alive(src, dst, kk, dead, None)
        if not hit:
            raise UnroutableError(f"no surviving path {src!r} -> {dst!r}")
        return hit

    def _alive(
        self,
        src: str,
        dst: str,
        kk: int,
        dead: FrozenSet[str],
        dead_vec: Optional[np.ndarray],
    ) -> Tuple[Path, ...]:
        """Cached surviving-candidate lookup shared by :meth:`route` and
        :meth:`route_batch` (one eviction bound, one key shape — the two
        entry points can never drift apart)."""
        key = (src, dst, kk, dead)
        hit = self._alive_cache.get(key)
        if hit is None:
            if dead_vec is None:
                dead_vec = self.dead_vector(dead)
            hit = self._survivors(src, dst, kk, dead, dead_vec)
            if len(self._alive_cache) > (1 << 18):
                self._alive_cache.clear()
            self._alive_cache[key] = hit
        return hit

    def _survivors(
        self,
        src: str,
        dst: str,
        kk: int,
        dead: FrozenSet[str],
        dead_vec: np.ndarray,
    ) -> Tuple[Path, ...]:
        """Incidence-filtered surviving candidates; Yen detour fallback.

        Returns ``()`` when *nothing* survives — cached too, so a pair
        proven unroutable under this dead set costs one dict hit on every
        later ask (the failure-storm candidate enumeration re-asks)."""
        cands = self.paths(src, dst, kk)
        ids = self._ids(src, dst, kk)
        alive = tuple(
            p for p, pid in zip(cands, ids)
            if not pid.size or not dead_vec[pid].any()
        )
        if alive:
            return alive
        key = (src, dst, kk, dead)
        hit = self._fail_cache.get(key)
        if hit is None:
            try:
                hit = k_shortest_paths(
                    self.fabric, src, dst, kk, banned_links=dead,
                    link_cost=self._link_cost(),
                )
            except UnroutableError:
                hit = ()
            if len(self._fail_cache) > (1 << 18):
                self._fail_cache.clear()  # bound flap-accumulated detours
            self._fail_cache[key] = hit
        return hit

    def route_batch(
        self,
        pairs: Sequence[Tuple[str, str]],
        dead_links: Iterable[str] = (),
        k: Optional[int] = None,
    ) -> Dict[Tuple[str, str], Tuple[Path, ...]]:
        """Surviving candidates for many endpoint pairs under one dead set.

        One liveness vector prices every pair's cached incidence rows; the
        per-(pair, dead-set) results land in the same cache :meth:`route`
        consults, so a failure storm's repeated pairs are one dict hit
        each.  Unroutable pairs map to ``()`` instead of raising — batch
        callers decide per pair (the reroute engine drops dead replicas
        from a candidate set and raises only when *every* replica died).
        """
        self._fresh()
        dead = frozenset(dead_links)
        kk = self.k if k is None else int(k)
        out: Dict[Tuple[str, str], Tuple[Path, ...]] = {}
        dead_vec: Optional[np.ndarray] = None
        if dead:
            dead_vec = self.dead_vector(dead)
        for src, dst in pairs:
            if (src, dst) in out:
                continue
            if not dead:
                try:
                    out[(src, dst)] = self.paths(src, dst, kk)
                except UnroutableError:
                    out[(src, dst)] = ()
                continue
            out[(src, dst)] = self._alive(src, dst, kk, dead, dead_vec)
        return out

    # -- vectorized scoring -------------------------------------------------
    def incidence(
        self, ledger: TimeSlotLedger, paths: Sequence[Path]
    ) -> np.ndarray:
        """``[n_paths, n_links]`` 0/1 incidence matrix in ledger row order."""
        m = np.zeros((len(paths), len(ledger.capacity)))
        for i, p in enumerate(paths):
            if p:
                m[i, list(ledger.rows(p))] = 1.0
        return m

    def score(
        self, ledger: TimeSlotLedger, paths: Sequence[Path], t: float
    ) -> np.ndarray:
        """Residual path bandwidth of every candidate at ``t`` — one
        :meth:`TimeSlotLedger.path_bandwidth_batch` numpy pass."""
        return ledger.path_bandwidth_batch([ledger.rows(p) for p in paths], t)

    def best(
        self, ledger: TimeSlotLedger, paths: Sequence[Path], t: float
    ) -> int:
        """Index of the best candidate: most residual bandwidth, ties to
        fewer hops then candidate order (Yen order is deterministic)."""
        bws = self.score(ledger, paths, t)
        return min(range(len(paths)), key=lambda i: (-bws[i], len(paths[i]), i))
