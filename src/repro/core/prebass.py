"""Pre-BASS — prefetching extension (Discussion 2 / Example 2).

Run BASS first, then for every data-remote task release its reservation and
re-plan the transfer *as early as the TS ledger allows* (instead of at the
destination's idle time), moving the block from the least-loaded replica
holder.  Compute on each node then starts at ``max(node availability,
transfer end)``, which can pull every later task on that node forward —
Example 2: TK1's transfer moves from TS4..TS8 to TS1..TS5, node N1 finishes
at 32 s instead of 35 s and the job at 34 s (last finisher becomes TK8).

The algorithm lives in :class:`repro.core.controller.PreBassPolicy`; this
wrapper is the historical offline entry point (DESIGN.md §1).  Both the
guard probe and the base BASS pass route through the wavefront engine
(``core.wavefront``, DESIGN.md §5); only the prefetch re-plan loop is
inherently sequential (each re-plan's window depends on the previous
release/commit pair).
"""
from __future__ import annotations

from typing import Optional

from .controller import PreBassPolicy, run_policy  # noqa: F401
from .tasks import Instance, Schedule
from .timeslot import TimeSlotLedger


def schedule_prebass(
    instance: Instance, ledger: Optional[TimeSlotLedger] = None
) -> Schedule:
    """BASS + prefetch refinement; never worse than plain BASS.

    The controller holds the global view, so when it owns the ledger (no
    shared ledger passed in) it evaluates the prefetched schedule against
    the base one and adopts whichever finishes earlier — prefetching with a
    different (least-loaded) source can, on adversarial ledgers, push a
    later task's window back, and the paper's intent ("further reduce the
    job completion time") is a refinement, not a regression."""
    return run_policy(PreBassPolicy(guard=ledger is None), instance, ledger)
