"""Pre-BASS — prefetching extension (Discussion 2 / Example 2).

Run BASS first, then for every data-remote task release its reservation and
re-plan the transfer *as early as the TS ledger allows* (instead of at the
destination's idle time), moving the block from the least-loaded replica
holder.  Compute on each node then starts at ``max(node availability,
transfer end)``, which can pull every later task on that node forward —
Example 2: TK1's transfer moves from TS4..TS8 to TS1..TS5, node N1 finishes
at 32 s instead of 35 s and the job at 34 s (last finisher becomes TK8).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .bass import pick_source, schedule_bass
from .tasks import Assignment, Instance, Schedule
from .timeslot import TimeSlotLedger


def schedule_prebass(
    instance: Instance, ledger: Optional[TimeSlotLedger] = None
) -> Schedule:
    """BASS + prefetch refinement; never worse than plain BASS.

    The controller holds the global view, so it evaluates the prefetched
    schedule against the base one and adopts whichever finishes earlier —
    prefetching with a different (least-loaded) source can, on adversarial
    ledgers, push a later task's window back, and the paper's intent
    ("further reduce the job completion time") is a refinement, not a
    regression."""
    base_makespan = schedule_bass(
        instance, instance.fresh_ledger() if ledger is None else None
    ).makespan if ledger is None else None
    out = _prefetch_schedule(instance, ledger)
    if base_makespan is not None and out.makespan > base_makespan + 1e-9:
        return schedule_bass(instance, instance.fresh_ledger())
    return out


def _prefetch_schedule(
    instance: Instance, ledger: Optional[TimeSlotLedger] = None
) -> Schedule:
    base = schedule_bass(instance, ledger)
    ledger = base.ledger
    tasks = {t.tid: t for t in instance.tasks}
    idle0 = dict(instance.idle)

    # Release every remote transfer, then re-plan in assignment order.
    remote = [a for a in base.assignments if a.transfer is not None]
    for a in remote:
        ledger.release(a.transfer)

    # Node availability proxy for "least loaded replica holder".
    load: Dict[str, float] = dict(idle0)
    for a in base.assignments:
        load[a.node] = max(load.get(a.node, 0.0), a.finish)

    ready: Dict[int, float] = {}
    for a in base.assignments:
        if a.transfer is None:
            ready[a.tid] = 0.0
            continue
        task = tasks[a.tid]
        src, rows = pick_source(
            task, a.node, ledger, at=0.0, idle=load, prefer_least_loaded=True
        )
        plan = ledger.plan_transfer(task.size, rows, not_before=0.0)
        ledger.commit(plan)
        a.source, a.transfer = src, plan
        ready[a.tid] = plan.end

    # Recompute per-node timelines with prefetched readiness.
    out: List[Assignment] = []
    for node, queue in base.by_node().items():
        t = idle0.get(node, 0.0)
        for a in queue:
            a.start = max(t, ready.get(a.tid, 0.0))
            a.finish = a.start + tasks[a.tid].compute
            t = a.finish
            out.append(a)

    out.sort(key=lambda a: a.tid)
    return Schedule(out, ledger, kinds={t.tid: t.kind for t in instance.tasks})
