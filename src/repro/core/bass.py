"""BASS — Bandwidth-Aware Scheduling with Sdn in hadoop (Algorithm 1).

Faithful implementation of the paper's Algorithm 1, per task ``TK_i``:

* Case 1   — a data-local node ``ND_loc`` exists (pick the least-loaded
  replica holder among available workers).
* Case 1.1 — ``ND_loc ≡ ND_minnow`` or ``ΥI_loc ≤ ΥI_minnow`` → run local
  (zero movement cost by Eq. 1).
* Case 1.2 — otherwise compute the bandwidth ``BW_{i,minnow}`` needed for the
  remote completion to beat the local one; if the real-time residue ``BW_rl``
  (the TS ledger, §IV.A) can supply it, run remote and reserve the slots of
  every link on the path ``ND_dataSrc → ND_minnow``.
* Case 1.3 — insufficient residue → run local.
* Case 2   — locality starvation (no replica holder is an available worker)
  → run on ``ND_minnow`` with a slot reservation.

The transfer starts at the destination's idle time ``ΥI_minnow`` — base BASS
does *not* prefetch (that is Pre-BASS, Example 2) — and by the paper's policy
consumes the full path residue until done, i.e. ``TM = SZ / BW_rl``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from .tasks import Assignment, Instance, Schedule, Task, completion_time
from .timeslot import TimeSlotLedger, TransferPlan

_EPS = 1e-9


def pick_minnow(idle: Dict[str, float], workers: Sequence[str]) -> str:
    """``ND_minnow``: the worker whose available idle time is minimum."""
    return min(workers, key=lambda n: (idle[n], n))


def pick_local(
    task: Task, idle: Dict[str, float], workers: Sequence[str]
) -> Optional[str]:
    """``ND_loc``: least-loaded *available* replica holder, or None (Case 2)."""
    holders = [n for n in task.replicas if n in workers]
    if not holders:
        return None
    return min(holders, key=lambda n: (idle[n], n))


def pick_source(
    task: Task,
    dst: str,
    ledger: TimeSlotLedger,
    at: float,
    idle: Optional[Dict[str, float]] = None,
    prefer_least_loaded: bool = False,
) -> Tuple[str, Tuple[int, ...]]:
    """Choose the replica to move data *from* (``ND_dataSrc``).

    Base BASS picks the replica whose path to ``dst`` has the most residual
    bandwidth at transfer time (ties: fewer hops, then name).  Pre-BASS
    prefers the least-loaded holder (Discussion 2: "always moved from the
    least loaded node storing the replica").
    """
    best: Optional[Tuple] = None
    for rep in task.replicas:
        if rep == dst:
            continue
        rows = ledger.rows(ledger.fabric.path(rep, dst))
        bw = ledger.path_bandwidth(rows, at)
        load = idle.get(rep, 0.0) if (prefer_least_loaded and idle) else 0.0
        key = (load, -bw, len(rows), rep)
        if best is None or key < best[0]:
            best = (key, rep, rows)
    assert best is not None, f"task {task.tid} has no off-node replica"
    return best[1], best[2]


def schedule_bass(
    instance: Instance,
    ledger: Optional[TimeSlotLedger] = None,
    order: Optional[Sequence[int]] = None,
) -> Schedule:
    """Run Algorithm 1 over ``instance.tasks`` (in submission order).

    ``ND_minnow`` is tracked with a lazy min-heap so scheduling stays
    O(m·(log n + R)) for m tasks, n nodes, R replicas — the 4 000-node /
    40 000-task regime of ``benchmarks/bench_sched_scale.py`` runs in
    seconds, which is what "deployable at 1000+ nodes" requires of a
    central controller.
    """
    idle = dict(instance.idle)
    ledger = ledger if ledger is not None else instance.fresh_ledger()
    tasks = {t.tid: t for t in instance.tasks}
    seq = list(order) if order is not None else [t.tid for t in instance.tasks]
    out: List[Assignment] = []
    heap = MinnowHeap(idle, instance.workers)

    for tid in seq:
        task = tasks[tid]
        out.append(_assign_one(task, idle, ledger, instance.workers, heap))

    return Schedule(out, ledger, kinds={t.tid: t.kind for t in instance.tasks})


class MinnowHeap:
    """Lazy min-heap over worker idle times (deterministic name tie-break)."""

    def __init__(self, idle: Dict[str, float], workers: Sequence[str]):
        import heapq

        self._heapq = heapq
        self._heap = [(idle[n], n) for n in workers]
        heapq.heapify(self._heap)

    def minnow(self, idle: Dict[str, float]) -> str:
        h = self._heap
        while True:
            t, n = h[0]
            if abs(idle[n] - t) <= _EPS:
                return n
            self._heapq.heapreplace(h, (idle[n], n))

    def update(self, node: str, new_idle: float) -> None:
        self._heapq.heappush(self._heap, (new_idle, node))


def _assign_one(
    task: Task,
    idle: Dict[str, float],
    ledger: TimeSlotLedger,
    workers: Sequence[str],
    heap: Optional["MinnowHeap"] = None,
) -> Assignment:
    minnow = heap.minnow(idle) if heap is not None else pick_minnow(idle, workers)
    loc = pick_local(task, idle, workers)

    if loc is not None and (minnow == loc or idle[loc] <= idle[minnow] + _EPS):
        # Case 1.1 — local is optimal, no movement (Eq. 1 with BW=∞).
        return _commit_local(task, loc, idle, heap)

    if loc is not None:
        # Case 1.2 / 1.3 — tradeoff governed by the TS ledger.
        yc_loc = completion_time(task.compute, 0.0, idle[loc])
        src, rows = pick_source(task, minnow, ledger, idle[minnow])
        plan = ledger.plan_transfer(task.size, rows, not_before=idle[minnow])
        tm = plan.end - plan.start if plan.slot_fracs else 0.0
        yc_min = completion_time(task.compute, 0.0, idle[minnow]) + tm
        # Algorithm 1 line 8: bandwidth needed so that ΥC_minnow < ΥC_loc.
        tm_budget = yc_loc - task.compute - idle[minnow]
        bw_needed = task.size / tm_budget if tm_budget > _EPS else float("inf")
        if yc_min < yc_loc - _EPS:
            # Case 1.2 — BW_{i,minnow} ≤ BW_rl: go remote, reserve the slots.
            ledger.commit(plan)
            start = plan.end if plan.slot_fracs else idle[minnow]
            finish = start + task.compute
            idle[minnow] = finish
            if heap is not None:
                heap.update(minnow, finish)
            return Assignment(task.tid, minnow, src, plan, start, finish, bw_needed)
        # Case 1.3 — residue insufficient: stay local.
        return _commit_local(task, loc, idle, heap, bw_needed=bw_needed)

    # Case 2 — locality starvation: remote on ND_minnow with reservation.
    src, rows = pick_source(task, minnow, ledger, idle[minnow])
    plan = ledger.plan_transfer(task.size, rows, not_before=idle[minnow])
    ledger.commit(plan)
    start = plan.end if plan.slot_fracs else idle[minnow]
    finish = start + task.compute
    idle[minnow] = finish
    if heap is not None:
        heap.update(minnow, finish)
    return Assignment(task.tid, minnow, src, plan, start, finish)


def _commit_local(
    task: Task,
    node: str,
    idle: Dict[str, float],
    heap: Optional["MinnowHeap"] = None,
    bw_needed: Optional[float] = None,
) -> Assignment:
    start = idle[node]
    finish = start + task.compute
    idle[node] = finish
    if heap is not None:
        heap.update(node, finish)
    return Assignment(task.tid, node, None, None, start, finish, bw_needed)
