"""BASS — Bandwidth-Aware Scheduling with Sdn in hadoop (Algorithm 1).

Faithful implementation of the paper's Algorithm 1, per task ``TK_i``:

* Case 1   — a data-local node ``ND_loc`` exists (pick the least-loaded
  replica holder among available workers).
* Case 1.1 — ``ND_loc ≡ ND_minnow`` or ``ΥI_loc ≤ ΥI_minnow`` → run local
  (zero movement cost by Eq. 1).
* Case 1.2 — otherwise compute the bandwidth ``BW_{i,minnow}`` needed for the
  remote completion to beat the local one; if the real-time residue ``BW_rl``
  (the TS ledger, §IV.A) can supply it, run remote and reserve the slots of
  every link on the path ``ND_dataSrc → ND_minnow``.
* Case 1.3 — insufficient residue → run local.
* Case 2   — locality starvation (no replica holder is an available worker)
  → run on ``ND_minnow`` with a slot reservation.

The transfer starts at the destination's idle time ``ΥI_minnow`` — base BASS
does *not* prefetch (that is Pre-BASS, Example 2) — and by the paper's policy
consumes the full path residue until done, i.e. ``TM = SZ / BW_rl``.

The decision logic lives in :class:`repro.core.controller.BassPolicy`
operating on a shared :class:`~repro.core.controller.ClusterState`; this
module is the historical offline entry point — a thin wrapper that remains
byte-identical to the pre-refactor batch scheduler (DESIGN.md §1).  Batch
placement routes through the wavefront engine (``core.wavefront``,
DESIGN.md §5): fused frontier-skipped candidate scans replace the
per-task ledger re-scans, bit-identically — the 4 096-host/40 000-task
fleet config of ``benchmarks/bench_sched_scale.py`` runs several times
faster than the per-task loop.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from .controller import (  # noqa: F401  (re-exported legacy surface)
    BassPolicy,
    MinnowHeap,
    choose_source,
    pick_local,
    pick_minnow,
    run_policy,
)
from .tasks import Instance, Schedule, Task
from .timeslot import TimeSlotLedger


def pick_source(
    task: Task,
    dst: str,
    ledger: TimeSlotLedger,
    at: float,
    idle: Optional[Dict[str, float]] = None,
    prefer_least_loaded: bool = False,
) -> Tuple[str, Tuple[int, ...]]:
    """Choose the replica to move data *from* (``ND_dataSrc``).

    Base BASS picks the replica whose path to ``dst`` has the most residual
    bandwidth at transfer time (ties: fewer hops, then name).  Pre-BASS
    prefers the least-loaded holder (Discussion 2: "always moved from the
    least loaded node storing the replica").
    """
    load = idle if (prefer_least_loaded and idle) else None
    return choose_source(task, dst, ledger, at, load=load)


def schedule_bass(
    instance: Instance,
    ledger: Optional[TimeSlotLedger] = None,
    order: Optional[Sequence[int]] = None,
) -> Schedule:
    """Run Algorithm 1 over ``instance.tasks`` (in submission order).

    ``ND_minnow`` is tracked with a lazy min-heap so scheduling stays
    O(m·(log n + R)) for m tasks, n nodes, R replicas — the 4 000-node /
    40 000-task regime of ``benchmarks/bench_sched_scale.py`` runs in
    seconds, which is what "deployable at 1000+ nodes" requires of a
    central controller.
    """
    return run_policy(BassPolicy(), instance, ledger, order=order)
