"""Wavefront placement engine — fused (task × replica × path) planning.

``BassPolicy.place`` decides one task at a time; each remote decision used
to re-scan the same ``[n_links, n_slots]`` ledger window per candidate
(``path_bandwidth_batch`` + ``plan_transfer``), so the controller's
decision loop — not the model — capped fleet throughput near ~2k tasks/s
at 4 096 hosts.  This engine plans *batches* of placements wave-by-wave
while staying **byte-identical** to the sequential greedy loop:

1. **Speculate** — from the exact current state, walk the next ``K``
   pending tasks with overlay-estimated idle times (``state.idle`` and
   the minnow heap are never corrupted), recording each task's likely
   decision context ``(dst = ND_minnow, t0 = ΥI_dst)`` and its candidate
   (replica × path) row sets (tree-LCA row cache / PathEngine).
2. **Broadcast** — score *every* recorded candidate in one array pass: a
   single ``[n_cand, max_path_len, window]`` ledger gather feeds the
   :func:`repro.kernels.ts_plan.plan_scan` residue→cummax→cumsum→
   searchsorted kernel (numpy reference by default, Pallas optional),
   yielding per-candidate residue curves, cumulative-deliverable curves,
   completion slots and plan ends — no per-candidate Python.
3. **Commit walk** — replay the tasks *in task order* against the exact
   state.  A task consumes its precomputed curves only if its speculated
   context matches bit-for-bit **and** no earlier commit this wave touched
   any (link, slot) cell its decision read (per-link dirty-slot map = the
   conflict set between wave winners).  Clean winners commit via the
   ledger's vectorized scatter; a stale or mis-speculated task re-scores
   live through the same fused kernel — the result is identical either
   way, only the work differs.  The next wave re-scores only invalidated
   candidates; still-clean curves carry over.

**Frontier skip.**  The paper's greedy policy consumes the *full* path
residue, so at steady state the ledger holds a backlog of fully-booked
slots and every plan lands at the residue frontier — thousands of slots
past ``slot_of(t0)``.  Scanning that prefix is pure waste: a slot whose
path residue is exactly zero contributes exactly ``0.0`` to the
cumulative-deliverable sum, so skipping it cannot change any float the
plan is built from.  The planner therefore keeps an exactly-full mask
(``reserved == 1.0``, built lazily per batch, updated in place on every
commit) plus per-link first-free pointers whose re-gallops amortize over
the batch, and starts each candidate's scan at the first slot not
covered by any full path link.  Commits only ever *add* reservations, so
a full slot stays full within a batch (releases happen between batches,
and the mask resets with each ``place_batch``).

Wave order replays task order, every float is produced by the same
expressions the sequential loop evaluates, and stale curves are never
consumed — so the emitted schedule is bit-identical to
``[policy.place(t, state) for t in tasks]`` (property-tested in
``tests/test_wavefront.py``, schedule-dump-diffed across the change).
See DESIGN.md §5 for the algorithm, conflict-set semantics and the
complexity table.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels import ts_plan
# One ND_loc implementation, shared with the sequential path (controller
# imports this module lazily inside place_batch, so there is no cycle).
from .controller import pick_local as _pick_local
from .tasks import Assignment, Task, completion_time
from .timeslot import TransferPlan
from .topology import UnroutableError

_EPS = 1e-9
_NEVER = np.iinfo(np.int64).max


class _Entry:
    """One task's scored candidate set + the decision context it was
    computed under.  Single-path entries carry the residue scores of all
    candidates (that is all ``choose_source`` reads) and the full
    ``plan_scan`` curve of the *winner* only; pairs-mode entries carry
    every candidate's curve (``choose_source_path`` compares plan ends)."""

    __slots__ = (
        "dst", "t0", "s0", "win", "cands", "srcs", "rows", "lens",
        "arrs", "caps", "score0", "winner", "best_end",
        # pairs mode: per-candidate curves
        "sz", "bw", "resid", "cum", "hit", "end", "fit_all",
        # single-path mode: winner-only curve (scalars / 1-D rows)
        "wsz", "wbw", "wresid", "wcum", "whit", "wend",
    )


class WavefrontPlanner:
    """Per-state wavefront engine (cached on the state; rebuilt when the
    fabric mutates).  ``place_batch`` is the only entry point."""

    MISS_STREAK = 16     # consecutive misses that force a fresh wave

    def __init__(self, state) -> None:
        self.state = state
        self.ledger = state.ledger
        self.fabric = state.fabric
        self._fab_version = self.fabric.version
        self._tree = self.fabric.tree_routing_ok()
        # node -> (chain nodes incl. self, {ancestor: depth}, uplink rows)
        self._chains: Dict[str, Optional[tuple]] = {}
        self._pair_cache: Dict[Tuple[str, str], tuple] = {}
        self._multi_cache: Dict[tuple, list] = {}
        self._entries: Dict[int, _Entry] = {}
        self._spec_until = 0
        n_links = len(self.ledger.capacity)
        self._dirty = np.full(n_links, _NEVER, dtype=np.int64)
        # Full-slot mask (reserved == 1.0), the frontier-skip evidence:
        # built lazily per batch, updated in place on every commit.  A
        # slot that is exactly full stays exactly full under commits, so
        # the mask only ever gains bits within a batch.
        self._full: Optional[np.ndarray] = None
        self._last_land = 0               # latest committed landing slot
        # Per-link first-free pointers: full on [nfb[l], nf[l]).
        self._nf = [0] * n_links
        self._nfb = [0] * n_links
        self._caplist = self.ledger.capacity.tolist()
        self._w_ema = 16.0                # EMA of observed plan spans
        self._hits_since_spec = 0
        # Adaptive speculation: waves pay only when curves survive to the
        # commit walk, so a persistently low hit rate turns them off and
        # the engine runs on the fused live path alone (re-probing later).
        self._spec_on = True
        self._spec_from = 0
        self._spec_resume = 0
        # Liveness: candidate row sets depend on the data plane's dead
        # set, so the pair/multi caches key on its mutation counter (a
        # fail/recover between batches drops them; healthy batches pay
        # nothing).  ``_dead`` is the current overlay, empty when healthy.
        self._dead: frozenset = frozenset()
        self._live_version = -1
        # Speculation counters live in the state's obs registry (same
        # dict-style surface as the plain dict they replaced); planner
        # rebuilds on the same state keep accumulating into one group.
        self.stats = state.obs.group(
            "wavefront", ("hits", "misses", "waves", "spec_tasks")
        )

    @classmethod
    def for_state(cls, state) -> "WavefrontPlanner":
        planner = getattr(state, "_wavefront", None)
        if (
            planner is None
            or planner.ledger is not state.ledger
            or planner._fab_version != state.fabric.version
        ):
            planner = cls(state)
            state._wavefront = planner
        return planner

    # -- the walk -----------------------------------------------------------
    def place_batch(
        self,
        tasks: Sequence[Task],
        multipath: bool = False,
        k_paths: Optional[int] = None,
    ) -> List[Assignment]:
        state = self.state
        idle = state.idle
        pairs_mode = bool(multipath) and state.dataplane is not None
        dp = state.dataplane
        if dp is not None and dp.liveness_version != self._live_version:
            self._pair_cache.clear()
            self._multi_cache.clear()
            self._live_version = dp.liveness_version
        self._dead = (
            dp.all_dead_links()
            if dp is not None and dp.has_failures()
            else frozenset()
        )
        self._entries = {}
        self._spec_until = 0
        self._dirty.fill(_NEVER)
        # Ledger may have been mutated between batches (releases, occupy,
        # direct writes): frontier evidence starts over.
        self._full = None
        self._last_land = 0
        n_links = len(self._nf)
        self._nf = [0] * n_links
        self._nfb = [0] * n_links
        self._w_ema = 16.0
        self._spec_on = True
        self._spec_from = 0
        self._spec_resume = 0
        self._hits_since_spec = 48  # seeds the first wave's lookahead
        miss_streak = 0
        out: List[Assignment] = []
        for i, task in enumerate(tasks):
            minnow = state.minnow()
            loc = _pick_local(task, idle, state.workers_set)
            if loc is not None and (
                minnow == loc or idle[loc] <= idle[minnow] + _EPS
            ):
                # Case 1.1 — local optimal; no ledger interaction at all.
                out.append(self._record(
                    state.commit_local(task, loc), task, "local-optimal"
                ))
                continue
            if self._spec_on:
                if i >= self._spec_until or miss_streak >= self.MISS_STREAK:
                    self._speculate(tasks, i, pairs_mode, k_paths)
                    miss_streak = 0
            elif i >= self._spec_resume:
                self._spec_on = True
                self._hits_since_spec = 8  # small probe wave
                self._spec_from = self._spec_until = i  # fresh probe stats
                self._speculate(tasks, i, pairs_mode, k_paths)
                miss_streak = 0
            at = idle[minnow]
            e = self._entries.get(i) if self._spec_on else None
            if (
                e is not None
                and e.dst == minnow
                and e.t0 == at
                and self._clean(e)
            ):
                self.stats["hits"] += 1
                self._hits_since_spec += 1
                miss_streak = 0
                src = e.srcs[e.winner]
                plan = self._winner_plan(e, task)
            else:
                self.stats["misses"] += 1
                miss_streak += 1
                src, plan = self._score_live(
                    task, minnow, at, pairs_mode, k_paths, reuse=e
                )
            out.append(self._finish(task, minnow, loc, at, src, plan))
        return out

    def _finish(
        self,
        task: Task,
        minnow: str,
        loc: Optional[str],
        at: float,
        src: str,
        plan: TransferPlan,
    ) -> Assignment:
        """Replay Algorithm 1's Case 1.2/1.3/2 arithmetic exactly as
        ``BassPolicy.place`` evaluates it, then commit + mark conflicts."""
        state = self.state
        idle = state.idle
        if loc is not None:
            yc_loc = completion_time(task.compute, 0.0, idle[loc])
            tm = plan.end - plan.start if plan.slot_fracs else 0.0
            yc_min = completion_time(task.compute, 0.0, idle[minnow]) + tm
            tm_budget = yc_loc - task.compute - idle[minnow]
            bw_needed = (
                task.size / tm_budget if tm_budget > _EPS else float("inf")
            )
            if yc_min < yc_loc - _EPS:
                a = state.commit_remote(task, minnow, src, plan,
                                        bw_needed=bw_needed)
                self._mark_dirty(plan)
                return self._record(a, task, "remote-faster")
            return self._record(
                state.commit_local(task, loc, bw_needed=bw_needed),
                task, "local-bw-insufficient",
            )
        a = state.commit_remote(task, minnow, src, plan)
        self._mark_dirty(plan)
        return self._record(a, task, "locality-starved")

    def _record(self, a: Assignment, task: Task, reason: str) -> Assignment:
        rec = self.state.obs.trace
        if rec.enabled:
            rec.record(
                "decision",
                tid=a.tid,
                node=a.node,
                src=a.source,
                reason=reason,
                cands=sum(1 for r in task.replicas if r != a.node),
                start=a.start,
                finish=a.finish,
                engine="wavefront",
            )
        return a

    def _mark_dirty(self, plan: TransferPlan) -> None:
        if not plan.slot_fracs:
            return
        first = plan.slot_fracs[0][0]
        if first > self._last_land:
            self._last_land = first
        d = self._dirty
        for r in plan.links:
            if first < d[r]:
                d[r] = first
        full = self._full
        if full is not None:
            # The mask is physical (column j ↔ absolute slot base + j);
            # plan fracs are absolute.  base is frozen for the batch —
            # retire() only runs between controller events.
            base = self.ledger.base_slot
            last = plan.slot_fracs[-1][0] - base
            if last >= full.shape[1]:
                full = self._fullmask()  # extend to the grown horizon
            if len(plan.slot_fracs) == 1:
                res = self.ledger.reserved
                for r in plan.links:
                    full[r, last] = res.item(r, last) == 1.0
            else:
                slots = [s - base for s, _ in plan.slot_fracs]
                rr = np.asarray(plan.links)[:, None]
                cc = np.asarray(slots)
                full[rr, cc] = self.ledger.reserved[rr, cc] == 1.0

    def _fullmask(self) -> np.ndarray:
        """The (links × slots) exactly-full mask, covering the ledger's
        current horizon.  Horizon growth extends with False columns (new
        slots are unbooked) instead of re-comparing the whole ledger."""
        full = self._full
        cols = self.ledger.reserved.shape[1]
        if full is None:
            full = self._full = self.ledger.reserved == 1.0
        elif full.shape[1] < cols:
            # Grow with geometric slack: the capacity-backed ledger view
            # widens a slot at a time, so an exact-fit mask would realloc
            # on nearly every commit.  Slack columns read False — the
            # mask's meaning for slots nothing has booked yet.
            wider = np.zeros(
                (full.shape[0], max(cols, 2 * full.shape[1])), dtype=bool
            )
            wider[:, : full.shape[1]] = full
            full = self._full = wider
        return full

    def _skip_path(self, idx, s0: int) -> int:
        """First slot ≥ s0 where *no* path link is exactly full — every
        slot in [s0, result) has exactly zero path residue, so a scan may
        start there without changing any plan float.

        Computed as a fixed point of per-link first-free pointers: each
        link caches (base, ptr) with "full on [base, ptr)"; queries with
        nondecreasing slots (the walk's ``t0`` is nondecreasing) reuse
        the pointer and only re-gallop the still-unverified tail, so the
        total gallop work per link is amortized over the whole batch.

        ``s0`` and the result are absolute slots; the pointers and the
        mask columns are physical (batch-local — the origin cannot move
        inside a batch)."""
        full = self._fullmask()
        base = self.ledger.base_slot
        horizon = full.shape[1]
        nf, nfb = self._nf, self._nfb
        j = s0 - base
        changed = True
        while changed:
            changed = False
            for l in idx:
                p = nf[l]
                b = nfb[l]
                row = full[l]
                if b <= j <= p and not (p < horizon and row.item(p)):
                    # cached run valid: [j, p) full, p free (or past the
                    # horizon, where nothing is booked yet).
                    if p > j:
                        j = p
                        changed = True
                    continue
                if b <= j <= p:
                    start = p   # commits extended the run: keep the base
                    base_l = b
                else:
                    start = j   # segment behind/ahead of j: start fresh
                    base_l = j
                p = start
                # Commits advance a link's frontier a slot or two at a
                # time: a short scalar walk resolves almost every update
                # without a vector gallop.
                lim = min(p + 16, horizon)
                while p < lim and row.item(p):
                    p += 1
                if p == lim and lim < horizon:
                    width = 64
                    while p < horizon:
                        seg = row[p: p + width]
                        if seg.all():
                            p += len(seg)
                            width *= 4
                            continue
                        p += int(seg.argmin())
                        break
                nf[l] = p
                nfb[l] = base_l
                if p > j:
                    j = p
                    changed = True
        return base + j

    def _clean(self, e: _Entry) -> bool:
        """True iff no commit since this entry's wave touched any
        (link, slot) cell its decision reads — the curves then equal what
        a live re-score would produce, bit for bit."""
        d = self._dirty
        if e.score0 is None:  # pairs mode: all candidate ends are compared
            if not e.fit_all:
                return False
            dmin = d[e.arrs].min(axis=1)
            return bool((dmin > e.sz + e.hit).all())
        # single-path: every candidate's residue at slot s0, winner's curve
        if d[e.arrs].min() <= e.s0:
            return False
        if e.whit >= e.win:
            return False
        return bool(d[e.arrs[e.winner]].min() > e.wsz + e.whit)

    # -- speculation --------------------------------------------------------
    def _speculate(
        self,
        tasks: Sequence[Task],
        i0: int,
        pairs_mode: bool,
        k_paths: Optional[int],
    ) -> None:
        """One wave: estimate the decision contexts of the next ``K``
        tasks, carry over still-clean curves, broadcast-score the rest.

        Speculation must leave the exact state untouched: estimated idle
        times live in an overlay dict (never ``state.idle``) and minnow
        queries run against a throwaway copy of the indexed heap —
        overrides push fresh entries onto the copy and superseded ones
        are discarded on pop.  The first speculated task therefore always
        sees its exact context.  Remote durations are estimated from the
        residue frontier (the last committed landing slot) plus the
        bottleneck transfer time — estimates steer only curve reuse,
        never results."""
        covered = self._spec_until - self._spec_from
        if covered >= 32 and self._hits_since_spec < 0.15 * covered:
            # Waves are not paying for themselves in this regime: drop to
            # the fused live path, re-probe a couple of thousand tasks on.
            self._spec_on = False
            self._spec_resume = i0 + 2048
            self._entries = {}
            return
        state = self.state
        idle = state.idle
        ledger = self.ledger
        dur = ledger.slot_duration
        # Speculation runs on a throwaway copy of the (exact, indexed)
        # minnow heap: overrides push fresh entries, superseded ones are
        # discarded on pop, and the real heap is never touched.
        h = list(state.heap._heap)
        k = int(min(4096, max(32, 2 * self._hits_since_spec + 8)))
        end_i = min(len(tasks), i0 + k)
        old = self._entries
        overrides: Dict[str, float] = {}
        specs: List[tuple] = []
        carried: Dict[int, _Entry] = {}

        def val(n: str) -> float:
            return overrides.get(n, idle[n])

        def bump(n: str, v: float) -> None:
            overrides[n] = v
            heapq.heappush(h, (v, n))

        def spec_minnow() -> str:
            while True:
                t, n = h[0]
                if t == val(n):
                    return n
                heapq.heappop(h)  # superseded by an override

        for j in range(i0, end_i):
            task = tasks[j]
            m = spec_minnow()
            holders = [n for n in task.replicas if n in state.workers_set]
            loc = (
                min(holders, key=lambda n: (val(n), n))
                if holders else None
            )
            if loc is not None and (
                m == loc or val(loc) <= val(m) + _EPS
            ):
                bump(loc, val(loc) + task.compute)
                continue
            at = val(m)
            est_end = None
            e = old.get(j)
            if (
                e is not None and e.dst == m and e.t0 == at
                and self._clean(e)
            ):
                carried[j] = e
                if np.isfinite(e.best_end):
                    est_end = float(e.best_end)
            if est_end is None:
                cands = None
                if j not in carried:
                    try:
                        cands = self._candidates(
                            task, m, pairs_mode, k_paths
                        )
                    except UnroutableError:
                        cands = []  # walk raises at the right task
                    if cands:
                        specs.append((j, task, m, at, cands))
                est_end = at
                if cands and task.size > 0:
                    # Transfers land at the advancing residue frontier;
                    # the last committed landing slot tracks it.
                    front = max(at, self._last_land * dur)
                    est_end = front + min(
                        (task.size / cap if cap > 0 else 0.0)
                        for _s, _rows, cap, _l in cands
                    )
            # Speculative Case 1.2/1.3/2 with the estimated ends.
            if loc is None:
                bump(m, est_end + task.compute)
            elif task.compute + at + (est_end - at) < (
                task.compute + val(loc)
            ) - _EPS:
                bump(m, est_end + task.compute)
            else:
                bump(loc, val(loc) + task.compute)
        self._entries = self._score_batch(specs, pairs_mode)
        self._entries.update(carried)
        self._dirty.fill(_NEVER)
        self._spec_from = i0
        self._spec_until = end_i
        self._hits_since_spec = 0
        self.stats["waves"] += 1
        self.stats["spec_tasks"] += end_i - i0

    # -- scoring ------------------------------------------------------------
    def _initial_window(self) -> int:
        """Power-of-4 initial scan window tracking the observed plan span
        (EMA) — under heavy contention greedy plans crawl through long
        partial-residue regions, and starting near the typical span saves
        the ×4 escalation re-scans."""
        w = 16
        target = min(self._w_ema * 1.25, float(1 << 16))
        while w < target:
            w *= 4
        return w

    def _curve_scan(self, pad, caps, s0c, t0c, sizes, sz, w):
        """Gather + ``plan_scan`` + plan-end extraction for one candidate
        row block — the fused ``ts_plan.wave_scan`` pipeline (device-
        resident when the device backend is live), every float by the
        same expressions ``plan_transfer`` evaluates per scalar
        (max/sub/div are elementwise-identical).  ``sz`` is the
        per-candidate frontier-skipped scan base."""
        ledger = self.ledger
        dur = ledger.slot_duration
        first_secs = np.where(sz > s0c, dur, (s0c + 1) * dur - t0c)
        resid, bw, cum, hit, end = ts_plan.wave_scan(
            ledger, pad, caps, sz, t0c, sizes, w, first_secs
        )
        fit = hit[hit < w]
        if fit.size:
            self._w_ema = 0.8 * self._w_ema + 0.2 * (float(fit.mean()) + 8.0)
        return sz, resid, bw, cum, hit, end

    def _score_batch(
        self,
        specs: List[tuple],
        pairs_mode: bool,
        window: Optional[int] = None,
    ) -> Dict[int, _Entry]:
        """The broadcast pass.  Single-path mode gathers one residue slot
        per candidate (all ``choose_source`` reads), picks each task's
        winner, then deep-scans *only the winners* in one block; pairs
        mode deep-scans every candidate (``choose_source_path`` compares
        every plan end)."""
        if not specs:
            return {}
        ledger = self.ledger
        w = self._initial_window() if window is None else window
        counts = [len(s[4]) for s in specs]
        n_cand = sum(counts)
        wl = max(
            max(c[3] for c in s[4]) for s in specs
        )
        pad = np.empty((n_cand, wl), dtype=np.intp)
        caps = np.empty(n_cand)
        s0c = np.empty(n_cand, dtype=np.int64)
        pos = 0
        for j, task, dst, at, cands in specs:
            s0 = ledger.slot_of(at)
            for _src, rows, cap, ln in cands:
                pad[pos, :ln] = rows
                pad[pos, ln:] = rows[0]
                caps[pos] = cap
                s0c[pos] = s0
                pos += 1

        if pairs_mode:
            t0c = np.empty(n_cand)
            sizes = np.empty(n_cand)
            sz = np.empty(n_cand, dtype=np.int64)
            pos = 0
            for (j, task, dst, at, cands), cnt in zip(specs, counts):
                s0 = int(s0c[pos])
                for c, cand in enumerate(cands):
                    sz[pos + c] = self._skip_path(list(cand[1]), s0)
                t0c[pos: pos + cnt] = at
                sizes[pos: pos + cnt] = task.size
                pos += cnt
            _sz, resid, bw, cum, hit, end = self._curve_scan(
                pad, caps, s0c, t0c, sizes, sz, w
            )
            # choose_source_path's key is (end, hops, name, cand order);
            # each candidate's precomputed rank — its position in the
            # segment's (hops, name, order) sort — reduces the key to
            # (end, rank), so one batched per-segment argmin
            # (ts_plan.wave_select) picks every wave's winners at once.
            ranks = np.empty(n_cand, dtype=np.int64)
            pos = 0
            for (j, task, dst, at, cands), cnt in zip(specs, counts):
                order = sorted(
                    range(cnt), key=lambda c: (cands[c][3], cands[c][0], c)
                )
                for r, c in enumerate(order):
                    ranks[pos + c] = r
                pos += cnt
            winners = ts_plan.wave_select(end, ranks, counts)
            entries: Dict[int, _Entry] = {}
            pos = 0
            for si, ((j, task, dst, at, cands), cnt) in enumerate(
                zip(specs, counts)
            ):
                sl = slice(pos, pos + cnt)
                pos += cnt
                e = _Entry()
                e.dst, e.t0, e.s0 = dst, at, int(s0c[sl.start])
                e.win = w
                e.cands = cands
                e.srcs = [c[0] for c in cands]
                e.rows = [c[1] for c in cands]
                e.lens = [c[3] for c in cands]
                e.arrs = pad[sl]
                e.caps = caps[sl]
                e.score0 = None
                e.sz = sz[sl]
                e.bw = bw[sl]
                e.resid = resid[sl]
                e.cum = cum[sl]
                e.hit = hit[sl]
                e.end = end[sl]
                e.fit_all = bool((e.hit < w).all())
                e.winner = int(winners[si])
                e.best_end = float(e.end[e.winner])
                entries[j] = e
            return entries

        # single-path: residue at slot_of(t0) is the whole selection input
        ledger._ensure(int(s0c.max()))
        booked0 = ledger.reserved[pad, (s0c - ledger.base_slot)[:, None]]
        score0 = ((1.0 - booked0) * ledger.capacity[pad]).min(axis=1)
        entries = {}
        pos = 0
        for (j, task, dst, at, cands), cnt in zip(specs, counts):
            sl = slice(pos, pos + cnt)
            pos += cnt
            e = _Entry()
            e.dst, e.t0, e.s0 = dst, at, int(s0c[sl.start])
            e.cands = cands
            e.srcs = [c[0] for c in cands]
            e.rows = [c[1] for c in cands]
            e.lens = [c[3] for c in cands]
            e.arrs = pad[sl]
            e.caps = caps[sl]
            e.score0 = score0[sl]
            s = e.score0
            # choose_source's key: (-bw, hops, name)
            e.winner = min(
                range(cnt), key=lambda c: (-s[c], e.lens[c], e.srcs[c])
            )
            entries[j] = e
        # deep-scan the winners only, as one block
        n = len(specs)
        padw = np.empty((n, wl), dtype=np.intp)
        capw = np.empty(n)
        s0w = np.empty(n, dtype=np.int64)
        t0w = np.empty(n)
        sizew = np.empty(n)
        szw = np.empty(n, dtype=np.int64)
        for k, (j, task, dst, at, cands) in enumerate(specs):
            e = entries[j]
            c = e.winner
            padw[k] = e.arrs[c]
            capw[k] = e.caps[c]
            s0w[k] = e.s0
            t0w[k] = at
            sizew[k] = task.size
            szw[k] = self._skip_path(list(e.rows[c]), e.s0)
        sz, resid, bw, cum, hit, end = self._curve_scan(
            padw, capw, s0w, t0w, sizew, szw, w
        )
        for k, (j, task, dst, at, cands) in enumerate(specs):
            e = entries[j]
            e.win = w
            e.wsz = int(sz[k])
            e.wbw = bw[k]
            e.wresid = resid[k]
            e.wcum = cum[k]
            e.whit = int(hit[k])
            e.wend = float(end[k])
            e.best_end = e.wend
        return entries

    def _score_live(
        self,
        task: Task,
        dst: str,
        at: float,
        pairs_mode: bool,
        k_paths: Optional[int],
        reuse: Optional[_Entry] = None,
    ) -> Tuple[str, TransferPlan]:
        """Exact re-score of one task against the live ledger — the fused
        fallback for mis-speculated or conflict-invalidated tasks.  A
        stale entry whose context still matches donates its candidate row
        sets, so only the residue reads and the winner scan re-run.
        Scalar-weight on purpose: scores are a handful of residue reads
        (all ``choose_source`` consults) and only the winner pays a plan
        scan, frontier-skipped and window-escalated like
        ``plan_transfer``."""
        if not pairs_mode and self._tree and not self._dead:
            got = self._score_tree(task, dst, at)
            if got is not None:
                return got
        if reuse is not None and reuse.dst == dst and reuse.t0 == at:
            cands = reuse.cands
        else:
            cands = self._candidates(task, dst, pairs_mode, k_paths)
        if not cands:
            if pairs_mode or self._dead:
                raise UnroutableError(
                    f"task {task.tid}: no replica has a surviving path to {dst!r}"
                )
            raise AssertionError(f"task {task.tid} has no off-node replica")
        ledger = self.ledger
        s0 = ledger.slot_of(at)
        if pairs_mode:
            # choose_source_path compares every candidate's plan end.
            plans = [
                self._plan_one(rows, cap, s0, at, task.size)
                for _s, rows, cap, _l in cands
            ]
            best = min(
                range(len(cands)),
                key=lambda c: (plans[c].end, cands[c][3], cands[c][0], c),
            )
            return cands[best][0], plans[best]
        ledger._ensure(s0)
        res = ledger.reserved
        capacity = ledger.capacity
        # path_bandwidth_batch's residue-at-slot: one gather over every
        # candidate link, then pure-float mins (same doubles, no ufunc
        # dispatch per element).
        flat = [r for _s, rows, _cap, _l in cands for r in rows]
        vals = (
            (1.0 - res[flat, s0 - ledger.base_slot]) * capacity[flat]
        ).tolist()
        scores = []
        pos = 0
        for _s, rows, _cap, _l in cands:
            nxt = pos + len(rows)
            scores.append(min(vals[pos:nxt]))
            pos = nxt
        best = 0
        bkey = (-scores[0], cands[0][3], cands[0][0])
        for c in range(1, len(cands)):
            key = (-scores[c], cands[c][3], cands[c][0])
            if key < bkey:
                best, bkey = c, key
        src, rows, cap, _l = cands[best]
        return src, self._plan_one(rows, cap, s0, at, task.size)

    def _score_tree(
        self, task: Task, dst: str, at: float
    ) -> Optional[Tuple[str, TransferPlan]]:
        """Tree-fabric fast path for single-path scoring: evaluate every
        replica's residue score straight off the cached LCA chains
        (python floats — the same doubles ``path_bandwidth_batch``
        computes) and materialize only the winner's row tuple.  Returns
        ``None`` when any endpoint falls outside the routing tree (the
        generic candidate path takes over)."""
        ledger = self.ledger
        s0 = ledger.slot_of(at)
        p0 = s0 - ledger.base_slot
        if p0 >= ledger.reserved.shape[1]:
            ledger._ensure(s0)
        res = ledger.reserved
        caplist = self._caplist
        best = None
        best_key = None
        found = False
        for rep in task.replicas:
            if rep == dst:
                continue
            found = True
            ca = self._chain(rep)
            cb = self._chain(dst)
            if ca is None or cb is None:
                return None
            nodes_a, _anc_a, links_a, pcaps_a = ca
            _nodes_b, anc_b, links_b, pcaps_b = cb
            j = None
            for i, name in enumerate(nodes_a):
                j = anc_b.get(name)
                if j is not None:
                    break
            if j is None:
                return None  # different trees: generic Dijkstra path
            s = float("inf")
            for l in links_a[:i]:
                v = (1.0 - res.item(l, p0)) * caplist[l]
                if v < s:
                    s = v
            for l in links_b[:j]:
                v = (1.0 - res.item(l, p0)) * caplist[l]
                if v < s:
                    s = v
            key = (-s, i + j, rep)
            if best_key is None or key < best_key:
                best_key = key
                best = (rep, i, j, links_a, links_b, pcaps_a, pcaps_b)
        if not found:
            raise AssertionError(f"task {task.tid} has no off-node replica")
        rep, i, j, links_a, links_b, pcaps_a, pcaps_b = best
        rows = links_a[:i] + tuple(reversed(links_b[:j]))
        cap = min(pcaps_a[i], pcaps_b[j])
        return rep, self._plan_one(rows, cap, s0, at, task.size)

    def _plan_one(
        self, rows: Tuple[int, ...], cap: float, s0: int, t0: float,
        size: float,
    ) -> TransferPlan:
        """One candidate's greedy plan — ``plan_transfer`` with the
        frontier skip (bit-identical: the skipped prefix has exactly zero
        path residue, contributing exactly ``0.0`` to the cumsum)."""
        ledger = self.ledger
        if size <= 0 or not rows:
            return TransferPlan(tuple(rows), t0, t0, ())
        idx = list(rows)
        sz = self._skip_path(idx, s0)
        # plan_transfer's horizon: windows escalate 64..65536 *from s0*
        # and a transfer not completing by s0 + 2^16 slots raises.  The
        # skip must not extend that reach, or the batch and sequential
        # paths would diverge on pathological backlogs.
        max_abs = s0 + (1 << 16)
        dur = ledger.slot_duration
        base = ledger.base_slot  # frozen for the batch (slots are absolute)
        # Scalar micro-scan: post-skip, almost every plan completes within
        # a few slots.  numpy's cumsum is a strict sequential accumulation,
        # so a Python walk computing cum_j = cum_{j-1} + bw_j*secs_j with
        # np.float64 scalars produces bit-identical floats — without the
        # ~1.5µs-per-call numpy dispatch the vector path pays ~10× over.
        lim = 24
        if sz + lim - base > ledger.reserved.shape[1]:
            ledger._ensure(sz + lim - 1)
        rowviews = [ledger.reserved[r] for r in idx]
        target = size - _EPS
        cum = 0.0
        sel: List[int] = []
        cums: List[float] = []
        bws: List[float] = []
        resids: List[float] = []
        hit = -1
        for j in range(lim):
            p = sz + j - base
            mx = rowviews[0].item(p)  # python floats: same IEEE doubles,
            for rv in rowviews[1:]:   # no per-element ufunc dispatch
                v = rv.item(p)
                if v > mx:
                    mx = v
            resid = 1.0 - mx
            bw = resid * cap
            secs = dur if (j > 0 or sz != s0) else (s0 + 1) * dur - t0
            cum = cum + bw * secs
            bws.append(bw)
            resids.append(resid)
            cums.append(cum)
            if bw > _EPS:
                sel.append(j)
            if cum >= target:
                hit = j
                break
        if hit >= 0:
            if sz + hit >= max_abs:
                raise RuntimeError(
                    "transfer does not fit within max_slots horizon"
                )
            self._w_ema = 0.8 * self._w_ema + 0.2 * (hit + 8.0)
            first = sel[0]
            start = max(t0, (sz + first) * dur)
            before = cums[hit - 1] if hit > 0 else 0.0
            t_in = max(t0, (sz + hit) * dur)
            end = t_in + (size - before) / bws[hit]
            fracs = tuple((sz + j, resids[j]) for j in sel)
            return TransferPlan(tuple(rows), start, end, fracs)
        reserved = ledger.reserved
        window = self._initial_window()
        while True:
            ledger._ensure(sz + window - 1)
            if reserved is not ledger.reserved:
                reserved = ledger.reserved
            lo = sz - base
            hi = lo + window
            # max over path links as pairwise np.maximum on row slices —
            # bit-identical to .max(axis=0) (max is exact) and ~3× faster
            # on the short windows the frontier skip leaves.
            mx = reserved[idx[0], lo:hi]
            for r in idx[1:]:
                mx = np.maximum(mx, reserved[r, lo:hi])
            resid = 1.0 - mx
            bw = resid * cap
            # deliverable = bw * secs with secs == dur everywhere except a
            # partial first slot — same elementwise products, no secs array.
            deliv = bw * dur
            if sz == s0:
                deliv[0] = bw[0] * ((s0 + 1) * dur - t0)
            cum = np.cumsum(deliv)
            hit = int(np.searchsorted(cum, size - _EPS))
            if hit < window:
                if sz + hit >= max_abs:
                    raise RuntimeError(
                        "transfer does not fit within max_slots horizon"
                    )
                self._w_ema = 0.8 * self._w_ema + 0.2 * (hit + 8.0)
                return ledger._plan_from_scan(
                    tuple(rows), sz, t0, size, bw, resid, cum, hit
                )
            if sz + window >= max_abs:
                raise RuntimeError(
                    "transfer does not fit within max_slots horizon"
                )
            window *= 4

    def _winner_plan(self, e: _Entry, task: Task) -> TransferPlan:
        c = e.winner
        if task.size <= 0:
            return TransferPlan(e.rows[c], e.t0, e.t0, ())
        if e.score0 is not None:
            if e.whit >= e.win:
                # Defensive only: unfit winners are rejected by _clean and
                # escalated by _score_live before reaching here.
                return self.ledger.plan_transfer(
                    task.size, e.rows[c], not_before=e.t0
                )
            return self.ledger._plan_from_scan(
                e.rows[c], e.wsz, e.t0, task.size,
                e.wbw, e.wresid, e.wcum, e.whit,
            )
        if e.hit[c] >= e.win:
            return self.ledger.plan_transfer(
                task.size, e.rows[c], not_before=e.t0
            )
        return self.ledger._plan_from_scan(
            e.rows[c], int(e.sz[c]), e.t0, task.size,
            e.bw[c], e.resid[c], e.cum[c], int(e.hit[c]),
        )

    # -- candidate row sets -------------------------------------------------
    def _candidates(
        self, task: Task, dst: str, pairs_mode: bool, k_paths: Optional[int]
    ) -> list:
        """[(src, rows_tuple, padded_row_array, bottleneck_cap, hops)] in
        the exact enumeration order of the sequential scorers.  Under
        live routing (``self._dead`` non-empty) candidates come from the
        data plane's surviving sets — dead links price replicas out here,
        exactly as ``ClusterState.choose_source`` drops them."""
        out: list = []
        if pairs_mode:
            for rep in task.replicas:
                if rep == dst:
                    continue
                key = (rep, dst, k_paths)
                lst = self._multi_cache.get(key)
                if lst is None:
                    try:
                        paths = self.state.dataplane.candidates(
                            rep, dst, k=k_paths
                        )
                    except UnroutableError:
                        lst = []
                    else:
                        lst = [
                            self._mk_cand(self.ledger.rows(p)) for p in paths
                        ]
                    if len(self._multi_cache) > (1 << 18):
                        self._multi_cache.clear()
                    self._multi_cache[key] = lst
                out.extend((rep,) + c for c in lst)
            return out
        if self._dead:
            # Failure-aware single path: each replica contributes its best
            # surviving path (k=1: Yen's first path, no spur searches);
            # unroutable replicas drop out of the candidate set.
            for rep in task.replicas:
                if rep == dst:
                    continue
                key = (rep, dst)
                hit = self._pair_cache.get(key, False)
                if hit is False:
                    try:
                        paths = self.state.dataplane.candidates(rep, dst, k=1)
                    except UnroutableError:
                        hit = None
                    else:
                        hit = self._mk_cand(self.ledger.rows(paths[0]))
                    if len(self._pair_cache) > (1 << 18):
                        self._pair_cache.clear()
                    self._pair_cache[key] = hit
                if hit is not None:
                    out.append((rep,) + hit)
            return out
        for rep in task.replicas:
            if rep == dst:
                continue
            out.append((rep,) + self._pair(rep, dst))
        return out

    def _pair(self, src: str, dst: str) -> tuple:
        hit = self._pair_cache.get((src, dst))
        if hit is None:
            res = self._tree_rows(src, dst)
            if res is None:
                hit = self._mk_cand(
                    self.ledger.rows(self.fabric.path(src, dst))
                )
            else:
                rows, cap = res
                hit = (rows, cap, len(rows))
            if len(self._pair_cache) > (1 << 18):
                self._pair_cache.clear()
            self._pair_cache[(src, dst)] = hit
        return hit

    def _mk_cand(self, rows: Sequence[int]) -> tuple:
        rows = tuple(rows)
        if rows:
            capacity = self.ledger.capacity
            cap = min(float(capacity[r]) for r in rows)
        else:
            cap = float("inf")
        return (rows, cap, len(rows))

    def _chain(self, node: str):
        hit = self._chains.get(node, False)
        if hit is not False:
            return hit
        try:
            pc = self.fabric.parent_chain(node)
            nodes = (node,) + tuple(p for p, _ in pc)
            # KeyError: the chain leaves the ledger's link subset — a
            # per-pod frontier planning over its shard (core.hierarchy)
            # whose root chain crosses the pod boundary.  Fall back to the
            # Dijkstra/path-cache pair path, which only translates links
            # the (pod-internal) path actually uses.
            rows = self.ledger.rows([l for _, l in pc])
        except (ValueError, KeyError):
            res = None
        else:
            caps = self.ledger.capacity
            pcaps = [float("inf")]  # pcaps[d] = bottleneck of first d links
            m = float("inf")
            for r in rows:
                c = float(caps[r])
                if c < m:
                    m = c
                pcaps.append(m)
            res = (
                nodes,
                {nm: i for i, nm in enumerate(nodes)},
                rows,
                tuple(pcaps),
            )
        self._chains[node] = res
        return res

    def _tree_rows(self, src: str, dst: str) -> Optional[tuple]:
        """Integer-row LCA walk — exactly ``Fabric._tree_path``'s link
        order (up-chain to the LCA, then the reversed down-chain), else
        ``None`` for the Dijkstra/path-cache fallback.  Returns
        ``(rows, bottleneck_cap)``, the cap from the chains' prefix-min
        tables (same min, no per-path capacity reduction)."""
        if not self._tree:
            return None
        ca = self._chain(src)
        cb = self._chain(dst)
        if ca is None or cb is None:
            return None
        nodes_a, anc_a, links_a, pcaps_a = ca
        nodes_b, anc_b, links_b, pcaps_b = cb
        # (no different-trees precheck: the LCA loop below returns None
        # when the chains share no node, which is the same answer
        # ``Fabric._tree_path``'s early-out produces)
        for i, name in enumerate(nodes_a):
            j = anc_b.get(name)
            if j is not None:
                rows = links_a[:i] + tuple(reversed(links_b[:j]))
                return rows, min(pcaps_a[i], pcaps_b[j])
        return None
