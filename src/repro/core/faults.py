"""Seeded, deterministic fault injection (DESIGN.md §10).

A :class:`FaultPlan` is a frozen script of host crashes/recoveries,
straggler onsets and link flaps, generated *up front* from a single
``random.Random(seed)`` stream and independent of anything the controller
later decides.  Applying the same plan to the same workload is therefore
reproducible down to the byte: every kill, retry, backoff, blacklist
decision and speculation outcome happens at a scripted sim time, and the
controller's own event loop is already deterministic (heap order =
``(at, submission seq)``), so same seed ⇒ byte-identical schedule dumps.

The plan *compiles to controller events* — ``apply()`` queues each fault
through the public ``fail_host`` / ``recover_host`` / ``straggle`` /
``fail_link`` / ``recover_link`` entry points, the same calls a live
operator (or the heartbeat sweep) would make.  Nothing here reaches into
controller internals.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class HostCrash:
    """Host dies at ``at``; recovers at ``recover_at`` (None: stays dead)."""

    node: str
    at: float
    recover_at: Optional[float] = None


@dataclass(frozen=True)
class StragglerOnset:
    """Whatever runs on ``node`` at ``at`` needs ``factor``× its remaining
    compute (the progress-rate model)."""

    node: str
    at: float
    factor: float


@dataclass(frozen=True)
class LinkFlap:
    """Link dies at ``at`` and comes back at ``up_at``."""

    link: str
    at: float
    up_at: float


@dataclass(frozen=True)
class ControllerCrash:
    """The control plane itself dies at ``at``; recovers at ``recover_at``
    (None: stays headless — the data plane finishes what was installed
    and everything else waits)."""

    at: float
    recover_at: Optional[float] = None


FaultEvent = "HostCrash | StragglerOnset | LinkFlap | ControllerCrash"


@dataclass(frozen=True)
class FaultPlan:
    """A frozen fault script: generate once, apply to any controller."""

    seed: int
    events: Tuple[object, ...] = field(default_factory=tuple)

    @classmethod
    def generate(
        cls,
        seed: int,
        hosts: Sequence[str],
        t0: float,
        t1: float,
        links: Sequence[str] = (),
        n_crashes: int = 0,
        mttr: float = 0.0,
        n_stragglers: int = 0,
        slow_factor: Tuple[float, float] = (2.0, 6.0),
        n_flaps: int = 0,
        flap_duration: float = 1.0,
        n_ctrl_crashes: int = 0,
        ctrl_mttr: float = 1.0,
    ) -> "FaultPlan":
        """Draw a plan from ``random.Random(seed)`` — one stream, fixed
        draw order (crashes, then stragglers, then flaps, then controller
        crashes), so the script is a pure function of the arguments and
        plans drawn before controller crashes existed are byte-identical.

        Crash/straggle/flap times are uniform in ``[t0, t1)``; a crash
        recovers ``mttr`` sim-seconds later (``mttr <= 0``: stays dead);
        straggler factors are uniform in ``slow_factor``.  Hosts are
        sampled without replacement per category (a host can both crash
        and straggle — that is realistic churn).  Controller crashes
        recover ``ctrl_mttr`` later (``<= 0``: stays headless).
        """
        rng = random.Random(seed)
        hosts = list(hosts)
        links = list(links)
        events: List[object] = []
        for node in rng.sample(hosts, min(n_crashes, len(hosts))):
            at = rng.uniform(t0, t1)
            events.append(HostCrash(
                node, at, at + mttr if mttr > 0.0 else None
            ))
        for node in rng.sample(hosts, min(n_stragglers, len(hosts))):
            at = rng.uniform(t0, t1)
            events.append(StragglerOnset(
                node, at, rng.uniform(*slow_factor)
            ))
        for link in rng.sample(links, min(n_flaps, len(links))):
            at = rng.uniform(t0, t1)
            events.append(LinkFlap(link, at, at + flap_duration))
        for _ in range(n_ctrl_crashes):
            at = rng.uniform(t0, t1)
            events.append(ControllerCrash(
                at, at + ctrl_mttr if ctrl_mttr > 0.0 else None
            ))
        events.sort(key=lambda e: (e.at, type(e).__name__, _key(e)))
        return cls(seed=seed, events=tuple(events))

    def apply(self, ctrl) -> None:
        """Queue every scripted fault on the controller's event heap."""
        for ev in self.events:
            if isinstance(ev, HostCrash):
                ctrl.fail_host(ev.node, at=ev.at)
                if ev.recover_at is not None:
                    ctrl.recover_host(ev.node, at=ev.recover_at)
            elif isinstance(ev, StragglerOnset):
                ctrl.straggle(ev.node, ev.factor, at=ev.at)
            elif isinstance(ev, LinkFlap):
                ctrl.fail_link(ev.link, at=ev.at)
                ctrl.recover_link(ev.link, at=ev.up_at)
            elif isinstance(ev, ControllerCrash):
                ctrl.fail_controller(at=ev.at)
                if ev.recover_at is not None:
                    ctrl.recover_controller(at=ev.recover_at)
            else:
                raise TypeError(f"not a fault event: {ev!r}")

    def __str__(self) -> str:
        lines = [f"FaultPlan(seed={self.seed}, {len(self.events)} events)"]
        for ev in self.events:
            lines.append(f"  [t={ev.at:8.2f}] {ev}")
        return "\n".join(lines)


def _key(ev) -> str:
    return getattr(ev, "node", None) or getattr(ev, "link", "")
