"""OpenFlow QoS queues — Discussion 3 / Example 3.

The paper's scheme: an egress port with a maximum rate (150 Mbps in Example
3) is split into rate-limited queues — Q1 = 100 Mbps for shuffle traffic,
Q2 = 40 Mbps for other Hadoop traffic, Q3 = 10 Mbps for background — and
flow entries steer traffic classes into queues.  The claim: shuffle
completion beats the default single shared-rate queue whenever background
traffic competes.

We model HTB-style queues with a *fluid* simulator: each queue's active
flows share the queue's guaranteed rate equally; unused guaranteed rate is
lent to other queues proportionally to their demand (work-conserving, like
OVS/HTB borrowing).  The same model prioritizes gradient-sync vs data-input
vs checkpoint traffic on the TPU DCN (see ``checkpoint`` and ``data``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

_EPS = 1e-9


@dataclass
class Flow:
    name: str
    size: float          # capacity-units·sec (Mbit at Mbps)
    queue: str           # traffic class
    arrival: float = 0.0
    finish: Optional[float] = None
    _left: float = field(default=0.0, repr=False)


@dataclass(frozen=True)
class QueueSpec:
    name: str
    rate: float          # guaranteed rate
    priority: int = 0    # lower = more important (borrowing order)


class QosPort:
    """One egress port with HTB-like queues (work-conserving borrowing)."""

    def __init__(self, max_rate: float, queues: Sequence[QueueSpec]):
        total = sum(q.rate for q in queues)
        if total > max_rate + _EPS:
            raise ValueError(f"queue rates {total} exceed port max_rate {max_rate}")
        self.max_rate = max_rate
        self.queues = {q.name: q for q in queues}

    def rates(self, demand: Dict[str, int]) -> Dict[str, float]:
        """Instantaneous per-queue service rate given active-flow counts."""
        active = {q: n for q, n in demand.items() if n > 0}
        if not active:
            return {q: 0.0 for q in self.queues}
        rates = {q: (self.queues[q].rate if q in active else 0.0) for q in self.queues}
        spare = self.max_rate - sum(rates.values())
        # Lend spare capacity by priority order (OVS max-rate borrowing).
        for q in sorted(active, key=lambda q: (self.queues[q].priority, q)):
            if spare <= _EPS:
                break
            rates[q] += spare
            spare = 0.0
        return rates

    def simulate(self, flows: Sequence[Flow]) -> Dict[str, float]:
        """Fluid simulation → finish time per flow name."""
        flows = [Flow(f.name, f.size, f.queue, f.arrival) for f in flows]
        for f in flows:
            f._left = f.size
        t = 0.0
        pending = sorted(flows, key=lambda f: f.arrival)
        done: Dict[str, float] = {}
        guard = 0
        while len(done) < len(flows):
            guard += 1
            if guard > 100000:
                raise RuntimeError("qos fluid sim did not converge")
            active = [f for f in pending if f.arrival <= t + _EPS and f._left > _EPS]
            next_arrival = min(
                (f.arrival for f in pending if f.arrival > t + _EPS), default=None
            )
            if not active:
                if next_arrival is None:
                    break
                t = next_arrival
                continue
            demand = {}
            for f in active:
                demand[f.queue] = demand.get(f.queue, 0) + 1
            qrates = self.rates(demand)
            per_flow = {
                q: (qrates[q] / n if n else 0.0) for q, n in demand.items()
            }
            # Advance until first completion or next arrival.
            dt_complete = min(
                f._left / per_flow[f.queue] if per_flow[f.queue] > _EPS else float("inf")
                for f in active
            )
            dt = dt_complete
            if next_arrival is not None:
                dt = min(dt, next_arrival - t)
            for f in active:
                f._left -= per_flow[f.queue] * dt
                if f._left <= _EPS:
                    f._left = 0.0
                    done[f.name] = t + dt
            t += dt
        return done


def example3_port() -> QosPort:
    """Example 3: max 150 Mbps, Q1=100 (shuffle), Q2=40 (hadoop), Q3=10 (bg)."""
    return QosPort(
        150.0,
        [
            QueueSpec("Q1", 100.0, priority=0),
            QueueSpec("Q2", 40.0, priority=1),
            QueueSpec("Q3", 10.0, priority=2),
        ],
    )


def single_queue_port(max_rate: float = 150.0) -> QosPort:
    """The paper's default scheme: all traffic in one shared queue."""
    return QosPort(max_rate, [QueueSpec("Q", max_rate, priority=0)])


def shuffle_vs_default(
    shuffle_mbit: float, background_mbit: float, n_background: int = 1
) -> Tuple[float, float]:
    """Example-3 comparison: (queued finish, single-queue finish) of shuffle."""
    qport = example3_port()
    flows_q = [Flow("shuffle", shuffle_mbit, "Q1")] + [
        Flow(f"bg{i}", background_mbit, "Q3") for i in range(n_background)
    ]
    queued = qport.simulate(flows_q)["shuffle"]

    dport = single_queue_port()
    flows_d = [Flow("shuffle", shuffle_mbit, "Q")] + [
        Flow(f"bg{i}", background_mbit, "Q") for i in range(n_background)
    ]
    default = dport.simulate(flows_d)["shuffle"]
    return queued, default
