"""OpenFlow QoS queues — Discussion 3 / Example 3.

The paper's scheme: an egress port with a maximum rate (150 Mbps in Example
3) is split into rate-limited queues — Q1 = 100 Mbps for shuffle traffic,
Q2 = 40 Mbps for other Hadoop traffic, Q3 = 10 Mbps for background — and
flow entries steer traffic classes into queues.  The claim: shuffle
completion beats the default single shared-rate queue whenever background
traffic competes.

We model HTB-style queues with a *fluid* simulator: each queue's active
flows share the queue's guaranteed rate equally, and unused guaranteed
rate is lent to other active queues (work-conserving).  How it is lent is
the port's ``borrowing`` mode: ``"priority"`` (default) hands all spare to
the single most important active class — OVS max-rate borrowing, and the
behavior every Example-3 number in this repo was produced with — while
``"proportional"`` splits spare across active classes proportionally to
their active-flow demand, classic HTB.  The same model prioritizes
gradient-sync vs data-input vs checkpoint traffic on the TPU DCN (see
``checkpoint`` and ``data``).

:class:`TenantSpec`/:class:`TenantBook` extend the class-level queues to
*per-tenant* QoS: token-bucket admission control plus WFQ-style weighted
fairness accounting, consumed by ``serving.router`` (DESIGN.md §12).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

_EPS = 1e-9


@dataclass
class Flow:
    name: str
    size: float          # capacity-units·sec (Mbit at Mbps)
    queue: str           # traffic class
    arrival: float = 0.0
    finish: Optional[float] = None
    _left: float = field(default=0.0, repr=False)


@dataclass(frozen=True)
class QueueSpec:
    name: str
    rate: float          # guaranteed rate
    priority: int = 0    # lower = more important (borrowing order)


class QosPort:
    """One egress port with HTB-like queues (work-conserving borrowing).

    ``borrowing`` selects how spare guaranteed rate is lent:

    * ``"priority"`` (default) — all spare goes to the single
      highest-priority active queue (lowest ``QueueSpec.priority``, name
      tie-break), like OVS max-rate borrowing.  This is the historical
      behavior of this class.
    * ``"proportional"`` — spare is split across the active queues
      proportionally to their active-flow counts, classic HTB sharing.
    """

    BORROWING = ("priority", "proportional")

    def __init__(self, max_rate: float, queues: Sequence[QueueSpec],
                 borrowing: str = "priority"):
        total = sum(q.rate for q in queues)
        if total > max_rate + _EPS:
            raise ValueError(f"queue rates {total} exceed port max_rate {max_rate}")
        if borrowing not in self.BORROWING:
            raise ValueError(
                f"borrowing must be one of {self.BORROWING}, got {borrowing!r}"
            )
        self.max_rate = max_rate
        self.queues = {q.name: q for q in queues}
        self.borrowing = borrowing

    def rates(self, demand: Dict[str, int]) -> Dict[str, float]:
        """Instantaneous per-queue service rate given active-flow counts.

        Every active queue gets its guaranteed rate; spare capacity (the
        port max minus active guarantees) is lent per the port's
        ``borrowing`` mode — entirely to the most important active class
        (``"priority"``), or split proportionally to each active class's
        flow count (``"proportional"``)."""
        active = {q: n for q, n in demand.items() if n > 0}
        if not active:
            return {q: 0.0 for q in self.queues}
        rates = {q: (self.queues[q].rate if q in active else 0.0) for q in self.queues}
        spare = self.max_rate - sum(rates.values())
        if spare <= _EPS:
            return rates
        if self.borrowing == "priority":
            # All spare to the most important active class.
            q = min(active, key=lambda q: (self.queues[q].priority, q))
            rates[q] += spare
        else:
            total_n = sum(active.values())
            for q, n in active.items():
                rates[q] += spare * (n / total_n)
        return rates

    def simulate(self, flows: Sequence[Flow]) -> Dict[str, float]:
        """Fluid simulation → finish time per flow name."""
        flows = [Flow(f.name, f.size, f.queue, f.arrival) for f in flows]
        for f in flows:
            f._left = f.size
        t = 0.0
        pending = sorted(flows, key=lambda f: f.arrival)
        done: Dict[str, float] = {}
        guard = 0
        while len(done) < len(flows):
            guard += 1
            if guard > 100000:
                raise RuntimeError("qos fluid sim did not converge")
            active = [f for f in pending if f.arrival <= t + _EPS and f._left > _EPS]
            next_arrival = min(
                (f.arrival for f in pending if f.arrival > t + _EPS), default=None
            )
            if not active:
                if next_arrival is None:
                    break
                t = next_arrival
                continue
            demand = {}
            for f in active:
                demand[f.queue] = demand.get(f.queue, 0) + 1
            qrates = self.rates(demand)
            per_flow = {
                q: (qrates[q] / n if n else 0.0) for q, n in demand.items()
            }
            # Advance until first completion or next arrival.
            dt_complete = min(
                f._left / per_flow[f.queue] if per_flow[f.queue] > _EPS else float("inf")
                for f in active
            )
            dt = dt_complete
            if next_arrival is not None:
                dt = min(dt, next_arrival - t)
            for f in active:
                f._left -= per_flow[f.queue] * dt
                if f._left <= _EPS:
                    f._left = 0.0
                    done[f.name] = t + dt
            t += dt
        return done


def example3_port(borrowing: str = "priority") -> QosPort:
    """Example 3: max 150 Mbps, Q1=100 (shuffle), Q2=40 (hadoop), Q3=10 (bg)."""
    return QosPort(
        150.0,
        [
            QueueSpec("Q1", 100.0, priority=0),
            QueueSpec("Q2", 40.0, priority=1),
            QueueSpec("Q3", 10.0, priority=2),
        ],
        borrowing=borrowing,
    )


def single_queue_port(max_rate: float = 150.0) -> QosPort:
    """The paper's default scheme: all traffic in one shared queue."""
    return QosPort(max_rate, [QueueSpec("Q", max_rate, priority=0)])


def shuffle_vs_default(
    shuffle_mbit: float, background_mbit: float, n_background: int = 1
) -> Tuple[float, float]:
    """Example-3 comparison: (queued finish, single-queue finish) of shuffle."""
    qport = example3_port()
    flows_q = [Flow("shuffle", shuffle_mbit, "Q1")] + [
        Flow(f"bg{i}", background_mbit, "Q3") for i in range(n_background)
    ]
    queued = qport.simulate(flows_q)["shuffle"]

    dport = single_queue_port()
    flows_d = [Flow("shuffle", shuffle_mbit, "Q")] + [
        Flow(f"bg{i}", background_mbit, "Q") for i in range(n_background)
    ]
    default = dport.simulate(flows_d)["shuffle"]
    return queued, default


# ---------------------------------------------------------------------------
# Per-tenant QoS: token-bucket admission + weighted fairness (DESIGN.md §12)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's QoS class.

    ``weight`` is the WFQ fair-share weight (2.0 earns twice the service
    of 1.0 before counting as over-share); ``rate``/``burst`` parameterize
    the admission token bucket — ``rate`` admissions per second sustained,
    ``burst`` admissions of depth.  The default spec admits everything and
    shares equally."""

    name: str
    weight: float = 1.0
    rate: float = float("inf")
    burst: float = 1.0

    def __post_init__(self):
        if self.weight <= 0.0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")
        if self.rate <= 0.0 or self.burst <= 0.0:
            raise ValueError(
                f"tenant rate/burst must be > 0, got {self.rate}/{self.burst}"
            )


class TenantBook:
    """Admission control + weighted-fairness accounting over tenants.

    * :meth:`admit` is a per-tenant token bucket: a request costs one
      token, tokens refill at ``spec.rate`` per second up to
      ``spec.burst`` — a tenant over its configured rate is *rejected*
      (hard admission control, before any scheduling work happens).
    * :meth:`charge` is WFQ-style virtual time: serving ``service_s``
      seconds of work advances the tenant's virtual clock by
      ``service_s / weight``, floored at the book-wide minimum so an idle
      tenant re-enters at the current fairness frontier instead of
      claiming its whole idle period as credit.
    * :meth:`lag` is how far a tenant's virtual clock runs ahead of the
      frontier — the router treats tenants beyond a slack as over their
      fair share and denies them the migration fast path (they still run,
      data-local, without new boundary reservations).
    """

    def __init__(self, specs: Sequence[TenantSpec]):
        if not specs:
            raise ValueError("TenantBook needs at least one TenantSpec")
        self.specs: Dict[str, TenantSpec] = {}
        for s in specs:
            if s.name in self.specs:
                raise ValueError(f"duplicate tenant {s.name!r}")
            self.specs[s.name] = s
        self._tokens = {s.name: float(s.burst) for s in specs}
        self._stamp = {s.name: 0.0 for s in specs}
        self._vt = {s.name: 0.0 for s in specs}

    def spec(self, name: str) -> TenantSpec:
        """The tenant's spec; KeyError for unknown tenants (a config
        error, not a policy decision)."""
        return self.specs[name]

    def admit(self, name: str, now: float, cost: float = 1.0) -> bool:
        spec = self.specs[name]
        tok = self._tokens[name]
        if spec.rate != float("inf"):
            dt = now - self._stamp[name]
            if dt > 0.0:
                tok = min(spec.burst, tok + dt * spec.rate)
        else:
            tok = spec.burst
        self._stamp[name] = max(self._stamp[name], now)
        if tok + _EPS < cost:
            self._tokens[name] = tok
            return False
        self._tokens[name] = tok - cost
        return True

    def charge(self, name: str, service_s: float) -> None:
        base = max(self._vt[name], self.floor())
        self._vt[name] = base + service_s / self.specs[name].weight

    def floor(self) -> float:
        """The fairness frontier: the minimum tenant virtual time."""
        return min(self._vt.values())

    def lag(self, name: str) -> float:
        """Weighted service the tenant has received beyond the frontier."""
        return self._vt[name] - self.floor()
