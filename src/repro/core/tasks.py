"""Problem formalization — paper §III, Eq. (1)–(5).

``TM_ij = SZ_i / BW(dataSrc, j)``       (1)  data-movement time
``TE_ij = TP_ij + TM_ij``               (2)  execution time
``ΥC_ij = TE_ij + ΥI_j``                (3)  completion time
``ND_j  = argmin_j ΥC_ij``              (4)  per-task objective
``min max_i ΥC_ij``                     (5)  job-level makespan objective
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from .timeslot import TimeSlotLedger, TransferPlan
from .topology import Fabric


@dataclass(frozen=True)
class Task:
    """A map/reduce task ``TK_i`` with a replicated input split."""

    tid: int
    size: float                    # SZ_i in capacity-units·sec (Mbit @ Mbps)
    compute: float                 # TP_ij (homogeneous cluster → scalar)
    replicas: Tuple[str, ...]      # nodes storing the input split
    kind: str = "map"              # map | reduce (for two-phase workloads)


@dataclass
class Assignment:
    """Scheduler output for one task."""

    tid: int
    node: str
    source: Optional[str]              # replica the data moved from, None if local
    transfer: Optional[TransferPlan]   # committed TS reservation, None if local
    start: float                       # compute start time
    finish: float                      # ΥC_ij
    bw_needed: Optional[float] = None  # BW_{i,minnow} from Algorithm 1 line 8

    @property
    def local(self) -> bool:
        return self.source is None


@dataclass(frozen=True)
class BackgroundFlow:
    """Ongoing cross-traffic (the paper's repetitively-executed background
    job): occupies ``fraction`` of every link on src→dst during [start, end).
    The SDN controller sees it in the ledger; bandwidth-oblivious schedulers
    do not account for it when deciding — but their transfers still pay."""

    src: str
    dst: str
    fraction: float
    start: float
    end: float


@dataclass
class Instance:
    """A scheduling problem: cluster + initial load + task list.

    ``workers`` are the *available* nodes (may be a subset of the fabric's
    hosts when the cluster is shared — the paper's locality-starvation case);
    ``idle`` is the initial ``ΥI_j`` per worker (estimated in practice via the
    ProgressRate scheme, §V.A — see ``runtime.progress``).
    """

    fabric: Fabric
    workers: List[str]
    idle: Dict[str, float]
    tasks: List[Task]
    slot_duration: float = 1.0
    background: List[BackgroundFlow] = field(default_factory=list)

    def fresh_ledger(self, horizon_slots: int = 256) -> TimeSlotLedger:
        ledger = TimeSlotLedger(self.fabric, self.slot_duration, horizon_slots)
        for bg in self.background:
            rows = ledger.rows(self.fabric.path(bg.src, bg.dst))
            ledger.occupy(rows, bg.start, bg.end, bg.fraction)
        return ledger


@dataclass
class Schedule:
    """A complete job schedule + derived paper metrics."""

    assignments: List[Assignment]
    ledger: TimeSlotLedger
    kinds: Dict[int, str] = field(default_factory=dict)  # tid -> map|reduce

    @property
    def makespan(self) -> float:
        """Job completion time JT — Eq. (5) objective value."""
        return max((a.finish for a in self.assignments), default=0.0)

    @property
    def locality_ratio(self) -> float:
        """LR = data-local tasks / total tasks (Table I)."""
        if not self.assignments:
            return 0.0
        return sum(1 for a in self.assignments if a.local) / len(self.assignments)

    def by_node(self) -> Dict[str, List[Assignment]]:
        out: Dict[str, List[Assignment]] = {}
        for a in sorted(self.assignments, key=lambda a: (a.start, a.tid)):
            out.setdefault(a.node, []).append(a)
        return out

    def phase_completion(self, kind: str) -> float:
        """MT / RT columns of Table I (latest finish among tasks of ``kind``)."""
        vals = [
            a.finish for a in self.assignments if self.kinds.get(a.tid, "map") == kind
        ]
        return max(vals) if vals else 0.0

    def latest(self) -> Assignment:
        return max(self.assignments, key=lambda a: (a.finish, a.tid))


def movement_time(size: float, bandwidth: float) -> float:
    """Eq. (1): ``TM = SZ / BW`` (0 for a data-local run)."""
    if size <= 0:
        return 0.0
    if bandwidth <= 0:
        return float("inf")
    return size / bandwidth

def execution_time(compute: float, tm: float) -> float:
    """Eq. (2): ``TE = TP + TM``."""
    return compute + tm

def completion_time(compute: float, tm: float, idle: float) -> float:
    """Eq. (3): ``ΥC = TE + ΥI``."""
    return execution_time(compute, tm) + idle


def argmin_completion(
    task: Task,
    nodes: Sequence[str],
    idle: Dict[str, float],
    tm_of: Dict[str, float],
) -> str:
    """Eq. (4): node with the earliest completion time (deterministic ties)."""
    best = min(nodes, key=lambda n: (completion_time(task.compute, tm_of[n], idle[n]), n))
    return best


def makespan_objective(finishes: Sequence[float]) -> float:
    """Eq. (5) evaluated for a fixed assignment."""
    return max(finishes) if len(finishes) else 0.0
