"""Paper baselines: HDS (Hadoop Default Scheduler) and BAR (BAlance-Reduce).

HDS (Discussion 1): node-driven greedy.  Whenever a node becomes idle it
takes an unstarted *data-local* task (lowest task id for determinism — the
paper says "randomly" for the non-local fallback); if no local task remains
it takes the lowest-id remaining task and pays the movement time.  HDS is
bandwidth-*oblivious* in its decisions, but its transfers still traverse the
shared network: movement time is evaluated against the same ledger (without
advance reservation the residue it sees is whatever is left).

BAR (Jin et al., CCGrid'11, as summarized in Discussion 1): phase 1 produces
the data-local allocation (= HDS result); phase 2 repeatedly takes the task
with the *latest* completion time and moves it to a remote node iff that
yields an earlier completion, until no such move exists.  BAR reasons with
static link bandwidth (it "disregards available bandwidth" — no TS ledger).

Both algorithms live in :mod:`repro.core.controller` as policies
(:class:`~repro.core.controller.HdsPolicy`,
:class:`~repro.core.controller.BarPolicy`); these wrappers are the
historical offline entry points, byte-identical to the pre-refactor batch
schedulers (DESIGN.md §1).
"""
from __future__ import annotations

from typing import Optional

from .controller import (  # noqa: F401  (re-exported legacy surface)
    BarPolicy,
    HdsPolicy,
    nearest_source as _nearest_source,
    run_policy,
)
from .tasks import Instance, Schedule
from .timeslot import TimeSlotLedger


def schedule_hds(
    instance: Instance, ledger: Optional[TimeSlotLedger] = None
) -> Schedule:
    return run_policy(HdsPolicy(), instance, ledger)


def schedule_bar(
    instance: Instance, ledger: Optional[TimeSlotLedger] = None
) -> Schedule:
    """BAR: HDS phase-1 allocation, then latest-task remote adjustment."""
    return run_policy(BarPolicy(), instance, ledger)
