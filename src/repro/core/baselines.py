"""Paper baselines: HDS (Hadoop Default Scheduler) and BAR (BAlance-Reduce).

HDS (Discussion 1): node-driven greedy.  Whenever a node becomes idle it
takes an unstarted *data-local* task (lowest task id for determinism — the
paper says "randomly" for the non-local fallback); if no local task remains
it takes the lowest-id remaining task and pays the movement time.  HDS is
bandwidth-*oblivious* in its decisions, but its transfers still traverse the
shared network: movement time is evaluated against the same ledger (without
advance reservation the residue it sees is whatever is left).

BAR (Jin et al., CCGrid'11, as summarized in Discussion 1): phase 1 produces
the data-local allocation (= HDS result); phase 2 repeatedly takes the task
with the *latest* completion time and moves it to a remote node iff that
yields an earlier completion, until no such move exists.  BAR reasons with
static link bandwidth (it "disregards available bandwidth" — no TS ledger).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from .tasks import Assignment, Instance, Schedule, Task
from .timeslot import TimeSlotLedger

_EPS = 1e-9


def schedule_hds(
    instance: Instance, ledger: Optional[TimeSlotLedger] = None
) -> Schedule:
    idle = dict(instance.idle)
    ledger = ledger if ledger is not None else instance.fresh_ledger()
    unstarted = {t.tid: t for t in instance.tasks}
    out: List[Assignment] = []
    # Event heap of (idle_time, node); deterministic tie-break on name.
    heap: List[Tuple[float, str]] = sorted((idle[n], n) for n in instance.workers)
    heapq.heapify(heap)

    while unstarted and heap:
        t_idle, node = heapq.heappop(heap)
        if abs(idle[node] - t_idle) > _EPS:
            continue  # stale entry
        local = [tid for tid, t in unstarted.items() if node in t.replicas]
        if local:
            tid = min(local)
            task = unstarted.pop(tid)
            start = t_idle
            finish = start + task.compute
            out.append(Assignment(tid, node, None, None, start, finish))
        else:
            tid = min(unstarted)
            task = unstarted.pop(tid)
            src, rows = _nearest_source(task, node, ledger)
            plan = ledger.plan_transfer(task.size, rows, not_before=t_idle)
            ledger.commit(plan)
            start = plan.end if plan.slot_fracs else t_idle
            finish = start + task.compute
            out.append(Assignment(tid, node, src, plan, start, finish))
        idle[node] = finish
        heapq.heappush(heap, (finish, node))

    out.sort(key=lambda a: a.tid)
    return Schedule(out, ledger, kinds={t.tid: t.kind for t in instance.tasks})


def _nearest_source(
    task: Task, dst: str, ledger: TimeSlotLedger
) -> Tuple[str, Tuple[int, ...]]:
    """Fewest-hop replica (bandwidth-oblivious choice)."""
    best = None
    for rep in task.replicas:
        if rep == dst:
            continue
        rows = ledger.rows(ledger.fabric.path(rep, dst))
        key = (len(rows), rep)
        if best is None or key < best[0]:
            best = (key, rep, rows)
    assert best is not None
    return best[1], best[2]


def schedule_bar(
    instance: Instance, ledger: Optional[TimeSlotLedger] = None
) -> Schedule:
    """BAR: HDS phase-1 allocation, then latest-task remote adjustment."""
    # Phase 1 + move decisions run on a scratch ledger (BAR's own beliefs);
    # the caller-visible ledger only receives the realized transfers below.
    phase1 = schedule_hds(instance, instance.fresh_ledger())
    # Node queues in start order; we re-derive per-node task sequences.
    queues: Dict[str, List[Assignment]] = phase1.by_node()
    tasks = {t.tid: t for t in instance.tasks}
    base_idle = dict(instance.idle)
    fabric = instance.fabric

    def static_tm(task: Task, node: str) -> Tuple[float, Optional[str]]:
        if node in task.replicas:
            return 0.0, None
        best = None
        for rep in task.replicas:
            bw = fabric.path_capacity(rep, node)
            tm = task.size / bw if bw > 0 else float("inf")
            if best is None or tm < best[0]:
                best = (tm, rep)
        assert best is not None
        return best

    def recompute(queues: Dict[str, List[Assignment]]) -> None:
        for node, q in queues.items():
            t = base_idle.get(node, 0.0)
            for a in q:
                tm, src = static_tm(tasks[a.tid], node)
                a.node, a.source, a.transfer = node, src, None
                a.start = t + tm
                a.finish = a.start + tasks[a.tid].compute
                t = a.finish

    recompute(queues)

    while True:
        all_assign = [a for q in queues.values() for a in q]
        latest = max(all_assign, key=lambda a: (a.finish, a.tid))
        task = tasks[latest.tid]
        # Candidate: append to another node's queue end.
        best: Optional[Tuple[float, str]] = None
        for node in instance.workers:
            if node == latest.node:
                continue
            q = queues.setdefault(node, [])
            t_avail = q[-1].finish if q else base_idle.get(node, 0.0)
            tm, _src = static_tm(task, node)
            yc = t_avail + tm + task.compute
            if yc < latest.finish - _EPS and (best is None or (yc, node) < best):
                best = (yc, node)
        if best is None:
            break
        _yc, node = best
        queues[latest.node].remove(latest)
        queues[node].append(latest)
        recompute(queues)

    # --- Realization: BAR's *decisions* used static bandwidth beliefs; the
    # resulting transfers still traverse the shared network.  Replay the
    # chosen per-node queues against a fresh TS ledger (event-driven, no
    # advance reservation) so contended moves pay their true movement time —
    # the paper's §I critique ("disregard available bandwidth") made honest.
    realized_ledger = ledger if ledger is not None else instance.fresh_ledger()
    avail: Dict[str, float] = {
        n: instance.idle.get(n, 0.0) for n in instance.workers
    }
    heads: Dict[str, int] = {n: 0 for n in queues}
    out: List[Assignment] = []
    while True:
        ready = [n for n, q in queues.items() if heads[n] < len(q)]
        if not ready:
            break
        node = min(ready, key=lambda n: (avail[n], n))
        a = queues[node][heads[node]]
        heads[node] += 1
        task = tasks[a.tid]
        if node in task.replicas:
            a.source, a.transfer = None, None
            a.start = avail[node]
        else:
            src, rows = _nearest_source(task, node, realized_ledger)
            plan = realized_ledger.plan_transfer(
                task.size, rows, not_before=avail[node]
            )
            realized_ledger.commit(plan)
            a.source, a.transfer = src, plan
            a.start = plan.end if plan.slot_fracs else avail[node]
        a.node = node
        a.finish = a.start + task.compute
        avail[node] = a.finish
        out.append(a)

    out.sort(key=lambda a: a.tid)
    return Schedule(
        out,
        realized_ledger,
        kinds={t.tid: t.kind for t in instance.tasks},
    )
