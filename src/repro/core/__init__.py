"""The paper's contribution: BASS bandwidth-aware scheduling with an SDN-style
global fabric view, Time-Slot bandwidth allocation, the HDS/BAR baselines,
Pre-BASS prefetching, QoS queueing, and the evaluation simulator.

Public API:

``Fabric``/``TimeSlotLedger``   — the controller's network view + TS ledger
``ClusterController``           — the online event loop (multi-job streams)
``ClusterState``/``POLICIES``   — shared world + pluggable per-event policies
``schedule_bass``               — Algorithm 1 (offline wrapper)
``schedule_hds``/``schedule_bar`` — paper baselines (offline wrappers)
``schedule_prebass``            — Discussion-2 prefetching variant
``QosPort``                     — Discussion-3 OpenFlow queue model
``replay``/``replay_online``/``evaluate_mapreduce`` — verification + metrics
"""
from .topology import (
    Fabric,
    UnroutableError,
    paper_fig2_fabric,
    storage_hosts,
    tpu_dcn_fabric,
    two_tier_fabric,
)
from .timeslot import TimeSlotLedger, TransferPlan
from .tasks import (
    Assignment,
    BackgroundFlow,
    Instance,
    Schedule,
    Task,
    completion_time,
    execution_time,
    movement_time,
)
from .controller import (
    POLICIES,
    BarPolicy,
    BassPolicy,
    ClusterController,
    ClusterState,
    HdsPolicy,
    PreBassPolicy,
    RetryPolicy,
    SchedulingPolicy,
    run_policy,
)
from .faults import FaultPlan, HostCrash, LinkFlap, StragglerOnset
from .bass import schedule_bass
from .baselines import schedule_bar, schedule_hds
from .prebass import schedule_prebass
from .qos import Flow, QosPort, QueueSpec, example3_port, shuffle_vs_default, single_queue_port
from .simulator import JobMetrics, ReplayReport, evaluate_mapreduce, replay, replay_online

SCHEDULERS = {
    "bass": schedule_bass,
    "hds": schedule_hds,
    "bar": schedule_bar,
    "prebass": schedule_prebass,
}

__all__ = [
    "Assignment",
    "BackgroundFlow",
    "BarPolicy",
    "BassPolicy",
    "ClusterController",
    "ClusterState",
    "Fabric",
    "FaultPlan",
    "Flow",
    "HostCrash",
    "LinkFlap",
    "StragglerOnset",
    "HdsPolicy",
    "Instance",
    "JobMetrics",
    "POLICIES",
    "PreBassPolicy",
    "QosPort",
    "QueueSpec",
    "ReplayReport",
    "RetryPolicy",
    "SCHEDULERS",
    "Schedule",
    "SchedulingPolicy",
    "Task",
    "TimeSlotLedger",
    "TransferPlan",
    "UnroutableError",
    "completion_time",
    "evaluate_mapreduce",
    "example3_port",
    "execution_time",
    "movement_time",
    "paper_fig2_fabric",
    "replay",
    "replay_online",
    "run_policy",
    "schedule_bar",
    "schedule_bass",
    "schedule_hds",
    "schedule_prebass",
    "shuffle_vs_default",
    "single_queue_port",
    "storage_hosts",
    "tpu_dcn_fabric",
    "two_tier_fabric",
]
