"""The paper's contribution: BASS bandwidth-aware scheduling with an SDN-style
global fabric view, Time-Slot bandwidth allocation, the HDS/BAR baselines,
Pre-BASS prefetching, QoS queueing, and the evaluation simulator.

Public API:

``Fabric``/``TimeSlotLedger``   — the controller's network view + TS ledger
``schedule_bass``               — Algorithm 1
``schedule_hds``/``schedule_bar`` — paper baselines
``schedule_prebass``            — Discussion-2 prefetching variant
``QosPort``                     — Discussion-3 OpenFlow queue model
``replay``/``evaluate_mapreduce`` — independent verification + Table-I metrics
"""
from .topology import Fabric, paper_fig2_fabric, two_tier_fabric, tpu_dcn_fabric
from .timeslot import TimeSlotLedger, TransferPlan
from .tasks import (
    Assignment,
    Instance,
    Schedule,
    Task,
    completion_time,
    execution_time,
    movement_time,
)
from .bass import schedule_bass
from .baselines import schedule_bar, schedule_hds
from .prebass import schedule_prebass
from .qos import Flow, QosPort, QueueSpec, example3_port, shuffle_vs_default, single_queue_port
from .simulator import JobMetrics, ReplayReport, evaluate_mapreduce, replay

SCHEDULERS = {
    "bass": schedule_bass,
    "hds": schedule_hds,
    "bar": schedule_bar,
    "prebass": schedule_prebass,
}

__all__ = [
    "Assignment",
    "Fabric",
    "Flow",
    "Instance",
    "JobMetrics",
    "QosPort",
    "QueueSpec",
    "ReplayReport",
    "SCHEDULERS",
    "Schedule",
    "Task",
    "TimeSlotLedger",
    "TransferPlan",
    "completion_time",
    "evaluate_mapreduce",
    "example3_port",
    "execution_time",
    "movement_time",
    "paper_fig2_fabric",
    "replay",
    "schedule_bar",
    "schedule_bass",
    "schedule_hds",
    "schedule_prebass",
    "shuffle_vs_default",
    "single_queue_port",
    "tpu_dcn_fabric",
    "two_tier_fabric",
]
