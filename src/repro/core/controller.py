"""Online event-driven scheduling core (see DESIGN.md §1).

The paper's BASS controller is inherently *online*: tasks and background
flows arrive while the SDN controller holds a live global view.  This module
is that controller, split into three layers:

* :class:`ClusterState` — the shared mutable world: idle map ``ΥI_j``, the
  lazy :class:`MinnowHeap`, the :class:`~repro.core.timeslot.TimeSlotLedger`
  and the fabric.  ``commit_local`` / ``commit_remote`` are the *single*
  source of truth for Assignment emission — every policy books work through
  them, so idle times, the minnow heap and the ledger can never drift apart.
* :class:`SchedulingPolicy` — the per-event decision protocol.  ``place``
  handles one arriving task, ``place_batch`` a job's task list.  BASS, HDS,
  BAR and Pre-BASS are policies (:data:`POLICIES`); the historical
  ``schedule_*(instance, ledger)`` entry points in ``bass``/``baselines``/
  ``prebass`` are thin offline wrappers that build a state, run the policy
  once, and wrap the result in a :class:`~repro.core.tasks.Schedule` —
  byte-identical to the pre-refactor batch schedulers.
* :class:`ClusterController` — the event loop: ``submit(tasks, at=...)``
  queues a job arrival, ``inject_flow`` queues dynamic background
  cross-traffic, ``reserve_transfer_at`` queues a raw flow reservation
  (training-side gradient sync), and ``run_until(t)`` / ``run()`` drain the
  event queue in time order, producing per-job assignments and
  :class:`~repro.core.simulator.JobMetrics`.
"""
from __future__ import annotations

import copy
import heapq
from dataclasses import dataclass, field
from typing import (
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
)

from ..obs import Registry
from .tasks import (
    Assignment,
    BackgroundFlow,
    Instance,
    Schedule,
    Task,
    completion_time,
)
from .timeslot import TimeSlotLedger, TransferPlan
from .topology import Fabric, UnroutableError

_EPS = 1e-9


def _believed_tm(belief, rows, size: float, at: float) -> float:
    """Estimated transfer time from a flat belief: size / believed BW_rl."""
    if size <= 0.0:
        return 0.0
    bw = belief.path_bandwidth(rows, at)
    return size / bw if bw > _EPS else float("inf")


class MinnowHeap:
    """Position-indexed min-heap over worker idle times (deterministic
    name tie-break).

    One entry per worker, kept exact by :meth:`update` (an
    increase/decrease-key sift), so ``ND_minnow`` is an O(1) peek and a
    placement costs one O(log n) sift — no stale-entry repair loops, and
    the heap never grows past n.  The selected minimum is the same
    ``(idle, name)`` tuple order the historical lazy heap resolved, so
    every policy decision is unchanged.
    """

    def __init__(self, idle: Dict[str, float], workers: Sequence[str]):
        self._heap = [(idle[n], n) for n in workers]
        heapq.heapify(self._heap)
        self._pos = {e[1]: i for i, e in enumerate(self._heap)}

    def minnow(self, idle: Optional[Dict[str, float]] = None) -> str:
        """The worker with minimal (idle, name); ``idle`` is accepted for
        backwards compatibility and ignored — entries are kept exact."""
        return self._heap[0][1]

    def update(self, node: str, new_idle: float) -> None:
        h, pos = self._heap, self._pos
        i = pos[node]
        item = (new_idle, node)
        while i > 0:  # sift up
            parent = (i - 1) >> 1
            if item < h[parent]:
                h[i] = h[parent]
                pos[h[i][1]] = i
                i = parent
            else:
                break
        n = len(h)
        while True:  # sift down
            c = 2 * i + 1
            if c >= n:
                break
            r = c + 1
            if r < n and h[r] < h[c]:
                c = r
            if h[c] < item:
                h[i] = h[c]
                pos[h[i][1]] = i
                i = c
            else:
                break
        h[i] = item
        pos[node] = i

    def insert(self, node: str, idle: float) -> None:
        """Admit a (re)joining worker: push + sift, one O(log n) pass."""
        h, pos = self._heap, self._pos
        if node in pos:
            raise ValueError(f"worker {node!r} already in heap")
        h.append((float("inf"), node))
        pos[node] = len(h) - 1
        self.update(node, idle)

    def remove(self, node: str) -> None:
        """Evict a crashed worker: swap-with-last + sift, O(log n)."""
        h, pos = self._heap, self._pos
        i = pos.pop(node)
        last = h.pop()
        if i < len(h):
            h[i] = last
            pos[last[1]] = i
            # Re-sift the moved entry to restore the invariant either way.
            self.update(last[1], last[0])


def pick_minnow(idle: Dict[str, float], workers: Sequence[str]) -> str:
    """``ND_minnow``: the worker whose available idle time is minimum."""
    return min(workers, key=lambda n: (idle[n], n))


def pick_local(
    task: Task, idle: Dict[str, float], workers: Sequence[str]
) -> Optional[str]:
    """``ND_loc``: least-loaded *available* replica holder, or None (Case 2).

    ``workers`` is any membership container; pass a set at fleet scale —
    a list turns every placement into an O(n_workers · R) string scan
    (``ClusterState.workers_set`` exists for exactly this)."""
    holders = [n for n in task.replicas if n in workers]
    if not holders:
        return None
    return min(holders, key=lambda n: (idle[n], n))


def choose_source(
    task: Task,
    dst: str,
    ledger: TimeSlotLedger,
    at: float,
    load: Optional[Dict[str, float]] = None,
    belief=None,
) -> Tuple[str, Tuple[int, ...]]:
    """Choose the replica to move data *from* (``ND_dataSrc``).

    Base BASS picks the replica whose path to ``dst`` has the most residual
    bandwidth at transfer time (ties: fewer hops, then name); with ``load``
    given (Pre-BASS, Discussion 2) the least-loaded holder wins first.  All
    candidate (source, destination) pairs are scored in one numpy pass via
    :meth:`TimeSlotLedger.path_bandwidth_batch`.  With ``belief`` given
    (telemetry mode) candidates are ranked by the *estimated* residual
    bandwidth instead of oracle ledger state — same query surface, stale
    answers (DESIGN.md §9).
    """
    cands = [rep for rep in task.replicas if rep != dst]
    assert cands, f"task {task.tid} has no off-node replica"
    rows_list = [ledger.path_rows(rep, dst) for rep in cands]
    bws = (ledger if belief is None else belief).path_bandwidth_batch(rows_list, at)
    best = min(
        range(len(cands)),
        key=lambda i: (
            load.get(cands[i], 0.0) if load is not None else 0.0,
            -bws[i],
            len(rows_list[i]),
            cands[i],
        ),
    )
    return cands[best], rows_list[best]


def nearest_source(
    task: Task, dst: str, ledger: TimeSlotLedger
) -> Tuple[str, Tuple[int, ...]]:
    """Fewest-hop replica (HDS/BAR's bandwidth-oblivious choice)."""
    best = None
    for rep in task.replicas:
        if rep == dst:
            continue
        rows = ledger.path_rows(rep, dst)
        key = (len(rows), rep)
        if best is None or key < best[0]:
            best = (key, rep, rows)
    assert best is not None
    return best[1], best[2]


# ---------------------------------------------------------------------------
# EventQueue — the deterministic event heap, extracted for pod-scope reuse
# ---------------------------------------------------------------------------


class EventQueue:
    """Deterministic controller event heap: ``(at, seq, kind, payload)``.

    Extracted from :class:`ClusterController` so pod-scope controllers
    (``core.hierarchy``) reuse the exact ordering contract — time first,
    then a monotonically increasing sequence number (FIFO among same-time
    events); kind/payload are never compared.  ``items`` is a live
    ``heapq`` list and stays a plain attribute on purpose: controller
    snapshots store it verbatim, because heapq's internal layout is part
    of the deterministic tie-break story.

    ``n_real`` counts queued events that are *work* — everything except
    the telemetry poll / heartbeat chain ticks — so those self-re-arming
    chains can key off pending work without counting each other.
    """

    #: Event kinds that are chain ticks, not work: the telemetry poll and
    #: heartbeat sweeps here, plus the hierarchical controller's periodic
    #: rebalance tick (``core.hierarchy``) — all three re-arm themselves
    #: only while real work is queued, so none can keep ``run()`` alive.
    CHAIN_KINDS = ("poll", "hb", "rebalance")

    __slots__ = ("items", "seq", "n_real")

    def __init__(self) -> None:
        self.items: List[Tuple[float, int, str, tuple]] = []
        self.seq = 0
        self.n_real = 0

    def __bool__(self) -> bool:
        return bool(self.items)

    def __len__(self) -> int:
        return len(self.items)

    def next_at(self) -> float:
        return self.items[0][0]

    def push(self, at: float, kind: str, payload: tuple) -> None:
        heapq.heappush(self.items, (at, self.seq, kind, payload))
        self.seq += 1
        if kind not in self.CHAIN_KINDS:
            self.n_real += 1

    def pop(self) -> Tuple[float, int, str, tuple]:
        ev = heapq.heappop(self.items)
        if ev[2] not in self.CHAIN_KINDS:
            self.n_real -= 1
        return ev


# ---------------------------------------------------------------------------
# ClusterState — the shared mutable world every policy operates on
# ---------------------------------------------------------------------------


class ClusterState:
    """Idle map + minnow heap + TS ledger + fabric, with commit_* as the
    single Assignment-emission path (DESIGN.md §1)."""

    def __init__(
        self,
        fabric: Fabric,
        workers: Sequence[str],
        idle: Optional[Dict[str, float]] = None,
        ledger: Optional[TimeSlotLedger] = None,
        slot_duration: float = 1.0,
        horizon_slots: int = 256,
        background: Sequence[BackgroundFlow] = (),
    ) -> None:
        self.fabric = fabric
        self.workers = list(workers)
        self.workers_set = frozenset(self.workers)
        idle = idle or {}
        self.idle: Dict[str, float] = {
            n: float(idle.get(n, 0.0)) for n in self.workers
        }
        self.ledger = (
            ledger
            if ledger is not None
            else TimeSlotLedger(fabric, slot_duration, horizon_slots)
        )
        self.background: List[BackgroundFlow] = list(background)
        self.heap = MinnowHeap(self.idle, self.workers)
        self.now = 0.0
        #: Optional SDN data plane (``repro.net.DataPlane``), attached by
        #: ClusterController.  When present and carrying failures, source/
        #: path choices route around dead links; with no failures the code
        #: paths below are byte-identical to the dataplane-less ones.
        self.dataplane = None
        #: Per-state observability registry (``repro.obs``): the wavefront
        #: planner, reroute engine and controller all report through it.
        self.obs = Registry()
        #: Optional telemetry belief (``repro.net.telemetry.BeliefState``),
        #: attached by ClusterController.attach_telemetry.  Only consulted
        #: by policies constructed with ``telemetry=True``.
        self.belief = None

    @classmethod
    def from_instance(
        cls, instance: Instance, ledger: Optional[TimeSlotLedger] = None
    ) -> "ClusterState":
        """Offline-wrapper entry: ledger defaults to ``instance.fresh_ledger()``
        (background flows pre-booked, exactly as the batch schedulers did)."""
        return cls(
            instance.fabric,
            instance.workers,
            instance.idle,
            ledger=ledger if ledger is not None else instance.fresh_ledger(),
            slot_duration=instance.slot_duration,
            background=instance.background,
        )

    # -- queries ------------------------------------------------------------
    def minnow(self) -> str:
        return self.heap.minnow(self.idle)

    def _routing_live(self) -> bool:
        """True when failure-aware routing must be consulted."""
        return self.dataplane is not None and self.dataplane.has_failures()

    def choose_source(
        self,
        task: Task,
        dst: str,
        at: float,
        load: Optional[Dict[str, float]] = None,
        belief=None,
    ) -> Tuple[str, Tuple[int, ...]]:
        if not self._routing_live():
            return choose_source(task, dst, self.ledger, at, load=load,
                                 belief=belief)
        # Failure-aware single-path: each replica contributes its best
        # surviving path; dead replicas drop out of the candidate set.
        cands: List[str] = []
        rows_list: List[Tuple[int, ...]] = []
        for rep in task.replicas:
            if rep == dst:
                continue
            try:
                # k=1: only the shortest surviving path is consumed, and
                # Yen's first path is exactly that — skip the spur searches.
                paths = self.dataplane.candidates(rep, dst, k=1)
            except UnroutableError:
                continue
            cands.append(rep)
            rows_list.append(self.ledger.rows(paths[0]))
        if not cands:
            raise UnroutableError(
                f"task {task.tid}: no replica has a surviving path to {dst!r}"
            )
        bws = (self.ledger if belief is None else belief).path_bandwidth_batch(
            rows_list, at
        )
        best = min(
            range(len(cands)),
            key=lambda i: (
                load.get(cands[i], 0.0) if load is not None else 0.0,
                -bws[i],
                len(rows_list[i]),
                cands[i],
            ),
        )
        return cands[best], rows_list[best]

    def choose_source_path(
        self,
        task: Task,
        dst: str,
        at: float,
        load: Optional[Dict[str, float]] = None,
        k: Optional[int] = None,
        size: Optional[float] = None,
        belief=None,
    ) -> Tuple[str, Tuple[int, ...], TransferPlan]:
        """Multipath ``ND_dataSrc``: greedily plan the transfer on *every*
        surviving (replica, path) pair in one
        :meth:`TimeSlotLedger.plan_transfer_batch` pass and return the one
        that completes earliest — Eq. (4)'s argmin applied to paths, not
        just nodes.  Ties break to fewer hops, replica name, candidate
        order.  Returns ``(source, rows, plan)`` — the winning plan is the
        uncommitted greedy plan itself, so callers don't re-plan it.
        Requires a dataplane; falls back to :meth:`choose_source` without
        one.  ``size`` overrides ``task.size`` (rerouting scores the
        *remaining* bytes)."""
        if self.dataplane is None:
            src, rows = self.choose_source(task, dst, at, load=load,
                                           belief=belief)
            plan = self.ledger.plan_transfer(
                task.size if size is None else size, rows, not_before=at
            )
            return src, rows, plan
        pairs: List[Tuple[str, int, Tuple[int, ...]]] = []
        for rep in task.replicas:
            if rep == dst:
                continue
            try:
                paths = self.dataplane.candidates(rep, dst, k=k)
            except UnroutableError:
                continue
            for pi, p in enumerate(paths):
                pairs.append((rep, pi, self.ledger.rows(p)))
        if not pairs:
            raise UnroutableError(
                f"task {task.tid}: no replica has a surviving path to {dst!r}"
            )
        sz = task.size if size is None else size
        if belief is not None:
            # Telemetry mode: rank every pair by its *estimated* completion
            # (size / believed residual bandwidth, flat in time) and plan
            # only the winner on the true ledger — belief can misrank, the
            # realized transfer still books real residue (DESIGN.md §9).
            bws = belief.path_bandwidth_batch([r for _, _, r in pairs], at)
            best = min(
                range(len(pairs)),
                key=lambda i: (
                    load.get(pairs[i][0], 0.0) if load is not None else 0.0,
                    at + (sz / bws[i] if bws[i] > _EPS else float("inf")),
                    len(pairs[i][2]),
                    pairs[i][0],
                    pairs[i][1],
                ),
            )
            plan = self.ledger.plan_transfer(sz, pairs[best][2], not_before=at)
            return pairs[best][0], pairs[best][2], plan
        plans = self.ledger.plan_transfer_batch(
            sz,
            [r for _, _, r in pairs],
            not_before=at,
        )
        best = min(
            range(len(pairs)),
            key=lambda i: (
                load.get(pairs[i][0], 0.0) if load is not None else 0.0,
                plans[i].end,
                len(pairs[i][2]),
                pairs[i][0],
                pairs[i][1],
            ),
        )
        return pairs[best][0], pairs[best][2], plans[best]

    def nearest_source(
        self, task: Task, dst: str
    ) -> Tuple[str, Tuple[int, ...]]:
        """Fewest-hop replica, failure-aware when the dataplane carries
        failures (HDS/BAR stay bandwidth-oblivious but must not book dead
        links)."""
        if not self._routing_live():
            return nearest_source(task, dst, self.ledger)
        best = None
        for rep in task.replicas:
            if rep == dst:
                continue
            try:
                paths = self.dataplane.candidates(rep, dst, k=1)
            except UnroutableError:
                continue
            rows = self.ledger.rows(paths[0])
            key = (len(rows), rep)
            if best is None or key < best[0]:
                best = (key, rep, rows)
        if best is None:
            raise UnroutableError(
                f"task {task.tid}: no replica has a surviving path to {dst!r}"
            )
        return best[1], best[2]

    def scratch_ledger(
        self, horizon_slots: Optional[int] = None
    ) -> TimeSlotLedger:
        """A fresh ledger seeded with every background flow seen so far —
        what BAR uses for its static-belief phase-1/adjustment reasoning.

        Inherits the live ledger's horizon and rolling origin by default:
        a hardcoded 256-slot horizon under-provisioned workloads the real
        ledger handles, and an origin-0 scratch in a long-running
        controller would re-allocate the whole elapsed history just to
        plan at ``now`` (``occupy`` clamps background flows that started
        before the live window)."""
        ledger = TimeSlotLedger(
            self.fabric,
            self.ledger.slot_duration,
            self.ledger.reserved.shape[1]
            if horizon_slots is None
            else horizon_slots,
        )
        ledger.base_slot = self.ledger.base_slot
        ledger.retire_stride = self.ledger.retire_stride
        for bg in self.background:
            ledger.occupy(
                ledger.rows(self.fabric.path(bg.src, bg.dst)),
                bg.start,
                bg.end,
                bg.fraction,
            )
        return ledger

    # -- mutations ----------------------------------------------------------
    def advance(self, t: float) -> None:
        """Online clock: nothing can start before ``t``, so clamp ΥI_j up.

        Rebuilds the minnow heap once instead of pushing per-worker
        entries — an event stream on a big fleet would otherwise grow the
        heap by O(workers) per event without ever popping them.

        Also the rolling-horizon hook: once the clock has moved a stride
        past the ledger origin, fully-past slots are retired so the live
        matrix stays O(horizon) regardless of elapsed simulated time
        (DESIGN.md §7)."""
        if t < self.now:
            raise ValueError(f"time moves backwards: {t} < {self.now}")
        self.now = t
        dirty = False
        for n in self.workers:
            if self.idle[n] < t:
                self.idle[n] = t
                dirty = True
        if dirty:
            self.reheap()
        self.ledger.maybe_retire(t)

    def set_idle(self, idle: Dict[str, float]) -> None:
        """Replace idle estimates wholesale (ProgressRate refresh, §V.A)."""
        for n, v in idle.items():
            if n in self.idle:
                self.idle[n] = float(v)
        self.reheap()

    def reheap(self) -> None:
        self.heap = MinnowHeap(self.idle, self.workers)

    def remove_worker(self, node: str) -> None:
        """Evict a crashed host from every placement surface at once:
        the worker list/set (``pick_local`` membership), the idle map and
        the minnow heap — a dead machine must never win Eq. (1)'s argmin."""
        if node not in self.workers_set:
            return
        self.workers.remove(node)
        self.workers_set = frozenset(self.workers)
        self.heap.remove(node)
        del self.idle[node]

    def add_worker(self, node: str, idle: Optional[float] = None) -> None:
        """(Re-)admit a recovered host with its idle clock at ``idle``
        (default: the current sim time — a fresh machine starts empty)."""
        if node in self.workers_set:
            return
        t = self.now if idle is None else float(idle)
        self.workers.append(node)
        self.workers_set = frozenset(self.workers)
        self.idle[node] = t
        self.heap.insert(node, t)

    def observe_flow(self, flow: BackgroundFlow) -> None:
        """Dynamic background cross-traffic: book it on the ledger and
        remember it so scratch ledgers (BAR) see it too."""
        self.background.append(flow)
        self.ledger.occupy(
            self.ledger.rows(self.fabric.path(flow.src, flow.dst)),
            flow.start,
            flow.end,
            flow.fraction,
        )

    # -- the single Assignment-emission path -------------------------------
    def commit_local(
        self, task: Task, node: str, bw_needed: Optional[float] = None
    ) -> Assignment:
        """Run ``task`` data-locally on ``node`` (Eq. 1 with BW=∞)."""
        start = self.idle[node]
        finish = start + task.compute
        self.idle[node] = finish
        self.heap.update(node, finish)
        return Assignment(task.tid, node, None, None, start, finish, bw_needed)

    def commit_remote(
        self,
        task: Task,
        node: str,
        src: str,
        plan: TransferPlan,
        bw_needed: Optional[float] = None,
    ) -> Assignment:
        """Run ``task`` on ``node`` with data moved from ``src``: reserve the
        plan's TS slots on every path link and book the compute."""
        self.ledger.commit(plan)
        start = plan.end if plan.slot_fracs else self.idle[node]
        finish = start + task.compute
        self.idle[node] = finish
        self.heap.update(node, finish)
        return Assignment(task.tid, node, src, plan, start, finish, bw_needed)

    # -- snapshots (Pre-BASS guard, what-if planning) -----------------------
    def snapshot(self) -> Tuple:
        return (dict(self.idle), self.ledger.reserved.copy(),
                self.ledger.base_slot, self.ledger.retired_slots,
                self.now, len(self.background))

    def restore(self, snap: Tuple) -> None:
        idle, reserved, base_slot, retired_slots, now, n_bg = snap
        self.idle = dict(idle)
        # Through the ``reserved`` setter: any attached device mirror is
        # invalidated and re-uploads the full window on its next sync —
        # a restore crossing a retire must not leave mirrored columns
        # aligned to the pre-restore origin.
        self.ledger.reserved = reserved.copy()
        self.ledger.base_slot = base_slot
        self.ledger.retired_slots = retired_slots
        self.now = now
        del self.background[n_bg:]
        self.reheap()

    def clone(self) -> "ClusterState":
        dup = ClusterState.__new__(ClusterState)
        dup.fabric = self.fabric
        dup.workers = list(self.workers)
        dup.workers_set = self.workers_set
        dup.idle = dict(self.idle)
        dup.ledger = TimeSlotLedger.__new__(TimeSlotLedger)
        dup.ledger.fabric = self.ledger.fabric
        dup.ledger.slot_duration = self.ledger.slot_duration
        dup.ledger._row = self.ledger._row
        dup.ledger._names = self.ledger._names
        dup.ledger.capacity = self.ledger.capacity
        dup.ledger.reserved = self.ledger.reserved.copy()
        dup.ledger.base_slot = self.ledger.base_slot
        dup.ledger.retired_slots = self.ledger.retired_slots
        dup.ledger.retire_stride = self.ledger.retire_stride
        dup.ledger.batch_scan_cells = 0
        dup.ledger._path_rows = self.ledger._path_rows  # shared read cache
        dup.ledger._path_rows_version = self.ledger._path_rows_version
        dup.background = list(self.background)
        dup.heap = MinnowHeap(dup.idle, dup.workers)
        dup.now = self.now
        dup.dataplane = self.dataplane  # shared: liveness is global state
        dup.obs = Registry()            # fresh: probe stats must not pollute
        dup.belief = self.belief        # shared: belief is read-only here
        return dup


# ---------------------------------------------------------------------------
# SchedulingPolicy protocol + the four paper policies
# ---------------------------------------------------------------------------


class SchedulingSurface(Protocol):
    """The exact state surface :meth:`BassPolicy.place` consumes — the
    scheduling state machine as a *pod-scope reusable unit* (DESIGN.md
    §12).  :class:`ClusterState` is the flat implementation;
    ``repro.core.hierarchy.HierarchicalState`` implements the same surface
    over per-pod shards (lazily-clamped idle map, per-pod minnow heaps, a
    sharded ledger) so one Algorithm-1 implementation drives both and the
    byte-parity contract is structural, not re-derived.
    """

    #: ``ΥI_j`` — a mapping view; implementations may clamp lazily against
    #: ``now`` instead of eagerly advancing every worker.
    idle: Dict[str, float]
    #: Membership container for ``pick_local`` (a set at fleet scale).
    workers_set: frozenset
    #: Plan/commit surface (flat ``TimeSlotLedger`` or ``ShardedLedger``).
    ledger: TimeSlotLedger
    obs: Registry

    def minnow(self) -> str:
        """``ND_minnow`` under the (idle, name) order."""
        ...

    def choose_source(
        self,
        task: Task,
        dst: str,
        at: float,
        load: Optional[Dict[str, float]] = None,
        belief=None,
    ) -> Tuple[str, Tuple[int, ...]]:
        ...

    def commit_local(
        self, task: Task, node: str, bw_needed: Optional[float] = None
    ) -> Assignment:
        ...

    def commit_remote(
        self,
        task: Task,
        node: str,
        src: str,
        plan: TransferPlan,
        bw_needed: Optional[float] = None,
    ) -> Assignment:
        ...


class SchedulingPolicy(Protocol):
    """Per-event scheduling decisions over a shared :class:`ClusterState`."""

    name: str

    def place(self, task: Task, state: ClusterState) -> Assignment:
        """Decide one arriving task."""
        ...

    def place_batch(
        self, tasks: Sequence[Task], state: ClusterState
    ) -> List[Assignment]:
        """Decide a job's task list (arrival of a whole job)."""
        ...


class BassPolicy:
    """Algorithm 1, one decision per arriving task (see ``bass`` module docs
    for the Case 1.1/1.2/1.3/2 taxonomy).

    ``multipath=True`` scores every surviving (replica, candidate-path)
    pair from the controller's data plane instead of one shortest path per
    replica — on fabrics with path diversity (fat-tree, multi-spine Clos)
    the transfer takes whichever parallel path has the most residue.
    Requires a dataplane-carrying state to differ from base BASS; with
    ``multipath=False`` (default) behaviour is byte-identical to before.

    ``telemetry=True`` scores the Case 1.2/1.3 tradeoff and the source
    choice against the controller's measured-bandwidth belief
    (``state.belief``, attached by ``ClusterController.attach_telemetry``)
    instead of the oracle ledger; commits still plan and book real slots
    on the true ledger — the belief decides *where*, never *what is
    booked* (DESIGN.md §9).  With ``telemetry=False`` (default) the
    belief is never consulted and schedules are byte-identical to before.
    """

    name = "bass"

    def __init__(
        self,
        multipath: bool = False,
        k_paths: Optional[int] = None,
        telemetry: bool = False,
    ):
        self.multipath = multipath
        self.k_paths = k_paths
        self.telemetry = telemetry

    def _belief(self, state: ClusterState):
        if not self.telemetry:
            return None
        belief = getattr(state, "belief", None)
        if belief is None:
            raise RuntimeError(
                "BassPolicy(telemetry=True) needs a belief state — attach a "
                "monitor via ClusterController.attach_telemetry() first"
            )
        return belief

    def _source(
        self, state: ClusterState, task: Task, dst: str, at: float
    ) -> Tuple[str, Tuple[int, ...], Optional[TransferPlan]]:
        """(source, rows, plan) — the multipath scorer already produced the
        winning greedy plan (true-ledger, belief-ranked under telemetry);
        single-path mode returns ``None`` and the caller plans the rows
        itself."""
        belief = self._belief(state)
        if self.multipath:
            return state.choose_source_path(
                task, dst, at, k=self.k_paths, belief=belief
            )
        src, rows = state.choose_source(task, dst, at=at, belief=belief)
        return src, rows, None

    @staticmethod
    def _trace(state, a: Assignment, task: Task, reason: str) -> Assignment:
        rec = state.obs.trace
        if rec.enabled:
            rec.record(
                "decision",
                tid=a.tid,
                node=a.node,
                src=a.source,
                reason=reason,
                cands=sum(1 for r in task.replicas if r != a.node),
                start=a.start,
                finish=a.finish,
            )
        return a

    def place(self, task: Task, state: ClusterState) -> Assignment:
        idle = state.idle
        minnow = state.minnow()
        loc = pick_local(task, idle, state.workers_set)

        if loc is not None and (minnow == loc or idle[loc] <= idle[minnow] + _EPS):
            # Case 1.1 — local is optimal, no movement (Eq. 1 with BW=∞).
            return self._trace(
                state, state.commit_local(task, loc), task, "local-optimal"
            )

        belief = self._belief(state)
        if loc is not None:
            # Case 1.2 / 1.3 — tradeoff governed by the TS ledger (oracle)
            # or by the telemetry belief's flat bandwidth estimate.
            yc_loc = completion_time(task.compute, 0.0, idle[loc])
            src, rows, plan = self._source(state, task, minnow, at=idle[minnow])
            if belief is None:
                if plan is None:
                    plan = state.ledger.plan_transfer(
                        task.size, rows, not_before=idle[minnow]
                    )
                tm = plan.end - plan.start if plan.slot_fracs else 0.0
            else:
                tm = _believed_tm(belief, rows, task.size, idle[minnow])
            yc_min = completion_time(task.compute, 0.0, idle[minnow]) + tm
            # Algorithm 1 line 8: bandwidth needed so that ΥC_minnow < ΥC_loc.
            tm_budget = yc_loc - task.compute - idle[minnow]
            bw_needed = task.size / tm_budget if tm_budget > _EPS else float("inf")
            if yc_min < yc_loc - _EPS:
                # Case 1.2 — BW_{i,minnow} ≤ BW_rl: go remote, reserve slots.
                if plan is None:
                    # Belief said remote: realize the plan on the true ledger.
                    plan = state.ledger.plan_transfer(
                        task.size, rows, not_before=idle[minnow]
                    )
                return self._trace(
                    state,
                    state.commit_remote(task, minnow, src, plan,
                                        bw_needed=bw_needed),
                    task,
                    "remote-faster",
                )
            # Case 1.3 — residue insufficient: stay local.
            return self._trace(
                state,
                state.commit_local(task, loc, bw_needed=bw_needed),
                task,
                "local-bw-insufficient",
            )

        # Case 2 — locality starvation: remote on ND_minnow with reservation.
        src, rows, plan = self._source(state, task, minnow, at=idle[minnow])
        if plan is None:
            plan = state.ledger.plan_transfer(
                task.size, rows, not_before=idle[minnow]
            )
        return self._trace(
            state,
            state.commit_remote(task, minnow, src, plan),
            task,
            "locality-starved",
        )

    def place_batch(
        self, tasks: Sequence[Task], state: ClusterState
    ) -> List[Assignment]:
        """Batch arrivals route through the wavefront engine
        (``core.wavefront``): one broadcasted (task × replica × path)
        scoring pass per wave instead of per-task ledger re-scans —
        bit-identical to the per-task ``place`` loop, including under
        live failure-aware routing (the planner threads the data plane's
        dead-link set through candidate enumeration, so degraded batches
        keep wavefront throughput instead of reverting to the loop).

        Telemetry mode falls back to the sequential loop: the wavefront's
        speculative curves are oracle-ledger artifacts and its whole
        contract is bit-identity with oracle ``place`` — belief-scored
        decisions are made per task instead (DESIGN.md §9)."""
        if len(tasks) > 1 and not self.telemetry:
            from .wavefront import WavefrontPlanner

            return WavefrontPlanner.for_state(state).place_batch(
                tasks, multipath=self.multipath, k_paths=self.k_paths
            )
        return [self.place(t, state) for t in tasks]


class HdsPolicy:
    """Hadoop Default Scheduler (Discussion 1): node-driven greedy, local
    tasks first, bandwidth-oblivious decisions whose transfers still pay."""

    name = "hds"

    def place(self, task: Task, state: ClusterState) -> Assignment:
        return self.place_batch([task], state)[0]

    def place_batch(
        self, tasks: Sequence[Task], state: ClusterState
    ) -> List[Assignment]:
        idle = state.idle
        unstarted = {t.tid: t for t in tasks}
        out: List[Assignment] = []
        # Event heap of (idle_time, node); deterministic tie-break on name.
        heap: List[Tuple[float, str]] = sorted(
            (idle[n], n) for n in state.workers
        )
        heapq.heapify(heap)

        while unstarted and heap:
            t_idle, node = heapq.heappop(heap)
            if abs(idle[node] - t_idle) > _EPS:
                continue  # stale entry
            local = [tid for tid, t in unstarted.items() if node in t.replicas]
            if local:
                task = unstarted.pop(min(local))
                out.append(state.commit_local(task, node))
            else:
                task = unstarted.pop(min(unstarted))
                src, rows = state.nearest_source(task, node)
                plan = state.ledger.plan_transfer(
                    task.size, rows, not_before=t_idle
                )
                out.append(state.commit_remote(task, node, src, plan))
            heapq.heappush(heap, (idle[node], node))

        out.sort(key=lambda a: a.tid)
        return out


class BarPolicy:
    """BAR (Jin et al., CCGrid'11): HDS phase-1 allocation, latest-task
    remote adjustment with *static* bandwidth beliefs, then realization of
    the chosen queues against the real ledger."""

    name = "bar"

    def place(self, task: Task, state: ClusterState) -> Assignment:
        return self.place_batch([task], state)[0]

    def place_batch(
        self, tasks_seq: Sequence[Task], state: ClusterState
    ) -> List[Assignment]:
        tasks = {t.tid: t for t in tasks_seq}
        base_idle = dict(state.idle)
        fabric = state.fabric

        # Phase 1 + move decisions run on a scratch state (BAR's own beliefs);
        # the caller-visible ledger only receives the realized transfers.
        scratch = ClusterState(
            fabric, state.workers, base_idle, ledger=state.scratch_ledger()
        )
        phase1 = HdsPolicy().place_batch(tasks_seq, scratch)
        queues: Dict[str, List[Assignment]] = {}
        for a in sorted(phase1, key=lambda a: (a.start, a.tid)):
            queues.setdefault(a.node, []).append(a)

        def static_tm(task: Task, node: str) -> Tuple[float, Optional[str]]:
            if node in task.replicas:
                return 0.0, None
            best = None
            for rep in task.replicas:
                bw = fabric.path_capacity(rep, node)
                tm = task.size / bw if bw > 0 else float("inf")
                if best is None or tm < best[0]:
                    best = (tm, rep)
            assert best is not None
            return best

        def recompute(queues: Dict[str, List[Assignment]]) -> None:
            for node, q in queues.items():
                t = base_idle.get(node, 0.0)
                for a in q:
                    tm, src = static_tm(tasks[a.tid], node)
                    a.node, a.source, a.transfer = node, src, None
                    a.start = t + tm
                    a.finish = a.start + tasks[a.tid].compute
                    t = a.finish

        recompute(queues)

        while True:
            all_assign = [a for q in queues.values() for a in q]
            latest = max(all_assign, key=lambda a: (a.finish, a.tid))
            task = tasks[latest.tid]
            # Candidate: append to another node's queue end.
            best: Optional[Tuple[float, str]] = None
            for node in state.workers:
                if node == latest.node:
                    continue
                q = queues.setdefault(node, [])
                t_avail = q[-1].finish if q else base_idle.get(node, 0.0)
                tm, _src = static_tm(task, node)
                yc = t_avail + tm + task.compute
                if yc < latest.finish - _EPS and (best is None or (yc, node) < best):
                    best = (yc, node)
            if best is None:
                break
            _yc, node = best
            queues[latest.node].remove(latest)
            queues[node].append(latest)
            recompute(queues)

        # --- Realization: BAR's *decisions* used static beliefs; the chosen
        # per-node queues now replay against the real shared state so
        # contended moves pay their true movement time (paper §I critique
        # "disregard available bandwidth", made honest).
        heads: Dict[str, int] = {n: 0 for n in queues}
        out: List[Assignment] = []
        while True:
            ready = [n for n, q in queues.items() if heads[n] < len(q)]
            if not ready:
                break
            node = min(ready, key=lambda n: (state.idle[n], n))
            a = queues[node][heads[node]]
            heads[node] += 1
            task = tasks[a.tid]
            if node in task.replicas:
                out.append(state.commit_local(task, node))
            else:
                src, rows = state.nearest_source(task, node)
                plan = state.ledger.plan_transfer(
                    task.size, rows, not_before=state.idle[node]
                )
                out.append(state.commit_remote(task, node, src, plan))

        out.sort(key=lambda a: a.tid)
        return out


class PreBassPolicy:
    """Pre-BASS (Discussion 2 / Example 2): BASS, then prefetch every remote
    transfer as early as the ledger allows, from the least-loaded holder.

    With ``guard=True`` (the default, and the offline-wrapper behaviour when
    no shared ledger is passed) the refined schedule is adopted only if it
    does not finish later than plain BASS — prefetching with a different
    source can, on adversarial ledgers, push a later task's window back.

    Both the guard probe and the base pass route through
    ``BassPolicy.place_batch`` and therefore the wavefront engine; only
    the prefetch re-plan loop is inherently sequential (each re-plan's
    window depends on the previous release/commit pair).
    """

    name = "prebass"

    def __init__(self, guard: bool = True, telemetry: bool = False):
        self.guard = guard
        self.telemetry = telemetry

    def _bass(self) -> "BassPolicy":
        return BassPolicy(telemetry=self.telemetry)

    def place(self, task: Task, state: ClusterState) -> Assignment:
        return self.place_batch([task], state)[0]

    def place_batch(
        self, tasks_seq: Sequence[Task], state: ClusterState
    ) -> List[Assignment]:
        base_mk: Optional[float] = None
        if self.guard:
            probe = self._bass().place_batch(tasks_seq, state.clone())
            base_mk = max((a.finish for a in probe), default=0.0)
        snap = state.snapshot() if self.guard else None
        out = self._prefetch(tasks_seq, state)
        refined_mk = max((a.finish for a in out), default=0.0)
        if base_mk is not None and refined_mk > base_mk + 1e-9:
            assert snap is not None
            state.restore(snap)
            return self._bass().place_batch(tasks_seq, state)
        return out

    def _prefetch(
        self, tasks_seq: Sequence[Task], state: ClusterState
    ) -> List[Assignment]:
        idle0 = dict(state.idle)
        # Prefetch can start no earlier than the job's arrival (state.now;
        # 0.0 for the offline wrappers) — replanning at t=0 for a job that
        # arrived at t=25 would book bandwidth that already elapsed.
        origin = state.now
        base = self._bass().place_batch(tasks_seq, state)
        ledger = state.ledger
        tasks = {t.tid: t for t in tasks_seq}

        # Release every remote transfer, then re-plan in assignment order.
        remote = [a for a in base if a.transfer is not None]
        for a in remote:
            ledger.release(a.transfer)

        # Node availability proxy for "least loaded replica holder".
        load: Dict[str, float] = dict(idle0)
        for a in base:
            load[a.node] = max(load.get(a.node, 0.0), a.finish)

        ready: Dict[int, float] = {}
        for a in base:
            if a.transfer is None:
                ready[a.tid] = 0.0
                continue
            task = tasks[a.tid]
            # state-level choice: failure-aware when the dataplane carries
            # dead links (identical to the module fn otherwise); belief-
            # ranked under telemetry, like the base pass.
            src, rows = state.choose_source(
                task, a.node, at=origin, load=load,
                belief=state.belief if self.telemetry else None,
            )
            plan = ledger.plan_transfer(task.size, rows, not_before=origin)
            ledger.commit(plan)
            a.source, a.transfer = src, plan
            ready[a.tid] = plan.end

        # Recompute per-node timelines with prefetched readiness.
        queues: Dict[str, List[Assignment]] = {}
        for a in sorted(base, key=lambda a: (a.start, a.tid)):
            queues.setdefault(a.node, []).append(a)
        out: List[Assignment] = []
        for node, queue in queues.items():
            t = idle0.get(node, 0.0)
            for a in queue:
                a.start = max(t, ready.get(a.tid, 0.0))
                a.finish = a.start + tasks[a.tid].compute
                t = a.finish
                out.append(a)
            # Prefetch pulled the node's timeline forward: resync the shared
            # idle map (BASS's bookkeeping assumed the un-prefetched starts).
            state.idle[node] = t
        state.reheap()

        out.sort(key=lambda a: a.tid)
        return out


POLICIES: Dict[str, Callable[[], SchedulingPolicy]] = {
    "bass": BassPolicy,
    "hds": HdsPolicy,
    "bar": BarPolicy,
    "prebass": PreBassPolicy,
}


def run_policy(
    policy: SchedulingPolicy,
    instance: Instance,
    ledger: Optional[TimeSlotLedger] = None,
    order: Optional[Sequence[int]] = None,
) -> Schedule:
    """Offline wrapper core: one batch decision over a frozen Instance.

    This is what ``schedule_bass``/``schedule_hds``/``schedule_bar``/
    ``schedule_prebass`` now are — byte-identical to the historical batch
    schedulers (enforced by the equivalence tests).
    """
    state = ClusterState.from_instance(instance, ledger)
    if order is not None:
        tasks_by_id = {t.tid: t for t in instance.tasks}
        tasks: Sequence[Task] = [tasks_by_id[tid] for tid in order]
    else:
        tasks = instance.tasks
    out = policy.place_batch(tasks, state)
    return Schedule(
        out, state.ledger, kinds={t.tid: t.kind for t in instance.tasks}
    )


# ---------------------------------------------------------------------------
# ClusterController — the online event loop
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded task re-execution under host crashes (Hadoop-style).

    A killed task is re-placed through the normal policy path (so retries
    stay bandwidth-aware) after ``backoff(attempt)`` sim-seconds; a retry
    that finds no live replica (transient all-replicas-dead window) burns
    an attempt and backs off again, and exhausting ``max_attempts`` raises
    :class:`UnroutableError` — no silent stalls, matching the reroute
    contract.  A host that crashes ``blacklist_after`` times is not
    re-admitted on recovery (its replicas stay priced out).
    """

    max_attempts: int = 4
    backoff_s: float = 1.0
    backoff_factor: float = 2.0
    blacklist_after: int = 3

    def backoff(self, attempt: int) -> float:
        """Sim-time delay before retry number ``attempt`` (0-based)."""
        return self.backoff_s * (self.backoff_factor ** attempt)


@dataclass
class _SpecRecord:
    """One in-flight LATE speculation: primary vs backup, first finisher
    wins at the resolve event."""

    jid: int
    primary: Assignment
    backup: Assignment


@dataclass
class JobRecord:
    """One submitted job: arrival time, tasks, and (once placed) results."""

    jid: int
    submit_at: float
    tasks: List[Task]
    assignments: List[Assignment] = field(default_factory=list)
    placed: bool = False
    rerouted: int = 0  # transfers re-planned after a path died
    reexecuted: int = 0     # tasks killed by a host crash and re-placed
    speculative: int = 0    # LATE backup copies launched
    wasted_bytes: float = 0.0  # delivered bytes thrown away (kills + losers)
    shed: bool = False  # load-shed by a headless controller's full mailbox

    @property
    def makespan(self) -> float:
        """Absolute completion time of the job's last task."""
        return max((a.finish for a in self.assignments), default=self.submit_at)


def _kernel_obs() -> dict:
    """Device-kernel snapshot section: backend + compile-cache counters
    (all zeros until the device module is actually imported — reading
    stats must never *cause* a jax import)."""
    from ..kernels import ts_plan

    out = {"backend": ts_plan.get_backend()}
    out.update(
        ts_plan.device_stats()
        or {k: 0 for k in ("traces", "cache_hits", "mirror_syncs",
                           "mirror_cells", "mirror_uploads")}
    )
    return out


class ClusterController:
    """The SDN controller as a long-lived service: multi-job arrival
    streams, dynamic background flows, and raw flow reservations share one
    :class:`ClusterState` and one :class:`SchedulingPolicy`."""

    def __init__(
        self,
        fabric: Fabric,
        workers: Sequence[str],
        policy: "SchedulingPolicy | str" = "bass",
        idle: Optional[Dict[str, float]] = None,
        slot_duration: float = 1.0,
        horizon_slots: int = 256,
        background: Sequence[BackgroundFlow] = (),
        k_paths: int = 4,
        retry: Optional[RetryPolicy] = None,
        speculation: bool = False,
        mailbox_limit: int = 64,
    ) -> None:
        if isinstance(policy, str):
            policy = POLICIES[policy]()
        self.policy = policy
        self.state = ClusterState(
            fabric,
            workers,
            idle,
            slot_duration=slot_duration,
            horizon_slots=horizon_slots,
        )
        for bg in background:
            self.state.observe_flow(bg)
        # The SDN data plane: link liveness, k-shortest-path candidates,
        # per-switch flow tables.  Lazy import keeps core→net one-way at
        # module load (net imports core.topology/timeslot).
        from ..net.dataplane import DataPlane

        self.dataplane = DataPlane(fabric, k=k_paths)
        self.state.dataplane = self.dataplane
        self.jobs: Dict[int, JobRecord] = {}
        self.flows: Dict[object, TransferPlan] = {}
        self.reroute_log: List[object] = []     # RerouteRecords, in fire order
        #: The deterministic event heap (see :class:`EventQueue`; the
        #: ``_events``/``_seq``/``_n_real_events`` names below stay as
        #: delegating properties because snapshots and the dispatch loop
        #: address the heap list and counters directly).  ``n_real``
        #: counts queued events that are *work* (everything except the
        #: poll/hb chain ticks); the chains re-arm only while it is
        #: non-zero — keying off the heap itself would let the two chains
        #: count each other as pending work and sustain themselves forever
        #: once both telemetry and heartbeats are attached.
        self._queue = EventQueue()
        self._next_jid = 0       # monotonic: ids stay unique if jobs are pruned
        self._auto_flow = 0      # untagged reservations get ("flow", n) keys
        self._idle0 = dict(self.state.idle)     # initial ΥI_j, for re-timelining
        self._live_jobs: Dict[int, float] = {}  # jid -> latest transfer end
        self._suspended: List[Tuple[object, Tuple[str, ...], float]] = []
        self._expiry: List[Tuple[float, int, object]] = []  # (end, gen, cookie)
        self._flow_gen: Dict[object, int] = {}
        #: Failure-replan implementation: "batched" (core.reroute engine)
        #: or "sequential" (the per-victim reference loop — the oracle the
        #: property tests and bench_failover_scale compare against).
        self.reroute_engine = "batched"
        #: One observability registry per controller, shared with the
        #: state so the wavefront planner reports into the same snapshot
        #: (DESIGN.md §9).  ``reroute_stats`` keeps its historical
        #: dict-style surface (events handled, victims replanned, prescan
        #: curve hits vs live re-scores, invariant-guard fallbacks) but is
        #: now a live view over registry counters.
        self.obs = self.state.obs
        self.reroute_stats = self.obs.group(
            "reroute", ("events", "victims", "hits", "misses", "fallbacks")
        )
        self._ev_stats = self.obs.group(
            "controller",
            ("events", "jobs", "flows", "transfers", "net_events", "polls"),
        )
        # Pre-register the wavefront group so the snapshot always carries
        # the section (zeros until the planner engages); the planner later
        # grabs this same group by prefix.
        self.obs.group("wavefront", ("hits", "misses", "waves", "spec_tasks"))
        self.obs.register_provider("ledger", self._ledger_obs)
        self.obs.register_provider("jobs", self._jobs_obs)
        self.obs.register_provider("kernels", _kernel_obs)
        #: Telemetry monitor (``repro.net.telemetry.LinkStatsMonitor``),
        #: None until attach_telemetry(); drives "poll" events.
        self.telemetry = None
        self._poll_pending = False
        # -- task-plane robustness (DESIGN.md §10) --------------------------
        #: Bounded re-execution policy for tasks killed by host crashes.
        self.retry = retry if retry is not None else RetryPolicy()
        #: LATE-style speculative execution: on straggler onset, launch a
        #: backup copy iff the ledger's residual bandwidth says it finishes
        #: before the straggler's projected finish (first finisher wins).
        self.speculation = speculation
        #: Per-host crash count; hosts reaching ``retry.blacklist_after``
        #: are not re-admitted on recovery.
        self._host_failures: Dict[str, int] = {}
        self.blacklist: set = set()
        self._specs: Dict[int, _SpecRecord] = {}  # tid -> live speculation
        self.fault_stats = self.obs.group(
            "faults",
            ("host_down", "host_up", "killed", "retries", "reexecuted",
             "spec_launch", "spec_win", "blacklisted", "wasted_bytes"),
        )
        #: Heartbeat monitor (``repro.runtime.ft.HeartbeatMonitor``), None
        #: until attach_heartbeats(); drives "hb" sweep events in sim time.
        self.heartbeats = None
        self._hb_pending = False
        self._hb_interval = 0.0
        self._hb_last = 0.0
        # -- control-plane crash-recovery (DESIGN.md §11) -------------------
        #: Write-ahead journal (``core.journal.Journal``), None until
        #: attach_journal(); records every public entry-point call.
        self.journal = None
        self._replaying = False  # replay must not re-journal its own calls
        self._in_run = False     # run() journals once, not its inner targets
        #: Headless data-plane mode: while the control plane is down, the
        #: data plane keeps forwarding on installed rules but scheduling
        #: stops — job arrivals queue in a bounded mailbox (overflow →
        #: load-shed), every other event is deferred to recovery, and the
        #: poll/heartbeat chains are suspended.
        self.ctrl_down = False
        self._down_since = 0.0
        self.mailbox_limit = int(mailbox_limit)
        self._mailbox: List[Tuple[str, tuple]] = []  # deferred, arrival order
        self._mailbox_jobs = 0
        self.shed_jobs: List[int] = []
        self.ha_stats = self.obs.group(
            "ha",
            ("ctrl_down", "ctrl_up", "mailbox_queued", "mailbox_shed",
             "deferred", "reconciled_rules"),
        )
        self.now = 0.0

    # -- event-queue delegation ---------------------------------------------
    # The dispatch loop, the poll/hb chains and the snapshot machinery all
    # address the heap list and its counters by these historical names;
    # the queue object itself is what pod-scope controllers reuse.
    @property
    def _events(self) -> List[Tuple[float, int, str, tuple]]:
        return self._queue.items

    @_events.setter
    def _events(self, items: List[Tuple[float, int, str, tuple]]) -> None:
        self._queue.items = items

    @property
    def _seq(self) -> int:
        return self._queue.seq

    @_seq.setter
    def _seq(self, value: int) -> None:
        self._queue.seq = value

    @property
    def _n_real_events(self) -> int:
        return self._queue.n_real

    @_n_real_events.setter
    def _n_real_events(self, value: int) -> None:
        self._queue.n_real = value

    @classmethod
    def from_instance(
        cls, instance: Instance, policy: "SchedulingPolicy | str" = "bass"
    ) -> "ClusterController":
        return cls(
            instance.fabric,
            instance.workers,
            policy,
            idle=instance.idle,
            slot_duration=instance.slot_duration,
            background=instance.background,
        )

    # -- write-ahead journal (DESIGN.md §11) --------------------------------
    def attach_journal(self, journal=None):
        """Attach a :class:`~repro.core.journal.Journal`: from now on every
        public entry-point call (``submit``, ``inject_flow``,
        ``reserve_transfer_at``, ``fail_*``/``recover_*``, ``straggle``,
        ``fail_controller``/``recover_controller``, ``attach_telemetry``/
        ``attach_heartbeats``, ``run_until``/``run``) is recorded with its
        *resolved* arguments before the mutation happens.  Returns the
        journal (a fresh one by default)."""
        if self.journal is not None:
            raise RuntimeError("journal already attached")
        from .journal import Journal

        self.journal = journal if journal is not None else Journal()
        return self.journal

    def _journal(self, op: str, *args) -> None:
        if self.journal is None or self._replaying or self._in_run:
            return
        self.journal.append(op, *args)

    def _apply_record(self, rec) -> None:
        """Re-issue one journaled entry-point call (replay dispatch)."""
        op, a = rec.op, rec.args
        if op == "submit":
            self.submit(list(a[2]), at=a[0], jid=a[1])
        elif op == "inject_flow":
            self.inject_flow(a[0], at=a[1])
        elif op == "reserve_transfer":
            self.reserve_transfer_at(a[0], a[1], a[2], tag=a[3])
        elif op == "fail_link":
            self.fail_link(a[0], at=a[1])
        elif op == "recover_link":
            self.recover_link(a[0], at=a[1])
        elif op == "fail_switch":
            self.fail_switch(a[0], at=a[1])
        elif op == "recover_switch":
            self.recover_switch(a[0], at=a[1])
        elif op == "fail_host":
            self.fail_host(a[0], at=a[1])
        elif op == "recover_host":
            self.recover_host(a[0], at=a[1])
        elif op == "straggle":
            self.straggle(a[0], a[1], at=a[2])
        elif op == "fail_controller":
            self.fail_controller(at=a[0])
        elif op == "recover_controller":
            self.recover_controller(at=a[0])
        elif op == "attach_telemetry":
            self.attach_telemetry(
                poll_interval=a[0], estimator=a[1], **a[2]
            )
        elif op == "attach_heartbeats":
            self.attach_heartbeats(interval=a[0], grace_s=a[1])
        elif op == "run_until":
            self.run_until(a[0])
        elif op == "run":
            self.run()
        else:
            raise ValueError(f"unknown journal op {op!r}")

    def replay_journal(self, journal, from_lsn: int = 0) -> int:
        """Re-issue ``journal``'s records from ``from_lsn`` through the
        normal entry points; returns the number of records applied.
        Replayed calls are not re-journaled."""
        self._replaying = True
        try:
            n = 0
            for rec in journal.since(from_lsn):
                self._apply_record(rec)
                n += 1
            return n
        finally:
            self._replaying = False

    # -- full-fidelity snapshots + recovery (DESIGN.md §11) -----------------
    def _policy_spec(self) -> Tuple[str, Optional[dict]]:
        """(name, kwargs) rebuilding this controller's policy, or
        ``(name, None)`` for a custom policy object ``recover_from`` cannot
        reconstruct on its own (pass ``policy=`` explicitly there)."""
        p = self.policy
        if type(p) is BassPolicy:
            return ("bass", {"multipath": p.multipath, "k_paths": p.k_paths,
                             "telemetry": p.telemetry})
        if type(p) is PreBassPolicy:
            return ("prebass", {"guard": p.guard, "telemetry": p.telemetry})
        if type(p) is HdsPolicy:
            return ("hds", {})
        if type(p) is BarPolicy:
            return ("bar", {})
        return (getattr(p, "name", type(p).__name__), None)

    def snapshot(self):
        """Full-fidelity :class:`~repro.core.journal.ControllerSnapshot` at
        the current journal position.

        Coverage matrix (field → captured-by) is documented in DESIGN.md
        §11; everything is plain picklable data — no fabric, registry or
        callable references.  Jobs, assignments and live speculations are
        deep-copied *together* so the ``_SpecRecord.primary is assignment``
        identity links survive both the dump and the restore.
        """
        st, led, dp = self.state, self.state.ledger, self.dataplane
        with self.obs.span("recovery.snapshot"):
            jobs, specs = copy.deepcopy((self.jobs, self._specs))
            hb = None
            if self.heartbeats is not None:
                hb = {
                    "grace_s": self.heartbeats.grace_s,
                    "interval": self._hb_interval,
                    "last": self._hb_last,
                    "hosts": [(h.name, h.last_beat, h.alive)
                              for h in self.heartbeats.hosts.values()],
                }
            payload = {
                "config": {
                    "policy": self._policy_spec(),
                    "slot_duration": led.slot_duration,
                    "k_paths": dp.engine.k,
                    "retry": (self.retry.max_attempts, self.retry.backoff_s,
                              self.retry.backoff_factor,
                              self.retry.blacklist_after),
                    "speculation": self.speculation,
                    "mailbox_limit": self.mailbox_limit,
                    "reroute_engine": self.reroute_engine,
                },
                "now": self.now,
                "state": {
                    "workers": list(st.workers),
                    "idle": dict(st.idle),
                    "now": st.now,
                    "background": list(st.background),
                    "idle0": dict(self._idle0),
                },
                "ledger": led.dump_state(),
                "liveness": dp.dump_liveness(),
                "tables": dp.tables.dump_state(),
                "jobs": jobs,
                "specs": specs,
                "flows": dict(self.flows),
                "reroute_log": list(self.reroute_log),
                # The heap list verbatim: heapq's layout is part of the
                # deterministic tie-break story, so restore must not
                # re-heapify a differently-shaped but equivalent heap.
                "events": list(self._events),
                "seq": self._seq,
                "next_jid": self._next_jid,
                "auto_flow": self._auto_flow,
                "live_jobs": dict(self._live_jobs),
                "suspended": list(self._suspended),
                "expiry": list(self._expiry),
                "flow_gen": dict(self._flow_gen),
                "host_failures": dict(self._host_failures),
                "blacklist": sorted(self.blacklist),
                "poll_pending": self._poll_pending,
                "hb_pending": self._hb_pending,
                "ctrl_down": self.ctrl_down,
                "down_since": self._down_since,
                "mailbox": list(self._mailbox),
                "mailbox_jobs": self._mailbox_jobs,
                "shed_jobs": list(self.shed_jobs),
                "obs": self.obs.dump_values(),
                "telemetry": (None if self.telemetry is None
                              else self.telemetry.dump_state()),
                "heartbeats": hb,
            }
        from .journal import ControllerSnapshot

        self.obs.counter("recovery.snapshots").inc()
        lsn = 0 if self.journal is None else self.journal.lsn
        return ControllerSnapshot(lsn=lsn, payload=payload)

    def _restore_full(self, payload: dict) -> None:
        """Overwrite this (freshly-constructed) controller's mutable state
        with a snapshot payload.  The inverse of :meth:`snapshot`."""
        cfg = payload["config"]
        self.reroute_engine = cfg["reroute_engine"]
        self.mailbox_limit = cfg["mailbox_limit"]
        st = self.state
        ps = payload["state"]
        st.workers = list(ps["workers"])
        st.workers_set = frozenset(st.workers)
        st.idle = dict(ps["idle"])
        st.background = list(ps["background"])
        st.heap = MinnowHeap(st.idle, st.workers)
        st.now = ps["now"]
        # Drop any cached wavefront planner: it holds pre-restore ledger
        # state (placements are bit-identical either way; its hit/miss
        # counters are cache artifacts outside the equivalence canon).
        st.__dict__.pop("_wavefront", None)
        st.ledger.load_state(payload["ledger"])
        self.dataplane.load_liveness(payload["liveness"])
        self.dataplane.tables.load_state(payload["tables"])
        # Deep-copy again so one snapshot can seed several recoveries.
        self.jobs, self._specs = copy.deepcopy(
            (payload["jobs"], payload["specs"])
        )
        self.flows = dict(payload["flows"])
        self.reroute_log = list(payload["reroute_log"])
        self._events = list(payload["events"])
        self._n_real_events = sum(
            1 for ev in self._events if ev[2] not in ("poll", "hb")
        )
        self._seq = payload["seq"]
        self._next_jid = payload["next_jid"]
        self._auto_flow = payload["auto_flow"]
        self._idle0 = dict(ps["idle0"])
        self._live_jobs = dict(payload["live_jobs"])
        self._suspended = list(payload["suspended"])
        self._expiry = list(payload["expiry"])
        self._flow_gen = dict(payload["flow_gen"])
        self._host_failures = dict(payload["host_failures"])
        self.blacklist = set(payload["blacklist"])
        self._poll_pending = payload["poll_pending"]
        self._hb_pending = payload["hb_pending"]
        self.ctrl_down = payload["ctrl_down"]
        self._down_since = payload["down_since"]
        self._mailbox = list(payload["mailbox"])
        self._mailbox_jobs = payload["mailbox_jobs"]
        self.shed_jobs = list(payload["shed_jobs"])
        self.now = payload["now"]
        # Counters before the telemetry monitor: its stats group must find
        # the restored cells when it re-registers by prefix.
        self.obs.load_values(payload["obs"])
        if payload["telemetry"] is not None:
            from ..net.telemetry import LinkStatsMonitor

            mon = LinkStatsMonitor.load_state(
                st.ledger, payload["telemetry"], obs=self.obs
            )
            self.telemetry = mon
            st.belief = mon.belief
            self.obs.register_provider("telemetry", mon.snapshot)
        hb = payload["heartbeats"]
        if hb is not None:
            from ..runtime.ft import HeartbeatMonitor, HostState

            mon = HeartbeatMonitor(
                [], grace_s=hb["grace_s"], clock=lambda: self.now
            )
            mon.hosts = {
                name: HostState(name, last_beat, alive)
                for name, last_beat, alive in hb["hosts"]
            }
            self.heartbeats = mon
            self._hb_interval = hb["interval"]
            self._hb_last = hb["last"]

    @classmethod
    def recover_from(
        cls, fabric: Fabric, snapshot, journal=None, policy=None
    ) -> "ClusterController":
        """Rebuild a controller from a :meth:`snapshot` and replay the
        journaled suffix ``journal.since(snapshot.lsn)`` through the normal
        entry points — byte-identical (schedule dumps, reroute logs,
        behavioral obs counters, ledger bytes) to a controller that never
        crashed.  ``policy=`` overrides reconstruction for custom policy
        objects the snapshot cannot describe."""
        payload = snapshot.payload
        cfg = payload["config"]
        if policy is None:
            name, kwargs = cfg["policy"]
            if kwargs is None:
                raise ValueError(
                    f"snapshot carries custom policy {name!r}; pass policy="
                )
            policy = POLICIES[name](**kwargs)
        ledger_state = payload["ledger"]
        ctrl = cls(
            fabric,
            payload["state"]["workers"],
            policy,
            slot_duration=cfg["slot_duration"],
            horizon_slots=max(1, ledger_state["reserved"].shape[1]),
            k_paths=cfg["k_paths"],
            retry=RetryPolicy(*cfg["retry"]),
            speculation=cfg["speculation"],
            mailbox_limit=cfg["mailbox_limit"],
        )
        with ctrl.obs.span("recovery.restore"):
            ctrl._restore_full(payload)
        ctrl.obs.counter("recovery.recoveries").inc()
        if journal is not None:
            with ctrl.obs.span("recovery.replay"):
                n = ctrl.replay_journal(journal, from_lsn=snapshot.lsn)
            ctrl.obs.counter("recovery.replayed").inc(n)
            # Re-attach *after* replay so the replayed suffix is not
            # double-journaled.
            ctrl.journal = journal
        return ctrl

    # -- telemetry ------------------------------------------------------------
    def attach_telemetry(
        self,
        poll_interval: Optional[float] = None,
        estimator: "str | object" = "ewma",
        **est_kwargs,
    ):
        """Attach a :class:`~repro.net.telemetry.LinkStatsMonitor` driven by
        this event loop: the monitor polls the ledger's per-link counters
        every ``poll_interval`` sim-seconds (default: one slot) while work
        is queued, and keeps ``state.belief`` fresh for policies running
        with ``telemetry=True``.  Attaching a monitor alone never changes
        schedules — oracle policies don't read the belief.  Returns the
        monitor."""
        if self.telemetry is not None:
            raise RuntimeError("telemetry monitor already attached")
        if (self.journal is not None and not self._replaying
                and not self._in_run and not isinstance(estimator, str)):
            raise ValueError(
                "a journaled controller needs a named estimator (str) — "
                "estimator objects are not replayable"
            )
        self._journal("attach_telemetry", poll_interval, estimator,
                      dict(est_kwargs))
        from ..net.telemetry import LinkStatsMonitor

        mon = LinkStatsMonitor(
            self.state.ledger,
            poll_interval=poll_interval,
            estimator=estimator,
            obs=self.obs,
            **est_kwargs,
        )
        self.telemetry = mon
        self.state.belief = mon.belief
        self.obs.register_provider("telemetry", mon.snapshot)
        mon.poll(self.now)
        if self._n_real_events:
            self._arm_poll()
        return mon

    def _arm_poll(self) -> None:
        """Schedule the next counter poll.  The chain only lives while
        other events are queued — ``run()`` drains the queue completely,
        so an unconditional self-rescheduling poll would never let it
        terminate; instead the chain dies with the last real event and is
        re-armed by the next ``_push``."""
        at = max(self.now, self.telemetry.last_poll + self.telemetry.poll_interval)
        self._poll_pending = True
        heapq.heappush(self._events, (at, self._seq, "poll", ()))
        self._seq += 1

    # -- heartbeats ---------------------------------------------------------
    def attach_heartbeats(
        self, interval: Optional[float] = None, grace_s: Optional[float] = None
    ):
        """Attach a :class:`~repro.runtime.ft.HeartbeatMonitor` over this
        controller's workers, driven by the event loop in *sim time* (the
        same poll-chain pattern as ``attach_telemetry`` — never
        ``time.monotonic``, so runs stay deterministic).  Every ``interval``
        sim-seconds (default: one ledger slot) the monitor sweeps; hosts
        whose last beat is older than ``grace_s`` (default: 3 intervals)
        emit ``fail_host``.  Call ``monitor.beat(host, now)`` from the
        workload to keep hosts alive; a recovered host needs an explicit
        ``recover_host`` (plus a beat) to rejoin.  Returns the monitor."""
        if self.heartbeats is not None:
            raise RuntimeError("heartbeat monitor already attached")
        from ..runtime.ft import HeartbeatMonitor

        interval = (self.state.ledger.slot_duration if interval is None
                    else float(interval))
        if interval <= 0.0:
            raise ValueError(f"heartbeat interval must be > 0, got {interval}")
        grace_s = 3.0 * interval if grace_s is None else float(grace_s)
        self._journal("attach_heartbeats", interval, grace_s)
        mon = HeartbeatMonitor(
            list(self.state.workers),
            grace_s=grace_s,
            clock=lambda: self.now,
        )
        self.heartbeats = mon
        self._hb_interval = interval
        self._hb_last = self.now
        if self._n_real_events:
            self._arm_hb()
        return mon

    def _arm_hb(self) -> None:
        """Schedule the next heartbeat sweep — like ``_arm_poll``, the
        chain lives only while real events are queued, else ``run()``
        would never terminate."""
        at = max(self.now, self._hb_last + self._hb_interval)
        self._hb_pending = True
        heapq.heappush(self._events, (at, self._seq, "hb", ()))
        self._seq += 1

    def _hb_sweep(self, at: float) -> None:
        """Missed beats become host failures, inline at the sweep time."""
        self._hb_last = at
        for host in self.heartbeats.sweep(at):
            if (host in self.state.workers_set
                    and host not in self.dataplane.dead_hosts):
                self._on_host_down(host, at)

    # -- event submission ---------------------------------------------------
    def _push(self, at: float, kind: str, payload: tuple) -> None:
        if at < self.now - _EPS:
            raise ValueError(f"event at {at} is in the controller's past {self.now}")
        heapq.heappush(self._events, (at, self._seq, kind, payload))
        self._seq += 1
        self._n_real_events += 1
        # A down controller neither polls nor sweeps — chains stay dead
        # until _on_ctrl_up re-arms them.
        if self.ctrl_down:
            return
        if self.telemetry is not None and not self._poll_pending:
            self._arm_poll()
        if self.heartbeats is not None and not self._hb_pending:
            self._arm_hb()

    def submit(
        self,
        tasks: Sequence[Task],
        at: float = 0.0,
        jid: Optional[int] = None,
    ) -> int:
        """Queue a job (its full task list) to arrive at time ``at``."""
        if jid is None:
            jid = self._next_jid
        if jid in self.jobs:
            raise ValueError(f"duplicate job id {jid}")
        # Journal with the *resolved* jid so a replayed auto-assignment
        # lands on the same id regardless of the restored counter.
        self._journal("submit", float(at), int(jid), tuple(tasks))
        self._next_jid = max(self._next_jid, jid + 1)
        self.jobs[jid] = JobRecord(jid, at, list(tasks))
        self._push(at, "job", (jid,))
        return jid

    def inject_flow(
        self, flow: BackgroundFlow, at: Optional[float] = None
    ) -> None:
        """Queue dynamic background cross-traffic (defaults to its start)."""
        at = flow.start if at is None else at
        self._journal("inject_flow", flow, float(at))
        self._push(at, "flow", (flow,))

    def reserve_transfer_at(
        self,
        at: float,
        size: float,
        links: Sequence[str],
        tag: object = None,
    ) -> None:
        """Queue a raw flow reservation on explicit links at time ``at`` —
        the training-side gradient-sync entry (``distributed.dcn``)."""
        self._journal("reserve_transfer", float(at), float(size),
                      tuple(links), tag)
        self._push(at, "transfer", (size, tuple(links), tag))

    # -- network churn ------------------------------------------------------
    def fail_link(self, name: str, at: Optional[float] = None) -> None:
        """Queue a link failure: in-flight transfers on it reroute when it
        fires (UnroutableError if a victim has no surviving path)."""
        self.state.fabric.link(name)  # validate early: KeyError on unknown
        at = self.now if at is None else at
        self._journal("fail_link", name, float(at))
        self._push(at, "link_down", (name,))

    def recover_link(self, name: str, at: Optional[float] = None) -> None:
        # Validate like fail_link: a typo'd recovery would otherwise be a
        # silent no-op that stalls suspended flows forever.
        self.state.fabric.link(name)
        at = self.now if at is None else at
        self._journal("recover_link", name, float(at))
        self._push(at, "link_up", (name,))

    def fail_switch(self, node: str, at: Optional[float] = None) -> None:
        """Queue a switch failure — every incident link goes down."""
        if not self.state.fabric.has_node(node):
            raise ValueError(f"unknown node {node!r}")
        at = self.now if at is None else at
        self._journal("fail_switch", node, float(at))
        self._push(at, "switch_down", (node,))

    def recover_switch(self, node: str, at: Optional[float] = None) -> None:
        if not self.state.fabric.has_node(node):
            raise ValueError(f"unknown node {node!r}")
        at = self.now if at is None else at
        self._journal("recover_switch", node, float(at))
        self._push(at, "switch_up", (node,))

    def fail_host(self, node: str, at: Optional[float] = None) -> None:
        """Queue a host crash: when it fires, the worker leaves every
        placement surface, its queued/running tasks are killed (transfer
        tails released), and the kills are re-placed through the normal
        policy path under :class:`RetryPolicy`."""
        if not self.state.fabric.has_node(node):
            raise ValueError(f"unknown node {node!r}")
        at = self.now if at is None else at
        self._journal("fail_host", node, float(at))
        self._push(at, "host_down", (node,))

    def recover_host(self, node: str, at: Optional[float] = None) -> None:
        """Queue a host recovery — re-admitted empty unless blacklisted."""
        if not self.state.fabric.has_node(node):
            raise ValueError(f"unknown node {node!r}")
        at = self.now if at is None else at
        self._journal("recover_host", node, float(at))
        self._push(at, "host_up", (node,))

    def straggle(self, node: str, factor: float, at: Optional[float] = None) -> None:
        """Queue a straggler onset: the task running on ``node`` when the
        event fires has its *remaining* compute inflated by ``factor``
        (the progress-rate model).  With ``speculation=True`` the LATE
        rule may launch a backup copy against ledger residuals."""
        if factor < 1.0:
            raise ValueError(f"straggle factor must be >= 1, got {factor}")
        at = self.now if at is None else at
        self._journal("straggle", node, float(factor), float(at))
        self._push(at, "straggle", (node, factor))

    # -- control-plane lifecycle (headless data-plane mode) -----------------
    def fail_controller(self, at: Optional[float] = None) -> None:
        """Queue a control-plane crash: when it fires, the data plane keeps
        forwarding on installed rules (in-flight transfers complete) but
        scheduling stops — new jobs queue in the bounded mailbox (overflow
        → load-shed), all other events are deferred, and the poll/heartbeat
        chains are suspended until :meth:`recover_controller`."""
        at = self.now if at is None else at
        self._journal("fail_controller", float(at))
        self._push(at, "ctrl_down", ())

    def recover_controller(self, at: Optional[float] = None) -> None:
        """Queue a control-plane recovery: reconcile lapsed rule expiries,
        forgive the heartbeat gap, drain the mailbox in arrival order and
        re-arm the polling chains."""
        at = self.now if at is None else at
        self._journal("recover_controller", float(at))
        self._push(at, "ctrl_up", ())

    def inject_net(self, event) -> None:
        """Queue a ``repro.net.events`` NetworkEvent at its own ``at``."""
        from ..net.events import (
            ControllerDown,
            ControllerUp,
            HostDown,
            HostUp,
            LinkDown,
            LinkUp,
            SwitchDown,
            SwitchUp,
        )

        if isinstance(event, ControllerDown):
            self.fail_controller(at=event.at)
            return
        if isinstance(event, ControllerUp):
            self.recover_controller(at=event.at)
            return
        if isinstance(event, LinkDown):
            self.fail_link(event.link, at=event.at)
        elif isinstance(event, LinkUp):
            self.recover_link(event.link, at=event.at)
        elif isinstance(event, SwitchDown):
            self.fail_switch(event.node, at=event.at)
        elif isinstance(event, SwitchUp):
            self.recover_switch(event.node, at=event.at)
        elif isinstance(event, HostDown):
            self.fail_host(event.node, at=event.at)
        elif isinstance(event, HostUp):
            self.recover_host(event.node, at=event.at)
        else:
            raise TypeError(f"not a network event: {event!r}")

    # -- the loop -----------------------------------------------------------
    def run_until(self, t: float) -> None:
        """Process every queued event with fire time ≤ ``t``, in time order
        (ties: submission order)."""
        self._journal("run_until", float(t))
        while self._events and self._events[0][0] <= t + _EPS:
            at, _seq, kind, payload = heapq.heappop(self._events)
            if kind not in ("poll", "hb"):
                self._n_real_events -= 1
            self.now = max(self.now, at)
            self.state.advance(max(self.state.now, at))
            if not self.ctrl_down:
                # Headless: rule expiry is a *control-plane* action — the
                # data plane keeps forwarding on whatever is installed
                # until recovery reconciles the lapsed entries.
                self._gc_tables(at)
            self._ev_stats["events"] += 1
            if self.ctrl_down and kind != "ctrl_up":
                self._headless_event(at, kind, payload)
                continue
            self._dispatch(at, kind, payload)
        self.now = max(self.now, t)
        if not self.ctrl_down:
            self._gc_tables(self.now)
        # Rolling horizon: a quiet controller (no events near ``t``) still
        # retires up to its target time — any later event may fire no
        # earlier than ``now - _EPS``, which maybe_retire's guard slot
        # covers (DESIGN.md §7).
        self.state.ledger.maybe_retire(self.now)

    def run(self) -> None:
        """Drain the event queue completely."""
        self._journal("run")
        was_in_run, self._in_run = self._in_run, True
        try:
            while self._events:
                self.run_until(self._events[0][0])
        finally:
            self._in_run = was_in_run

    def _dispatch(self, at: float, kind: str, payload: tuple) -> None:
        """Apply one popped (or mailbox-drained) event at time ``at``."""
        if kind == "job":
            (jid,) = payload
            self._ev_stats["jobs"] += 1
            with self.obs.span("controller.drain"):
                self._drain(self.jobs[jid])
        elif kind == "poll":
            self._poll_pending = False
            if self.telemetry is not None:
                self._ev_stats["polls"] += 1
                self.telemetry.poll(at)
                if self._n_real_events:
                    self._arm_poll()
        elif kind == "flow":
            (flow,) = payload
            self._ev_stats["flows"] += 1
            self.state.observe_flow(flow)
        elif kind == "transfer":
            size, links, tag = payload
            self._ev_stats["transfers"] += 1
            if tag is None:
                tag = ("flow", self._auto_flow)
                self._auto_flow += 1
            dead = self.dataplane.all_dead_links()
            if any(l in dead for l in links):
                # Requested links are down: suspend until recovery.
                self._suspended.append((tag, links, size))
            else:
                rows = self.state.ledger.rows(links)
                plan = self.state.ledger.plan_transfer(
                    size, rows, not_before=at
                )
                self.state.ledger.commit(plan)
                self.flows[tag] = plan
        elif kind == "link_down":
            (name,) = payload
            self._ev_stats["net_events"] += 1
            self.dataplane.fail_link(name)
            self._reroute_dead(at)
        elif kind == "link_up":
            (name,) = payload
            self._ev_stats["net_events"] += 1
            self.dataplane.recover_link(name)
            self._resume_flows(at)
        elif kind == "switch_down":
            (node,) = payload
            self._ev_stats["net_events"] += 1
            self.dataplane.fail_switch(node)
            self._reroute_dead(at)
        elif kind == "switch_up":
            (node,) = payload
            self._ev_stats["net_events"] += 1
            self.dataplane.recover_switch(node)
            self._resume_flows(at)
        elif kind == "host_down":
            (node,) = payload
            self._ev_stats["net_events"] += 1
            self._on_host_down(node, at)
        elif kind == "host_up":
            (node,) = payload
            self._ev_stats["net_events"] += 1
            self._on_host_up(node, at)
        elif kind == "straggle":
            node, factor = payload
            self._on_straggle(node, factor, at)
        elif kind == "task_retry":
            jid, tid, attempt = payload
            self._retry_task(jid, tid, attempt, at)
        elif kind == "spec_resolve":
            (tid,) = payload
            self._resolve_spec(tid, at)
        elif kind == "hb":
            self._hb_pending = False
            if self.heartbeats is not None:
                # A sweep can _push retries, which re-arms the chain —
                # don't arm twice.
                self._hb_sweep(at)
                if self._n_real_events and not self._hb_pending:
                    self._arm_hb()
        elif kind == "ctrl_down":
            self._on_ctrl_down(at)
        elif kind == "ctrl_up":
            self._on_ctrl_up(at)

    # -- headless data-plane mode (DESIGN.md §11) ---------------------------
    def _headless_event(self, at: float, kind: str, payload: tuple) -> None:
        """One event firing while the control plane is down.

        The data plane needs no controller to finish what was installed —
        transfers already booked on the ledger complete on their reserved
        slots and their rules stay up until recovery reconciles expiries.
        Everything needing a *decision* waits: job arrivals enter the
        bounded mailbox (overflow → load-shed, surfaced as a ``degraded``
        reject by ``serving.router``), and every other event (flows, raw
        transfers, net churn, retries, speculation resolves) is deferred
        in arrival order.  Deferred net events apply their liveness change
        at drain time — a path that died headless reroutes at recovery,
        with the outage bytes counted delivered (the documented
        approximation: in-flight completion is only guaranteed on paths
        that stay alive).  Poll/heartbeat chain events are dropped with
        their pending flags cleared — the chains die (a dead controller
        neither polls counters nor hears beats) and recovery re-arms them.
        """
        if kind == "ctrl_down":
            return  # duplicate crash while already down
        if kind in ("poll", "hb"):
            if kind == "poll":
                self._poll_pending = False
            else:
                self._hb_pending = False
            return
        if kind == "job":
            (jid,) = payload
            if self._mailbox_jobs >= self.mailbox_limit:
                self.jobs[jid].shed = True
                self.shed_jobs.append(jid)
                self.ha_stats["mailbox_shed"] += 1
                return
            self._mailbox_jobs += 1
            self.ha_stats["mailbox_queued"] += 1
        else:
            self.ha_stats["deferred"] += 1
        self._mailbox.append((kind, payload))

    def _on_ctrl_down(self, at: float) -> None:
        if self.ctrl_down:
            return  # duplicate crash event
        self.ctrl_down = True
        self._down_since = at
        self.ha_stats["ctrl_down"] += 1
        rec_t = self.obs.trace
        if rec_t.enabled:
            rec_t.record("ctrl_down", at=at)

    def _on_ctrl_up(self, at: float) -> None:
        if not self.ctrl_down:
            return  # never crashed (or duplicate recovery)
        self.ctrl_down = False
        outage = at - self._down_since
        self.ha_stats["ctrl_up"] += 1
        # A dead controller heard no beats: forgive the gap so the first
        # post-recovery sweep doesn't mass-declare healthy hosts dead.
        if self.heartbeats is not None:
            self.heartbeats.suspend_accrual(outage, now=at)
        # Reconcile rule expiries that lapsed during the outage.
        n0 = self.dataplane.tables.n_rules()
        self._gc_tables(at)
        self.ha_stats["reconciled_rules"] += (
            n0 - self.dataplane.tables.n_rules()
        )
        # Drain the mailbox in arrival order, all at recovery time.
        backlog, self._mailbox, self._mailbox_jobs = self._mailbox, [], 0
        for kind, payload in backlog:
            self._dispatch(at, kind, payload)
        # Re-arm the suspended chains.
        if (self.telemetry is not None and self._n_real_events
                and not self._poll_pending):
            self._arm_poll()
        if (self.heartbeats is not None and self._n_real_events
                and not self._hb_pending):
            self._arm_hb()
        rec_t = self.obs.trace
        if rec_t.enabled:
            rec_t.record("ctrl_up", at=at, outage=outage,
                         drained=len(backlog))

    def _drain(self, rec: "JobRecord") -> None:
        """Place one arrived job's task list and install its flow rules.

        ``policy.place_batch`` routes through the wavefront engine
        (``core.wavefront``) healthy or degraded — a fleet-scale arrival
        is planned in broadcast waves rather than per-task ledger
        re-scans, with dead links priced out of candidate enumeration —
        byte-identical either way."""
        rec.assignments = self.policy.place_batch(rec.tasks, self.state)
        rec.placed = True
        for a in rec.assignments:
            if a.transfer is not None and a.transfer.slot_fracs:
                self._install(("job", rec.jid, a.tid), a.source, a.node,
                              a.transfer)
                self._live_jobs[rec.jid] = max(
                    self._live_jobs.get(rec.jid, 0.0), a.transfer.end
                )

    # -- data-plane bookkeeping ---------------------------------------------
    def _install(self, cookie, src: Optional[str], dst: str,
                 plan: TransferPlan) -> None:
        """Push the transfer's per-switch rules; schedule their expiry."""
        if src is None:
            return
        links = self.state.ledger.link_names(plan.links)
        self.dataplane.tables.install_path(cookie, src, dst, links)
        gen = self._flow_gen.get(cookie, 0) + 1
        self._flow_gen[cookie] = gen
        heapq.heappush(self._expiry, (plan.end, gen, cookie))

    def _gc_tables(self, now: float) -> None:
        """Uninstall rules of transfers that have completed by ``now``.

        Generation guard: a reroute reinstalls under the same cookie with a
        later end — the stale expiry entry must not strip the new rules.
        """
        while self._expiry and self._expiry[0][0] <= now + _EPS:
            _end, gen, cookie = heapq.heappop(self._expiry)
            if self._flow_gen.get(cookie) == gen:
                self.dataplane.tables.uninstall(cookie)
                del self._flow_gen[cookie]

    # -- failure-aware rerouting --------------------------------------------
    def _reroute_dead(self, at: float) -> None:
        """Re-plan every in-flight transfer whose path just died.

        Semantics (DESIGN.md §4): slots consumed before the failure slot
        stay booked (those bytes arrived); the failure slot and everything
        after are released, and the remaining bytes are re-planned on the
        best surviving (replica, path) candidate starting at ``at``.
        Raises :class:`UnroutableError` when a victim has no surviving
        path — there are no silent stalls.

        The batched engine (``core.reroute``, DESIGN.md §6) replans the
        whole storm in fused array passes, byte-identical to the
        sequential per-victim loop, which survives as the reference
        oracle (``reroute_engine = "sequential"``).
        """
        from .reroute import RerouteEngine, sequential_reroute

        n0 = len(self.reroute_log)
        with self.obs.span("controller.reroute"):
            if self.reroute_engine == "sequential":
                sequential_reroute(self, at)
            else:
                RerouteEngine(self).run(at)
        rec = self.obs.trace
        if rec.enabled:
            rec.record("reroute", at=at, victims=len(self.reroute_log) - n0)
        self._compact_expiry()

    def _compact_expiry(self) -> None:
        """Drop stale flow-rule expiry entries (lazy-deletion compaction).

        A reroute reinstalls rules under the same cookie with a newer
        generation; the superseded heap entry only disappears once its
        old end time passes.  Across a long failure storm of mass
        reinstalls the heap would otherwise accumulate one stale entry
        per reroute — compact whenever stale entries outnumber live
        cookies."""
        if len(self._expiry) > 64 and len(self._expiry) > 2 * len(self._flow_gen):
            self._expiry = [
                e for e in self._expiry if self._flow_gen.get(e[2]) == e[1]
            ]
            heapq.heapify(self._expiry)

    def _resume_flows(self, at: float) -> None:
        """Re-plan suspended raw flows whose links are all alive again."""
        dead = self.dataplane.all_dead_links()
        still = []
        for tag, links, remaining in self._suspended:
            if any(l in dead for l in links):
                still.append((tag, links, remaining))
                continue
            rows = self.state.ledger.rows(links)
            plan = self.state.ledger.plan_transfer(
                remaining, rows, not_before=at
            )
            self.state.ledger.commit(plan)
            self.flows[tag] = plan
        self._suspended = still

    # -- host lifecycle + task re-execution (DESIGN.md §10) -----------------
    def _kill_assignment(self, rec: "JobRecord", a: Assignment, at: float,
                         cookie=None) -> float:
        """Tear one unfinished assignment down: release the transfer's
        unconsumed tail (PR 4 ``release_after`` — the boundary slot is
        forfeited whole), account the delivered-but-unusable bytes as
        waste, drop its flow rule, and remove it from the job record.
        Returns the wasted byte count."""
        ledger = self.state.ledger
        wasted = 0.0
        if a.transfer is not None and a.transfer.slot_fracs:
            kept = ledger.release_after(a.transfer, at)
            a.transfer = kept
            wasted = ledger.plan_bytes(kept)
            if cookie is None:
                cookie = ("job", rec.jid, a.tid)
            if cookie in self._flow_gen:
                self.dataplane.tables.uninstall(cookie)
                del self._flow_gen[cookie]
        rec.wasted_bytes += wasted
        self.fault_stats["wasted_bytes"] += wasted
        rec.assignments.remove(a)
        return wasted

    def _on_host_down(self, node: str, at: float) -> None:
        """Host crash: leave every placement surface, kill the machine's
        unfinished work, then reroute in-flight transfers it was sourcing.

        Ordering matters: kills run *before* ``_reroute_dead`` so the
        victim sweep never tries to replan a transfer toward a dead
        destination (which has no surviving path by definition); the
        sweep then only sees transfers *from* the dead host's replicas
        toward live nodes, which reroute to surviving replicas."""
        if node in self.dataplane.dead_hosts:
            return  # duplicate crash event
        self.fault_stats["host_down"] += 1
        n_fail = self._host_failures.get(node, 0) + 1
        self._host_failures[node] = n_fail
        if n_fail >= self.retry.blacklist_after and node not in self.blacklist:
            self.blacklist.add(node)
            self.fault_stats["blacklisted"] += 1
        self.dataplane.fail_host(node)
        self.state.remove_worker(node)
        retries: List[Tuple[int, int]] = []
        for jid in sorted(self.jobs):
            rec = self.jobs[jid]
            if not rec.placed:
                continue
            for a in [x for x in rec.assignments
                      if x.node == node and x.finish > at + _EPS]:
                self.fault_stats["killed"] += 1
                spec = self._specs.get(a.tid)
                if spec is not None and (spec.primary is a or spec.backup is a):
                    # Its speculation partner survives: resolve by forfeit
                    # instead of re-executing.
                    self._kill_assignment(
                        rec, a, at,
                        cookie=("spec", jid, a.tid) if spec.backup is a
                        else None,
                    )
                    del self._specs[a.tid]
                    if spec.backup is not a:
                        self.fault_stats["spec_win"] += 1
                    elif self.speculation:
                        # The backup died with the host but the straggler
                        # is still slow — relaunch against the post-crash
                        # ledger (LATE keeps one live backup per task).
                        self._maybe_speculate(rec, spec.primary, at)
                    continue
                self._kill_assignment(rec, a, at)
                retries.append((jid, a.tid))
        self._reroute_dead(at)
        if self.retry.max_attempts > 0:
            for jid, tid in retries:
                self._push(at + self.retry.backoff(0), "task_retry",
                           (jid, tid, 0))
        rec_t = self.obs.trace
        if rec_t.enabled:
            rec_t.record("host_down", node=node, at=at, killed=len(retries))

    def _on_host_up(self, node: str, at: float) -> None:
        """Host recovery: re-admit the worker empty (idle = now) unless it
        crashed its way onto the blacklist — then it stays priced out."""
        if node not in self.dataplane.dead_hosts:
            return  # never failed (or duplicate recovery)
        if node in self.blacklist:
            return  # administratively down
        self.fault_stats["host_up"] += 1
        self.dataplane.recover_host(node)
        self.state.add_worker(node, at)
        self._resume_flows(at)

    def _retry_task(self, jid: int, tid: int, attempt: int, at: float) -> None:
        """Re-place one killed task through the normal (bandwidth-aware)
        policy path; a transient all-replicas-dead window burns an attempt
        and backs off, exhaustion raises — no silent stalls."""
        rec = self.jobs.get(jid)
        if rec is None:
            return
        task = next(t for t in rec.tasks if t.tid == tid)
        self.fault_stats["retries"] += 1
        try:
            a = self.policy.place(task, self.state)
        except UnroutableError:
            nxt = attempt + 1
            if nxt >= self.retry.max_attempts:
                raise UnroutableError(
                    f"task {tid}: no live replica after {nxt} attempts"
                )
            self._push(at + self.retry.backoff(nxt), "task_retry",
                       (jid, tid, nxt))
            return
        rec.assignments.append(a)
        rec.reexecuted += 1
        self.fault_stats["reexecuted"] += 1
        if a.transfer is not None and a.transfer.slot_fracs:
            self._install(("job", jid, tid), a.source, a.node, a.transfer)
            self._live_jobs[jid] = max(
                self._live_jobs.get(jid, 0.0), a.transfer.end
            )

    # -- stragglers + LATE speculation --------------------------------------
    def _on_straggle(self, node: str, factor: float, at: float) -> None:
        """Progress-rate drop: the task running on ``node`` now needs
        ``factor``× its remaining compute.  Node exclusivity means at most
        one assignment is running; queued tasks are not stragglers yet."""
        victim = vrec = None
        for rec in self.jobs.values():
            for a in rec.assignments:
                if a.node != node or a.finish <= at + _EPS:
                    continue
                # Running task wins; otherwise the node's next queued task
                # (the slowdown is a property of the machine at ``at``).
                key = (a.start > at + _EPS, a.start, a.tid)
                if victim is None or key < (victim.start > at + _EPS,
                                            victim.start, victim.tid):
                    victim, vrec = a, rec
        if victim is None:
            return
        # Remaining (running) or whole (queued) compute inflates.
        t0 = max(at, victim.start)
        victim.finish = t0 + (victim.finish - t0) * factor
        self._retime_nodes({node})
        if self.speculation and victim.tid not in self._specs:
            self._maybe_speculate(vrec, victim, at)

    def _maybe_speculate(self, rec: "JobRecord", a: Assignment,
                         at: float) -> None:
        """The LATE rule, priced by the ledger: launch a backup copy on
        the least-loaded other worker iff the ledger's *residual* slots
        say the backup (data movement included) finishes before the
        straggler's projected finish.  A progress-rate-only rule would
        launch backups whose transfers crawl through congested links and
        finish after the straggler anyway — pure waste."""
        task = next(t for t in rec.tasks if t.tid == a.tid)
        state = self.state
        cands = [n for n in state.workers if n != a.node]
        if not cands:
            return
        bnode = min(cands, key=lambda n: (state.idle[n], n))
        plan = src = None
        if bnode in task.replicas:
            backup_finish = state.idle[bnode] + task.compute
        else:
            try:
                src, _rows, plan = state.choose_source_path(
                    task, bnode, at=state.idle[bnode]
                )
            except UnroutableError:
                return
            start = plan.end if plan.slot_fracs else state.idle[bnode]
            backup_finish = start + task.compute
        if backup_finish >= a.finish - _EPS:
            return  # residuals say the backup loses: don't burn bandwidth
        if plan is None:
            b = state.commit_local(task, bnode)
        else:
            b = state.commit_remote(task, bnode, src, plan)
            self._install(("spec", rec.jid, a.tid), src, bnode, plan)
            self._live_jobs[rec.jid] = max(
                self._live_jobs.get(rec.jid, 0.0), plan.end
            )
        rec.assignments.append(b)
        rec.speculative += 1
        self.fault_stats["spec_launch"] += 1
        self._specs[a.tid] = _SpecRecord(rec.jid, a, b)
        self._push(min(a.finish, b.finish), "spec_resolve", (a.tid,))

    def _resolve_spec(self, tid: int, at: float) -> None:
        """First finisher wins; the loser's remaining slots are released
        and its delivered bytes counted as waste.  Retimes may have pushed
        both copies past the scheduled resolve time — re-arm at the new
        earliest finish instead of guessing."""
        spec = self._specs.get(tid)
        if spec is None:
            return  # resolved by forfeit (host crash) meanwhile
        p, b = spec.primary, spec.backup
        done = min(p.finish, b.finish)
        if done > at + _EPS:
            self._push(done, "spec_resolve", (tid,))
            return
        winner, loser = (p, b) if p.finish <= b.finish + _EPS else (b, p)
        del self._specs[tid]
        rec = self.jobs[spec.jid]
        self._kill_assignment(
            rec, loser, at,
            cookie=("spec", spec.jid, tid) if loser is b else None,
        )
        if winner is b:
            self.fault_stats["spec_win"] += 1
        # The loser's node genuinely lost a queue entry: let its remaining
        # tasks rewind to their natural no-idle starts (same contract as a
        # reroute's retime) — otherwise the win never reaches tasks queued
        # behind the dead straggler and speculation can't move makespan.
        rewind = {a2.tid for r2 in self.jobs.values()
                  for a2 in r2.assignments if a2.node == loser.node}
        self._retime_nodes({loser.node}, rewind)
        rec_t = self.obs.trace
        if rec_t.enabled:
            rec_t.record("spec_resolve", tid=tid, at=at,
                         winner=winner.node, loser=loser.node)

    def _retime_nodes(self, nodes, rerouted_tids=frozenset()) -> None:
        """Recompute the compute timeline of every touched node.

        Mirrors the replay oracle: tasks keep their committed order (old
        start, tid), each starts at max(previous finish, its transfer's
        end, its job's arrival), never before the node's initial idle
        time.  Tasks whose transfer was *not* rerouted additionally never
        move earlier than their committed start — external idle estimates
        (``set_idle`` backlog refreshes) are folded into committed starts
        and must not be rewound by a retime that only knows ``_idle0``.
        The shared idle map and minnow heap are resynced.

        One grouping pass over the assignment set feeds every node's
        replay (the per-node scan is a genuine recurrence and stays in
        python floats — the same doubles, in the same order); the
        historical per-node re-scan of all jobs made a storm's retime
        O(touched nodes × assignments).
        """
        by_node: Dict[str, List[Tuple[float, "Assignment"]]] = {
            n: [] for n in nodes
        }
        for rec in self.jobs.values():
            submit_at = rec.submit_at
            for a in rec.assignments:
                q = by_node.get(a.node)
                if q is not None:
                    q.append((submit_at, a))
        for node, items in by_node.items():
            items.sort(key=lambda sa: (sa[1].start, sa[1].tid))
            t = self._idle0.get(node, 0.0)
            for submit_at, a in items:
                ready = submit_at
                if a.transfer is not None and a.transfer.slot_fracs:
                    ready = max(ready, a.transfer.end)
                task_compute = a.finish - a.start  # TP is start-invariant
                start = max(t, ready)
                if a.tid not in rerouted_tids:
                    start = max(start, a.start)  # committed history holds
                a.start = start
                a.finish = start + task_compute
                t = a.finish
            self.state.idle[node] = max(t, self.state.now)
        self.state.reheap()

    # -- results ------------------------------------------------------------
    def job_schedule(self, jid: int) -> Schedule:
        rec = self.jobs[jid]
        return Schedule(
            list(rec.assignments),
            self.state.ledger,
            kinds={t.tid: t.kind for t in rec.tasks},
        )

    def schedule(self) -> Schedule:
        """All placed assignments across jobs, as one Schedule."""
        out = [a for rec in self.jobs.values() for a in rec.assignments]
        kinds = {
            t.tid: t.kind for rec in self.jobs.values() for t in rec.tasks
        }
        out.sort(key=lambda a: a.tid)
        return Schedule(out, self.state.ledger, kinds=kinds)

    # -- observability providers (lazily evaluated at snapshot time) --------
    def _ledger_obs(self) -> dict:
        led = self.state.ledger
        return {
            "batch_scan_cells": led.batch_scan_cells,
            "base_slot": led.base_slot,
            "retired_slots": led.retired_slots,
            "live_slots": int(led.reserved.shape[1]),
            "links": int(led.reserved.shape[0]),
            "utilization": led.utilization(),
        }

    def _jobs_obs(self) -> dict:
        return {
            str(jid): self.job_metrics(jid).to_dict()
            for jid, rec in self.jobs.items()
            if rec.placed
        }

    def job_metrics(self, jid: int):
        """Per-job Table-I row relative to the job's arrival: MT/RT/JT/LR."""
        from .simulator import JobMetrics

        rec = self.jobs[jid]
        if not rec.placed:
            raise ValueError(f"job {jid} not placed yet (run_until?)")
        kinds = {t.tid: t.kind for t in rec.tasks}
        jt = rec.makespan - rec.submit_at
        maps = [
            a.finish for a in rec.assignments if kinds.get(a.tid, "map") == "map"
        ]
        mt = (max(maps) - rec.submit_at) if maps else jt
        n = len(rec.assignments)
        lr = sum(1 for a in rec.assignments if a.local) / n if n else 0.0
        return JobMetrics(mt=mt, rt=jt - mt, jt=jt, lr=lr,
                          rerouted=rec.rerouted,
                          reexecuted=rec.reexecuted,
                          speculative=rec.speculative,
                          wasted_bytes=rec.wasted_bytes)
