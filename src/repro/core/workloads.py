"""Table-I-style workload generators (Wordcount / Sort, §V).

The paper's testbed: 6 nodes in 5 physical systems behind 2 OVS switches,
replicas = 3, 64 MB blocks, 100 Mbps links, a repetitively-executed
background job supplying each test's initial workload; data sizes 150 MB,
300 MB, 600 MB, 1 GB, 5 GB; Wordcount is CPU-heavy, Sort is shuffle/IO-heavy.

We regenerate instances with the same shape.  Absolute seconds cannot match
a 2013 physical testbed; the *reproducible claims* are (a) BASS ≤ BAR ≤ HDS
job completion on every row and (b) BASS may win with a lower locality ratio
(§V.B's argument).  ``benchmarks/bench_table1.py`` prints our table next to
the paper's for comparison.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .tasks import BackgroundFlow, Instance, Task
from .topology import Fabric, two_tier_fabric

MB = 8.0                     # Mbit per MB
BLOCK_MB = 64.0              # HDFS block size (§V.A)
LINK_MBPS = 100.0            # max link rate (§V.A)
DATA_SIZES_MB = {"150M": 150, "300M": 300, "600M": 600, "1G": 1024, "5G": 5120}


@dataclass(frozen=True)
class JobSpec:
    """Calibration of a job type (per 64 MB block / per reduce wave)."""

    name: str
    map_cpu: float            # TP per map task, seconds
    reduce_cpu: float         # TP per reduce task, seconds
    shuffle_frac: float       # shuffle bytes as a fraction of input
    n_reducers: int


WORDCOUNT = JobSpec("wordcount", map_cpu=22.0, reduce_cpu=16.0, shuffle_frac=0.08, n_reducers=2)
SORT = JobSpec("sort", map_cpu=6.0, reduce_cpu=20.0, shuffle_frac=1.0, n_reducers=4)


def testbed_fabric() -> Fabric:
    """6 workers behind 2 switches (paper's 2-OVS testbed)."""
    return two_tier_fabric(n_leaves=2, hosts_per_leaf=3, host_mbps=LINK_MBPS,
                           trunk_mbps=LINK_MBPS)


def make_instance(
    job: JobSpec,
    data_size_mb: float,
    seed: int,
    replication: int = 3,
    background_load: float = 30.0,
) -> Tuple[Instance, List[Task], float]:
    """Build (map instance, reduce tasks, shuffle size per reduce)."""
    rng = np.random.default_rng(seed)
    fabric = testbed_fabric()
    workers = [f"H{i}" for i in range(6)]
    n_blocks = max(1, math.ceil(data_size_mb / BLOCK_MB))

    tasks: List[Task] = []
    for i in range(n_blocks):
        reps = tuple(rng.choice(workers, size=replication, replace=False))
        last_mb = data_size_mb - BLOCK_MB * (n_blocks - 1)
        size_mb = BLOCK_MB if i < n_blocks - 1 else max(last_mb, 1.0)
        # mild heterogeneity in per-block compute (stragglers exist in practice)
        cpu = job.map_cpu * (size_mb / BLOCK_MB) * float(rng.uniform(0.9, 1.15))
        tasks.append(Task(tid=i + 1, size=size_mb * MB, compute=cpu, replicas=reps))

    # Background job ⇒ uneven initial idle times AND ongoing cross-traffic
    # (paper: "repetitively execute a background job to provide each test
    # with initial workload").  The flows occupy 40–80 % of their paths in
    # recurring bursts over the whole horizon; the SDN ledger sees them.
    idle = {w: float(rng.uniform(0.0, background_load)) for w in workers}
    horizon = 240.0 + n_blocks * (job.map_cpu + 8.0)  # covers map + reduce tail
    background: List[BackgroundFlow] = []
    t = 0.0
    while t < horizon:
        src, dst = rng.choice(workers, size=2, replace=False)
        dur = float(rng.uniform(4.0, 12.0))
        background.append(
            BackgroundFlow(str(src), str(dst), float(rng.uniform(0.4, 0.8)),
                           t, min(t + dur, horizon))
        )
        t += dur * float(rng.uniform(0.4, 0.9))

    inst = Instance(fabric=fabric, workers=workers, idle=idle, tasks=tasks,
                    slot_duration=1.0, background=background)

    shuffle_total_mb = data_size_mb * job.shuffle_frac
    per_reduce_mb = shuffle_total_mb / job.n_reducers
    reduce_tasks = [
        Task(
            tid=10_000 + r,
            size=per_reduce_mb * MB,
            compute=job.reduce_cpu * max(per_reduce_mb / BLOCK_MB, 0.25),
            # shuffle output is spread across mappers: no locality in general —
            # model the reduce input's "home" as a random mapper subset.
            replicas=tuple(rng.choice(workers, size=2, replace=False)),
            kind="reduce",
        )
        for r in range(job.n_reducers)
    ]
    return inst, reduce_tasks, per_reduce_mb * MB


# Paper Table I ground truth (JT seconds + LR) for side-by-side reporting.
PAPER_TABLE1 = {
    "wordcount": {
        "150M": {"BASS": 78, "BAR": 78, "HDS": 78},
        "300M": {"BASS": 128, "BAR": 146, "HDS": 156},
        "600M": {"BASS": 231, "BAR": 259, "HDS": 269},
        "1G": {"BASS": 298, "BAR": 305, "HDS": 311},
        "5G": {"BASS": 1302, "BAR": 1377, "HDS": 1396},
    },
    "sort": {
        "150M": {"BASS": 55, "BAR": 67, "HDS": 74},
        "300M": {"BASS": 91, "BAR": 110, "HDS": 117},
        "600M": {"BASS": 144, "BAR": 155, "HDS": 168},
        "1G": {"BASS": 262, "BAR": 285, "HDS": 323},
        "5G": {"BASS": 1572, "BAR": 1632, "HDS": 1859},
    },
}
