"""Time-Slot (TS) bandwidth allocation — paper §IV.A.

Each link's residual bandwidth is disintegrated into equal-duration time slots
``TS_1, TS_2, …``; a task that moves data over a path during ``(t_m, t_n)`` has
the corresponding slots reserved *on every link of that path* in advance, and
the usable bandwidth of a path in a slot is the minimum residual over its
links.  The paper's allocation policy is deliberately simple ("always provide
tasks requiring data movement with the most residue bandwidth, then take it
back after the occupation") — a transfer greedily consumes the full residual
of its path slot-by-slot until the bytes are delivered.

The ledger is a dense ``[n_links, n_slots]`` float matrix of *reserved
fractions* (0 = free, 1 = fully booked), vectorized with numpy so the same
code schedules a 4-node Hadoop testbed and a 4 000-host TPU-fleet DCN (see
``benchmarks/bench_sched_scale.py``).

**Rolling horizon (DESIGN.md §7).**  A long-lived controller advances
simulated time forever, but only the slots at/after "now" can still be
planned, committed or released — fully-past slots hold delivered history
nobody re-reads through the matrix.  The ledger therefore carries a
``base_slot`` origin: physical column ``j`` stores absolute slot
``base_slot + j``, and :meth:`retire` drops fully-past columns so the
live matrix stays O(live window) instead of O(elapsed time).  Every
public API (and ``TransferPlan.slot_fracs``) speaks *absolute* slots
throughout — compaction is invisible to callers, and a compacted ledger
answers every query/plan/commit identically to a never-compacted twin
(property-tested in ``tests/test_compaction.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels import ts_plan
from ..obs import Counter
from .topology import Fabric

_EPS = 1e-9
assert ts_plan.EPS == _EPS, "ts_plan kernel and ledger must share one epsilon"


@dataclass(frozen=True)
class TransferPlan:
    """An uncommitted transfer: slot reservations + continuous start/end times."""

    links: Tuple[int, ...]           # ledger row indices
    start: float                     # seconds (continuous)
    end: float                       # seconds (continuous)
    slot_fracs: Tuple[Tuple[int, float], ...]  # (slot index, fraction reserved)

    @property
    def duration(self) -> float:
        return self.end - self.start

    @property
    def slots(self) -> Tuple[int, ...]:
        """1-based slot numbers à la paper (TS_1 covers [0, slot_dur))."""
        return tuple(s + 1 for s, _ in self.slot_fracs)


class TimeSlotLedger:
    """Per-link slotted reservation calendar (the SDN controller's ``SL_rl``)."""

    #: Device-resident mirror (``kernels.ts_plan_device.DeviceMirror``),
    #: attached lazily by :meth:`device_mirror`.  Class-level default so
    #: ``__new__``-based clones (controller snapshots) start mirror-free.
    _mirror = None

    def __init__(
        self,
        fabric: Fabric,
        slot_duration: float = 1.0,
        horizon_slots: int = 256,
    ) -> None:
        self.fabric = fabric
        self.slot_duration = float(slot_duration)
        names = sorted(fabric.links)
        self._row: Dict[str, int] = {n: i for i, n in enumerate(names)}
        self._names = names
        self.capacity = np.array(
            [fabric.link(n).capacity for n in names], dtype=np.float64
        )
        # Capacity-backed storage: ``reserved`` is a view into ``_buf``
        # starting at column ``_col0`` — growth re-slices within capacity
        # and origin retirement advances the offset, both copy-free
        # (see :meth:`_ensure` / :meth:`retire_to`).
        self._buf = np.zeros((len(names), horizon_slots), dtype=np.float64)
        self._col0 = 0
        self._res = self._buf
        #: Rolling-horizon origin: ``reserved[:, 0]`` holds absolute slot
        #: ``base_slot``.  Public APIs are absolute; only physical column
        #: indices shift (DESIGN.md §7).
        self.base_slot = 0
        #: Telemetry: columns dropped by :meth:`retire` so far.
        self.retired_slots = 0
        #: :meth:`maybe_retire` compacts once this many fully-past slots
        #: have accumulated; ``None`` disables auto-compaction (the
        #: never-compacted twin the equivalence tests compare against).
        self.retire_stride: Optional[int] = max(64, horizon_slots)
        #: Instrumentation: candidate·slot cells scanned by
        #: :meth:`plan_transfer_batch` (the escalation-freeze regression
        #: test pins that one oversized outlier no longer re-scans the
        #: whole batch at 4× the window).  Backed by a ``repro.obs``
        #: counter (see the property below) so the obs snapshot reads it
        #: live; int-style use (`led.batch_scan_cells += n`, `= 0`) is
        #: unchanged.
        self.batch_scan_cells = 0
        self._path_rows: Dict[Tuple[str, str], Tuple[int, ...]] = {}
        self._path_rows_version = fabric.version

    @classmethod
    def for_links(
        cls,
        fabric: Fabric,
        link_names: Iterable[str],
        slot_duration: float = 1.0,
        horizon_slots: int = 256,
    ) -> "TimeSlotLedger":
        """A ledger *shard*: same calendar machinery, rows restricted to
        ``link_names`` (a pod's internal links, or the boundary slice).

        Row numbering is local to the shard (sorted subset order) — the
        :class:`ShardedLedger` facade owns the global↔local translation.
        Built via ``__new__`` like ``ClusterState.clone`` so the flat
        constructor's full-fabric row map is never materialized."""
        led = cls.__new__(cls)
        led.fabric = fabric
        led.slot_duration = float(slot_duration)
        names = sorted(link_names)
        led._row = {n: i for i, n in enumerate(names)}
        led._names = names
        led.capacity = np.array(
            [fabric.link(n).capacity for n in names], dtype=np.float64
        )
        led._buf = np.zeros((len(names), horizon_slots), dtype=np.float64)
        led._col0 = 0
        led._res = led._buf
        led.base_slot = 0
        led.retired_slots = 0
        led.retire_stride = max(64, horizon_slots)
        led.batch_scan_cells = 0
        led._path_rows = {}
        led._path_rows_version = fabric.version
        return led

    # -- plumbing -----------------------------------------------------------
    # ``batch_scan_cells`` counter cell: class default None so instances
    # built via ``__new__`` (ClusterState.clone) lazily create theirs on
    # first assignment.
    _scan_cells: Optional[Counter] = None

    @property
    def batch_scan_cells(self) -> int:
        cell = self._scan_cells
        return 0 if cell is None else cell.value

    @batch_scan_cells.setter
    def batch_scan_cells(self, value: int) -> None:
        cell = self._scan_cells
        if cell is None:
            self._scan_cells = Counter("ledger.batch_scan_cells", value)
        else:
            cell.value = value

    def rows(self, link_names: Sequence[str]) -> Tuple[int, ...]:
        return tuple(self._row[n] for n in link_names)

    def link_names(self, rows: Sequence[int]) -> Tuple[str, ...]:
        return tuple(self._names[r] for r in rows)

    def path_rows(self, src: str, dst: str) -> Tuple[int, ...]:
        """``rows(fabric.path(src, dst))``, cached per endpoint pair.

        The scheduling loop re-derives the same path-row tuples for every
        placement (every replica of every task); the fabric's own path
        cache still pays a name→row translation per link per call.  Keyed
        on ``fabric.version`` so a topology mutation can never serve a
        pre-mutation row set.
        """
        if self.fabric.version != self._path_rows_version:
            self._path_rows.clear()
            self._path_rows_version = self.fabric.version
        hit = self._path_rows.get((src, dst))
        if hit is None:
            hit = self.rows(self.fabric.path(src, dst))
            if len(self._path_rows) > (1 << 18):
                self._path_rows.clear()
            self._path_rows[(src, dst)] = hit
        return hit

    @property
    def reserved(self) -> np.ndarray:
        """Live ``[n_links, width]`` reservation window (column 0 holds
        absolute slot :attr:`base_slot`) — a view into the wider capacity
        buffer, so its identity changes whenever the window grows or the
        origin shifts."""
        return self._res

    @reserved.setter
    def reserved(self, arr: np.ndarray) -> None:
        # Wholesale replacement (controller snapshot/restore/clone): the
        # array becomes the new capacity buffer and any device mirror is
        # stale by definition.
        self._buf = arr
        self._col0 = 0
        self._res = arr
        if self._mirror is not None:
            self._mirror.invalidate()

    def _ensure(self, slot: int) -> None:
        """Grow the live window so absolute ``slot`` has a live column.

        Growth within capacity just widens the view — no copy, no
        zeroing (pages arrive zeroed from the allocator).  A capacity
        miss reallocates at 8× the requested width, so the copy cost per
        cell amortizes to O(1) over a run; the old at-least-double
        zeros+copy was the dominant wall-clock cost at fleet scale.
        """
        n = self._res.shape[1]
        need = slot - self.base_slot
        if need < n:
            return
        width = need + 1
        if self._col0 + width > self._buf.shape[1]:
            cap = max(width * 8, 64)
            wider = np.zeros((self._res.shape[0], cap))
            wider[:, :n] = self._res
            self._buf = wider
            self._col0 = 0
        self._res = self._buf[:, self._col0 : self._col0 + width]

    def device_mirror(self):
        """The lazily-attached device-resident mirror of :attr:`reserved`
        (``kernels.ts_plan_device.DeviceMirror``) — the device backend's
        gather source.  Narrow sync API: the mutators journal every cell
        write through it and the mirror folds the journal in at its next
        ``sync()`` (DESIGN.md §8)."""
        if self._mirror is None:
            from ..kernels.ts_plan_device import DeviceMirror

            self._mirror = DeviceMirror(self)
        return self._mirror

    def mirror_invalidate(self) -> None:
        """Drop the device mirror's incremental state after a direct
        :attr:`reserved` write that bypassed the journaling mutators; the
        next sync re-uploads the full window."""
        if self._mirror is not None:
            self._mirror.invalidate()

    # -- full-state serialization (controller crash-recovery) ---------------
    def dump_state(self) -> dict:
        """Plain-data serialization of the rolling reservation window —
        everything :meth:`load_state` needs to make a same-fabric ledger
        byte-identical: the live matrix, its absolute origin, the
        compaction telemetry/stride, and the batch-scan counter (DESIGN.md
        §11).  Static structure (row map, capacities) is derived from the
        fabric at construction and is not serialized."""
        return {
            "reserved": self.reserved.copy(),
            "base_slot": self.base_slot,
            "retired_slots": self.retired_slots,
            "retire_stride": self.retire_stride,
            "scan_cells": self.batch_scan_cells,
        }

    def load_state(self, state: dict) -> None:
        """Restore a :meth:`dump_state` dict in place.  Goes through the
        ``reserved`` setter, so any attached device mirror is invalidated
        and re-uploads the full window on its next sync."""
        self.reserved = state["reserved"].copy()
        self.base_slot = state["base_slot"]
        self.retired_slots = state["retired_slots"]
        self.retire_stride = state["retire_stride"]
        self.batch_scan_cells = state["scan_cells"]

    def slot_of(self, t: float) -> int:
        return int(math.floor(t / self.slot_duration + _EPS))

    # -- rolling-horizon compaction -----------------------------------------
    def retire(self, t: float) -> int:
        """Drop every fully-past slot — absolute slots ``< slot_of(t)`` —
        and shift the origin there.  Returns the number of columns dropped.

        Retire-safety (DESIGN.md §7): no code path *writes* a slot before
        ``slot_of(now)`` (plans start at ``not_before >= now``, tail
        releases cut at ``slot_of(at >= now)``, ``occupy`` clamps), and
        the only reads of delivered history go through the plan objects
        themselves (``plan_bytes``/``release_after`` keep-arithmetic),
        never the matrix — so in-flight plans' tails survive intact and
        dropped columns are unreachable.  Read-only queries aimed at the
        retired past answer "free" (see :meth:`residual_fraction`).
        """
        return self.retire_to(self.slot_of(t))

    def retire_to(self, cut: int) -> int:
        """Make ``cut`` the new origin (no-op when it is not ahead)."""
        drop = cut - self.base_slot
        if drop <= 0:
            return 0
        width = self._res.shape[1]
        if drop >= width:
            # Everything booked is in the past: restart with a minimal
            # window (columns beyond the old width were never allocated
            # and are zero by definition).  Assigning through the setter
            # also invalidates any device mirror.
            self.reserved = np.zeros((self._res.shape[0], 64))
        else:
            # Origin shift = view-offset advance, copy-free; the retired
            # columns stay in the capacity buffer until the next realloc.
            # A device mirror re-bases itself at its next sync.
            self._col0 += drop
            self._res = self._buf[:, self._col0 : self._col0 + (width - drop)]
        self.base_slot = cut
        self.retired_slots += drop
        return drop

    def maybe_retire(self, t: float) -> int:
        """Hysteresis wrapper the controller calls per clock advance:
        compact only once ``retire_stride`` fully-past slots accumulated
        (so the slice-copy amortizes), and keep one *guard slot* behind
        ``slot_of(t)`` — queued events may legally fire up to ``_EPS``
        before ``t``, which can land one slot earlier after flooring."""
        stride = self.retire_stride
        if stride is None:
            return 0
        cut = self.slot_of(t) - 1
        if cut - self.base_slot < stride:
            return 0
        return self.retire_to(cut)

    # -- queries ------------------------------------------------------------
    #
    # Read-only queries never allocate: a slot past the live horizon holds
    # no reservation by definition, and a retired slot is delivered history
    # the forward-looking ledger has dropped — both answer "free" (full
    # residue) without growing the matrix.  (They historically called
    # ``_ensure`` and silently doubled the allocation on lookup.)

    def residual_fraction(self, rows: Sequence[int], slot: int) -> float:
        """Min residual fraction over ``rows`` in ``slot`` (path residue)."""
        if not rows:
            return 1.0
        p = slot - self.base_slot
        if p < 0 or p >= self.reserved.shape[1]:
            return 1.0
        return float(1.0 - self.reserved[list(rows), p].max())

    def path_bandwidth(self, rows: Sequence[int], t: float) -> float:
        """``BW_rl`` of a path at time ``t`` = min over links of residual bw."""
        if not rows:
            return float("inf")
        idx = list(rows)
        p = self.slot_of(t) - self.base_slot
        if p < 0 or p >= self.reserved.shape[1]:
            return float(self.capacity[idx].min())
        resid = (1.0 - self.reserved[idx, p]) * self.capacity[idx]
        return float(resid.min())

    def path_bandwidth_batch(
        self, rows_list: Sequence[Sequence[int]], t: float
    ) -> np.ndarray:
        """``BW_rl`` for many candidate paths in one numpy pass.

        Element ``i`` equals ``path_bandwidth(rows_list[i], t)`` exactly —
        the per-candidate min runs over a padded row matrix whose padding
        repeats one of the candidate's own links, so duplicates cannot
        change the minimum.
        """
        n = len(rows_list)
        out = np.full(n, float("inf"))
        live = [i for i in range(n) if rows_list[i]]
        if not live:
            return out
        pad = self._padded_rows([rows_list[i] for i in live])
        p = self.slot_of(t) - self.base_slot
        if p < 0 or p >= self.reserved.shape[1]:
            out[live] = self.capacity[pad].min(axis=1)
            return out
        resid = (1.0 - self.reserved[:, p][pad]) * self.capacity[pad]
        out[live] = resid.min(axis=1)
        return out

    def min_path_bandwidth(self, rows: Sequence[int], t0: float, t1: float) -> float:
        """Worst-case ``BW_rl`` over the continuous window [t0, t1)."""
        if not rows:
            return float("inf")
        s0, s1 = self.slot_of(t0), self.slot_of(max(t0, t1 - _EPS))
        idx = list(rows)
        capmin = float(self.capacity[idx].min())
        width = self.reserved.shape[1]
        lo = max(s0 - self.base_slot, 0)
        hi = min(s1 - self.base_slot + 1, width)
        if lo >= hi:
            return capmin  # window entirely outside the live matrix: free
        # Slots clamped away (retired past / beyond the horizon) are free
        # and would contribute exactly capmin — never less than the live
        # part's minimum (reserved ∈ [0, 1] ⇒ per-slot path min ≤ capmin),
        # so the live slice alone decides.
        resid = (1.0 - self.reserved[idx, lo:hi]) * self.capacity[idx, None]
        return float(resid.min(axis=0).min())

    # -- planning -----------------------------------------------------------
    def plan_transfer(
        self,
        size: float,
        rows: Sequence[int],
        not_before: float = 0.0,
        bandwidth_cap: Optional[float] = None,
        max_slots: int = 1 << 16,
    ) -> TransferPlan:
        """Greedy paper-policy transfer plan: start at the first slot with any
        residue at/after ``not_before`` and consume the path residue (up to
        ``bandwidth_cap``) slot-by-slot until ``size`` is delivered.

        ``size`` is in capacity-units·seconds (e.g. Mbit when capacity is
        Mbps).  Returns a plan; nothing is committed until :meth:`commit`.

        Dedicated single-path fast version of :meth:`plan_transfer_batch`
        (the scheduling hot loop plans one chosen path per remote task);
        the two must stay bit-identical — a property test enforces it.
        """
        if size <= 0 or not rows:
            return TransferPlan(tuple(rows), not_before, not_before, ())
        idx = list(rows)
        cap = float(self.capacity[idx].min())
        t0 = float(not_before)
        s0 = self.slot_of(t0)
        p0 = s0 - self.base_slot
        if p0 < 0:
            raise ValueError(
                f"plan_transfer: slot {s0} precedes retired origin "
                f"{self.base_slot} (not_before={t0})"
            )
        window = 64
        while window <= max_slots:
            self._ensure(s0 + window - 1)
            # Vectorized residue over [s0, s0+window): path residue per slot.
            resid_frac = 1.0 - self.reserved[idx, p0 : p0 + window].max(axis=0)
            bw = resid_frac * cap
            if bandwidth_cap is not None:
                bw = np.minimum(bw, bandwidth_cap)
            # Usable seconds per slot (first slot may be partial).
            secs = np.full(window, self.slot_duration)
            secs[0] = (s0 + 1) * self.slot_duration - t0
            deliverable = bw * secs
            cum = np.cumsum(deliverable)
            hit = int(np.searchsorted(cum, size - _EPS))
            if hit >= window:
                window *= 4
                continue
            active = bw > _EPS
            sel = np.nonzero(active[: hit + 1])[0]
            first = int(sel[0])
            start = max(t0, (s0 + first) * self.slot_duration)
            before = float(cum[hit - 1]) if hit > 0 else 0.0
            t_in = max(t0, (s0 + hit) * self.slot_duration)
            end = t_in + (size - before) / float(bw[hit])
            if bandwidth_cap is None:
                fr = resid_frac
            else:
                fr = bw / cap
            fracs = tuple((s0 + int(i), float(fr[i])) for i in sel)
            return TransferPlan(tuple(rows), start, end, fracs)
        raise RuntimeError("transfer does not fit within max_slots horizon")

    def _padded_rows(self, rows_list: Sequence[Sequence[int]]) -> np.ndarray:
        """Rectangular [n_candidates, max_path_len] row-index matrix; padding
        repeats the candidate's own first link so max/min reductions over the
        link axis are unaffected.  Callers must pass non-empty row lists."""
        width = max(len(r) for r in rows_list)
        pad = np.empty((len(rows_list), width), dtype=np.intp)
        for i, r in enumerate(rows_list):
            pad[i, : len(r)] = r
            pad[i, len(r) :] = r[0]
        return pad

    def booked_window(
        self, pad: np.ndarray, s0: np.ndarray, window: int
    ) -> np.ndarray:
        """``[n_cand, width, window]`` reserved-fraction gather: candidate
        ``k``'s padded link rows over slots ``[s0[k], s0[k] + window)``.
        ``s0`` may be a scalar (shared start) or per-candidate array.
        Slots are absolute; the gather shifts to physical columns."""
        s0 = np.asarray(s0)
        if int(s0.min()) < self.base_slot:
            raise ValueError(
                f"booked_window: slot {int(s0.min())} precedes retired "
                f"origin {self.base_slot}"
            )
        self._ensure(int(s0.max()) + window - 1)
        off = s0 - self.base_slot
        idx = off.reshape(-1, 1, 1) if off.ndim else off
        return self.reserved[pad[:, :, None], idx + np.arange(window)[None, None, :]]

    def _plan_from_scan(
        self,
        rows: Tuple[int, ...],
        s0: int,
        t0: float,
        size: float,
        bw_row: np.ndarray,
        resid_row: np.ndarray,
        cum_row: np.ndarray,
        hit: int,
        cap: Optional[float] = None,
    ) -> TransferPlan:
        """Materialize one greedy plan from a ``ts_plan.plan_scan`` row —
        the exact tail arithmetic of :meth:`plan_transfer` (bit-identical).
        ``cap`` is the candidate's bottleneck capacity, passed only when a
        ``bandwidth_cap`` squeezed ``bw`` below the residue."""
        active = bw_row > _EPS
        sel = np.nonzero(active[: hit + 1])[0]
        first = int(sel[0])
        start = max(t0, (s0 + first) * self.slot_duration)
        before = float(cum_row[hit - 1]) if hit > 0 else 0.0
        t_in = max(t0, (s0 + hit) * self.slot_duration)
        end = t_in + (size - before) / float(bw_row[hit])
        fr = resid_row if cap is None else bw_row / cap
        fracs = tuple((s0 + int(j), float(fr[j])) for j in sel)
        return TransferPlan(rows, start, end, fracs)

    def plan_transfer_batch(
        self,
        size: float,
        rows_list: Sequence[Sequence[int]],
        not_before: float = 0.0,
        bandwidth_cap: Optional[float] = None,
        max_slots: int = 1 << 16,
    ) -> List[TransferPlan]:
        """Greedy paper-policy plans for *all* candidate paths in one
        :func:`repro.kernels.ts_plan.plan_scan` pass — the controller
        scores every (source, destination) option without a Python loop
        per replica.

        Element ``i`` is bit-identical to planning ``rows_list[i]`` alone
        against the current ledger state; nothing is committed.  Window
        escalation freezes finished candidates: a plan found at window
        ``W`` is final (the scan is prefix-stable), so only the candidates
        whose transfer did not fit re-scan at ``4W`` — one oversized
        outlier no longer forces the whole batch to re-scan.  A candidate
        that cannot fit within ``max_slots`` raises, matching a
        ``plan_transfer`` loop over the same list.
        """
        n = len(rows_list)
        if n == 0:
            return []
        plans: List[Optional[TransferPlan]] = [None] * n
        live: List[int] = []
        for i, rows in enumerate(rows_list):
            if size <= 0 or not rows:
                plans[i] = TransferPlan(tuple(rows), not_before, not_before, ())
            else:
                live.append(i)
        if not live:
            return plans  # type: ignore[return-value]
        pad = self._padded_rows([rows_list[i] for i in live])
        caps = self.capacity[pad].min(axis=1)
        t0 = float(not_before)
        s0 = self.slot_of(t0)
        window = 64
        unresolved = np.arange(len(live))
        while window <= max_slots:
            sub = unresolved
            booked = self.booked_window(pad[sub], np.asarray(s0), window)
            # Usable seconds per slot (first slot may be partial).
            secs = np.full((len(sub), window), self.slot_duration)
            secs[:, 0] = (s0 + 1) * self.slot_duration - t0
            sizes = np.full(len(sub), size)
            self.batch_scan_cells += len(sub) * window
            resid, bw, cum, hits = ts_plan.plan_scan(
                booked, caps[sub], secs, sizes, bandwidth_cap
            )
            done = hits < window
            for k in np.nonzero(done)[0]:
                i = live[sub[k]]
                plans[i] = self._plan_from_scan(
                    tuple(rows_list[i]), s0, t0, size,
                    bw[k], resid[k], cum[k], int(hits[k]),
                    None if bandwidth_cap is None else float(caps[sub[k]]),
                )
            unresolved = sub[~done]
            if unresolved.size == 0:
                return plans  # type: ignore[return-value]
            window *= 4
        raise RuntimeError("transfer does not fit within max_slots horizon")

    def commit(self, plan: TransferPlan) -> None:
        """Reserve the plan's slot fractions on every path link — one
        ``(rows × slots)`` scatter instead of a per-slot Python loop, with
        a single joint over-reservation check (slots within a plan are
        distinct, so the scatter equals the sequential loop exactly)."""
        if not plan.slot_fracs:
            return
        base = self.base_slot
        if len(plan.slot_fracs) == 1 and len(plan.links) <= 8:
            # Frontier-landing common case: scalar python floats (same
            # doubles as the vector scatter, no ufunc dispatch).
            slot, frac = plan.slot_fracs[0]
            p = slot - base
            if p < 0:
                raise ValueError(
                    f"commit: slot {slot} precedes retired origin {base}"
                )
            if p >= self.reserved.shape[1]:
                self._ensure(slot)
            res = self.reserved
            vals = [res.item(r, p) + frac for r in plan.links]
            mx = max(vals)
            if mx > 1.0 + 1e-6:
                raise ValueError(
                    f"over-reservation on slot {slot}: {mx:.6f} > 1"
                )
            for r, v in zip(plan.links, vals):
                res[r, p] = v if v < 1.0 else 1.0
            if self._mirror is not None:
                self._mirror.note_flat(
                    np.asarray(plan.links),
                    np.full(len(plan.links), slot, dtype=np.int64),
                    np.minimum(vals, 1.0),
                )
            return
        slots = [s for s, _ in plan.slot_fracs]
        fracs = np.array([f for _, f in plan.slot_fracs])
        if min(slots) < base:
            raise ValueError(
                f"commit: slot {min(slots)} precedes retired origin {base}"
            )
        self._ensure(max(slots))
        rr = np.asarray(plan.links)[:, None]  # open mesh: (rows × slots)
        cc = np.asarray(slots) - base
        new = self.reserved[rr, cc] + fracs[None, :]
        over = new > 1.0 + 1e-6
        if over.any():
            col = int(over.any(axis=0).argmax())
            raise ValueError(
                f"over-reservation on slot {slots[col]}: "
                f"{new[:, col].max():.6f} > 1"
            )
        clamped = np.minimum(new, 1.0)
        self.reserved[rr, cc] = clamped
        if self._mirror is not None:
            self._mirror.note_grid(np.asarray(plan.links), np.asarray(slots), clamped)

    def commit_batch(self, plans: Sequence[TransferPlan]) -> None:
        """Commit many plans whose (link, slot) cells are pairwise disjoint
        in one concatenated scatter (the reroute engine's grouped commit).

        Disjointness is the caller's contract — the engine's conflict walk
        only groups winners whose reads (a superset of their writes) were
        untouched by every earlier winner in the group — so a plain fancy-
        index add equals committing the plans one by one, in any order.
        A single joint over-reservation check mirrors :meth:`commit`.
        """
        rr_parts: List[np.ndarray] = []
        cc_parts: List[np.ndarray] = []
        vv_parts: List[np.ndarray] = []
        for plan in plans:
            n_slots = len(plan.slot_fracs)
            if not n_slots:
                continue
            links = np.asarray(plan.links)
            slots = np.fromiter(
                (s for s, _ in plan.slot_fracs), dtype=np.int64, count=n_slots
            )
            fracs = np.fromiter(
                (f for _, f in plan.slot_fracs), dtype=np.float64,
                count=n_slots,
            )
            rr_parts.append(np.repeat(links, n_slots))
            cc_parts.append(np.tile(slots, links.size))
            vv_parts.append(np.tile(fracs, links.size))
        if not rr_parts:
            return
        rr = np.concatenate(rr_parts)
        cc = np.concatenate(cc_parts)
        if int(cc.min()) < self.base_slot:
            raise ValueError(
                f"commit_batch: slot {int(cc.min())} precedes retired "
                f"origin {self.base_slot}"
            )
        self._ensure(int(cc.max()))
        ccp = cc - self.base_slot
        # The disjointness contract is load-bearing (fancy-index assignment
        # is last-write-wins): a violation must fail loudly, not silently
        # drop a reservation.
        cells = rr * self.reserved.shape[1] + ccp
        if np.unique(cells).size != cells.size:
            raise ValueError("commit_batch: plans share a (link, slot) cell")
        new = self.reserved[rr, ccp] + np.concatenate(vv_parts)
        over = new > 1.0 + 1e-6
        if over.any():
            k = int(over.argmax())
            raise ValueError(
                f"over-reservation on slot {cc[k]}: {new[k]:.6f} > 1"
            )
        clamped = np.minimum(new, 1.0)
        self.reserved[rr, ccp] = clamped
        if self._mirror is not None:
            self._mirror.note_flat(rr, cc, clamped)

    def occupy(
        self, rows: Sequence[int], start: float, end: float, fraction: float
    ) -> None:
        """Book ``fraction`` of every row over the continuous window
        [start, end) — background cross-traffic the controller observes but
        did not plan (saturates at 1.0 instead of raising).  The portion
        falling before the retired origin is delivered history and is
        skipped (a scratch ledger replays old background flows whose
        start predates the live window)."""
        s0 = self.slot_of(start)
        s1 = self.slot_of(max(start, end - _EPS))
        if s1 < self.base_slot:
            return
        s0 = max(s0, self.base_slot)
        self._ensure(s1)
        p0, p1 = s0 - self.base_slot, s1 - self.base_slot
        idx = list(rows)
        block = np.minimum(self.reserved[idx, p0 : p1 + 1] + fraction, 1.0)
        self.reserved[idx, p0 : p1 + 1] = block
        if self._mirror is not None:
            self._mirror.note_grid(
                np.asarray(idx), np.arange(s0, s1 + 1, dtype=np.int64), block
            )

    def release(self, plan: TransferPlan) -> None:
        """Exact inverse of :meth:`commit` — one ``(rows × slots)`` scatter.
        Slots already retired hold delivered history with no live column;
        they are skipped (there is nothing left to free)."""
        if not plan.slot_fracs:
            return
        base = self.base_slot
        live = [(s, f) for s, f in plan.slot_fracs if s >= base]
        if not live:
            return
        fracs = np.array([f for _, f in live])
        rr = np.asarray(plan.links)[:, None]
        slots = np.array([s for s, _ in live], dtype=np.int64)
        cc = slots - base
        freed = np.maximum(self.reserved[rr, cc] - fracs[None, :], 0.0)
        self.reserved[rr, cc] = freed
        if self._mirror is not None:
            self._mirror.note_grid(np.asarray(plan.links), slots, freed)

    def plan_bytes(self, plan: TransferPlan, until: Optional[float] = None) -> float:
        """Capacity-units·seconds the plan delivers by ``until`` (default:
        the whole plan — i.e. the transfer's total size as booked)."""
        if not plan.slot_fracs:
            return 0.0
        cap = float(self.capacity[list(plan.links)].min())
        t1 = plan.end if until is None else min(float(until), plan.end)
        slots = np.array([s for s, _ in plan.slot_fracs])
        fracs = np.array([f for _, f in plan.slot_fracs])
        lo = np.maximum(plan.start, slots * self.slot_duration)
        hi = np.minimum(t1, (slots + 1) * self.slot_duration)
        return float((fracs * cap * np.clip(hi - lo, 0.0, None)).sum())

    def release_after(self, plan: TransferPlan, t: float) -> TransferPlan:
        """Release the unconsumed tail of a committed plan (reroute support).

        Every slot at/after ``t``'s slot is released; slots that completed
        strictly before it stay committed.  The boundary slot — the one
        ``t`` falls inside — is released *whole*: its bytes are forfeited
        and must be retransmitted (see DESIGN.md §4; since controller
        replans always use ``not_before >= t``, the freed past fraction
        can never be double-booked).  Returns the kept (truncated) plan,
        whose :meth:`plan_bytes` is exactly the delivered size.
        """
        if not plan.slot_fracs or t >= plan.end:
            return plan
        if t <= plan.start:
            cut = plan.slot_fracs[0][0]
        else:
            cut = self.slot_of(t)
        keep = tuple((s, f) for s, f in plan.slot_fracs if s < cut)
        idx = list(plan.links)
        # The physical scatter skips tail slots already retired (possible
        # only when a caller cuts behind the live origin; the controller
        # always cuts at the failure instant, ahead of it).
        wipe = max(cut, self.base_slot)
        tail_slots = [s for s, _ in plan.slot_fracs if s >= wipe]
        if tail_slots:
            tail_fracs = np.array([f for s, f in plan.slot_fracs if s >= wipe])
            rr = np.asarray(idx)[:, None]
            cc = np.asarray(tail_slots) - self.base_slot
            freed = np.maximum(self.reserved[rr, cc] - tail_fracs[None, :], 0.0)
            self.reserved[rr, cc] = freed
            if self._mirror is not None:
                self._mirror.note_grid(
                    np.asarray(idx), np.asarray(tail_slots, dtype=np.int64), freed
                )
        if not keep:
            return TransferPlan(plan.links, plan.start, plan.start, ())
        new_end = min(plan.end, cut * self.slot_duration)
        return TransferPlan(plan.links, plan.start, new_end, keep)

    # -- convenience --------------------------------------------------------
    def transfer_time(
        self, size: float, rows: Sequence[int], not_before: float = 0.0
    ) -> float:
        """Duration the greedy plan would take (no commit) — Eq. (1) with the
        real-time ledger standing in for ``BW_{dataSrc,j}``."""
        plan = self.plan_transfer(size, rows, not_before)
        return plan.end - plan.start if plan.slot_fracs else 0.0

    def earliest_window(
        self,
        rows: Sequence[int],
        size: float,
        not_before: float,
        deadline: float,
    ) -> Optional[TransferPlan]:
        """Earliest greedy plan finishing by ``deadline`` (Pre-BASS prefetch)."""
        plan = self.plan_transfer(size, rows, not_before)
        if plan.end <= deadline + _EPS:
            return plan
        return None

    def utilization(self) -> float:
        """Mean reserved fraction over the *live booked window* — physical
        columns up to the last slot holding any reservation.

        The historical definition divided by the entire allocated matrix,
        so every ``_ensure`` doubling (and, for a long-running controller,
        sheer elapsed time) diluted the value toward 0 regardless of load.
        Measuring against the booked window makes it allocation-invariant
        (regression-pinned across a doubling in
        ``tests/test_compaction.py``)."""
        res = self.reserved
        booked = np.flatnonzero(res.any(axis=0))
        if booked.size == 0:
            return 0.0
        n = int(booked[-1]) + 1
        return float(res[:, :n].sum() / (res.shape[0] * n))


# ---------------------------------------------------------------------------
# ShardedLedger — per-pod shards behind the flat ledger's surface
# ---------------------------------------------------------------------------


class ShardedLedger:
    """Pod-partitioned reservation calendar: one :class:`TimeSlotLedger`
    shard per link group (each pod's internal links + one boundary shard
    for the core/aggregation slice), behind the flat ledger's query/plan/
    commit surface with the flat ledger's *global* row numbering.

    Byte-parity contract (DESIGN.md §12): every public method returns the
    exact floats the flat ledger would — the reservation matrix is
    conceptually infinite with zeros outside each live window, so a
    per-shard gather with per-shard origins reads the same cell values a
    single matrix would, and max/min reductions over a row partition equal
    the unpartitioned reduction (IEEE max/min are order-invariant).  Plans
    carry global rows throughout, so ``TransferPlan`` equality against a
    flat-ledger plan is structural.

    Each shard keeps its own §7 rolling origin; :meth:`maybe_retire` fans
    the clock out, and identical strides keep the origins in lockstep.
    :meth:`commit` distributes a plan's cells shard-by-shard — the
    over-reservation check runs per shard, so a rejected commit may leave
    earlier shards booked (callers never over-reserve planned transfers;
    the flat ledger's joint check is atomic, this one is loud-but-partial).
    """

    def __init__(
        self,
        fabric: Fabric,
        groups: Dict[str, Sequence[str]],
        slot_duration: float = 1.0,
        horizon_slots: int = 256,
    ) -> None:
        self.fabric = fabric
        self.slot_duration = float(slot_duration)
        names = sorted(fabric.links)
        self._row: Dict[str, int] = {n: i for i, n in enumerate(names)}
        self._names = names
        self.capacity = np.array(
            [fabric.link(n).capacity for n in names], dtype=np.float64
        )
        owner: Dict[str, str] = {}
        for g, lns in groups.items():
            for n in lns:
                if n in owner:
                    raise ValueError(
                        f"link {n!r} in shards {owner[n]!r} and {g!r}"
                    )
                owner[n] = g
        missing = set(names) - set(owner)
        if missing:
            raise ValueError(f"links not covered by any shard: {sorted(missing)[:4]}")
        self.shard_names = tuple(sorted(groups))
        self.shards: Dict[str, TimeSlotLedger] = {
            g: TimeSlotLedger.for_links(
                fabric, groups[g], slot_duration, horizon_slots
            )
            for g in self.shard_names
        }
        self._shard_list = [self.shards[g] for g in self.shard_names]
        # Global row → (owning shard index, shard-local row).
        self._shard_idx = np.empty(len(names), dtype=np.intp)
        self._local_row = np.empty(len(names), dtype=np.intp)
        for gi, g in enumerate(self.shard_names):
            sh = self.shards[g]
            for n in sh._names:
                r = self._row[n]
                self._shard_idx[r] = gi
                self._local_row[r] = sh._row[n]
        self._path_rows: Dict[Tuple[str, str], Tuple[int, ...]] = {}
        self._path_rows_version = fabric.version

    # -- plumbing (flat-surface mirrors) ------------------------------------
    def rows(self, link_names: Sequence[str]) -> Tuple[int, ...]:
        return tuple(self._row[n] for n in link_names)

    def link_names(self, rows: Sequence[int]) -> Tuple[str, ...]:
        return tuple(self._names[r] for r in rows)

    def path_rows(self, src: str, dst: str) -> Tuple[int, ...]:
        if self.fabric.version != self._path_rows_version:
            self._path_rows.clear()
            self._path_rows_version = self.fabric.version
        hit = self._path_rows.get((src, dst))
        if hit is None:
            hit = self.rows(self.fabric.path(src, dst))
            if len(self._path_rows) > (1 << 18):
                self._path_rows.clear()
            self._path_rows[(src, dst)] = hit
        return hit

    def slot_of(self, t: float) -> int:
        return int(math.floor(t / self.slot_duration + _EPS))

    @property
    def base_slot(self) -> int:
        """Rolling origin (identical across shards under lockstep strides;
        reported as the minimum so a mixed state stays conservative)."""
        return min(sh.base_slot for sh in self._shard_list)

    @property
    def retired_slots(self) -> int:
        return min(sh.retired_slots for sh in self._shard_list)

    @property
    def reserved(self) -> np.ndarray:
        """Materialized global ``[n_links, width]`` reservation window in
        flat row order (column 0 at the facade's :attr:`base_slot`),
        gathered from each shard's live window at its origin offset —
        cells outside a shard's window are zero, exactly the flat
        ledger's conceptually-infinite matrix.  Read-only and built per
        call: it exists for external auditors (the replay oracle's
        over-booking sweep, tests); planning paths never touch it."""
        origin = self.base_slot
        width = max(
            sh.base_slot - origin + sh.reserved.shape[1]
            for sh in self._shard_list
        )
        out = np.zeros((len(self._names), width), dtype=np.float64)
        for sh in self._shard_list:
            win = sh.reserved
            off = sh.base_slot - origin
            grows = np.fromiter(
                (self._row[n] for n in sh._names), dtype=np.intp,
                count=len(sh._names),
            )
            lrows = np.fromiter(
                (sh._row[n] for n in sh._names), dtype=np.intp,
                count=len(sh._names),
            )
            out[grows, off:off + win.shape[1]] = win[lrows]
        return out

    @property
    def retire_stride(self) -> Optional[int]:
        return self._shard_list[0].retire_stride

    @retire_stride.setter
    def retire_stride(self, stride: Optional[int]) -> None:
        for sh in self._shard_list:
            sh.retire_stride = stride

    @property
    def batch_scan_cells(self) -> int:
        return sum(sh.batch_scan_cells for sh in self._shard_list)

    @batch_scan_cells.setter
    def batch_scan_cells(self, value: int) -> None:
        for sh in self._shard_list:
            sh.batch_scan_cells = 0
        self._shard_list[0].batch_scan_cells = value

    def _split(
        self, rows: Sequence[int]
    ) -> List[Tuple[TimeSlotLedger, List[int]]]:
        """Group global rows by owning shard (insertion-ordered, so the
        grouping is deterministic in the path's link order)."""
        per: Dict[int, List[int]] = {}
        sidx, lrow = self._shard_idx, self._local_row
        for r in rows:
            per.setdefault(int(sidx[r]), []).append(int(lrow[r]))
        return [(self._shard_list[si], lr) for si, lr in per.items()]

    # -- serialization (crash recovery) -------------------------------------
    def dump_state(self) -> dict:
        return {
            "shards": {g: self.shards[g].dump_state() for g in self.shard_names}
        }

    def load_state(self, state: dict) -> None:
        for g, st in state["shards"].items():
            self.shards[g].load_state(st)

    # -- rolling-horizon compaction -----------------------------------------
    def retire(self, t: float) -> int:
        return sum(sh.retire(t) for sh in self._shard_list)

    def retire_to(self, cut: int) -> int:
        return sum(sh.retire_to(cut) for sh in self._shard_list)

    def maybe_retire(self, t: float) -> int:
        return sum(sh.maybe_retire(t) for sh in self._shard_list)

    # -- queries ------------------------------------------------------------
    def residual_fraction(self, rows: Sequence[int], slot: int) -> float:
        if not rows:
            return 1.0
        best = 1.0
        for sh, lr in self._split(rows):
            p = slot - sh.base_slot
            if p < 0 or p >= sh.reserved.shape[1]:
                continue  # free slice: contributes exactly 1.0
            v = float(1.0 - sh.reserved[lr, p].max())
            if v < best:
                best = v
        return best

    def path_bandwidth(self, rows: Sequence[int], t: float) -> float:
        if not rows:
            return float("inf")
        s = self.slot_of(t)
        best = float("inf")
        for sh, lr in self._split(rows):
            caps = sh.capacity[lr]
            p = s - sh.base_slot
            if p < 0 or p >= sh.reserved.shape[1]:
                m = float(caps.min())
            else:
                m = float(((1.0 - sh.reserved[lr, p]) * caps).min())
            if m < best:
                best = m
        return best

    def path_bandwidth_batch(
        self, rows_list: Sequence[Sequence[int]], t: float
    ) -> np.ndarray:
        return np.array(
            [self.path_bandwidth(r, t) for r in rows_list], dtype=np.float64
        )

    def min_path_bandwidth(
        self, rows: Sequence[int], t0: float, t1: float
    ) -> float:
        if not rows:
            return float("inf")
        s0, s1 = self.slot_of(t0), self.slot_of(max(t0, t1 - _EPS))
        n = s1 - s0 + 1
        vals: Optional[np.ndarray] = None
        for sh, lr in self._split(rows):
            caps = sh.capacity[lr]
            block = np.zeros((len(lr), n))
            lo = max(s0 - sh.base_slot, 0)
            hi = min(s1 - sh.base_slot + 1, sh.reserved.shape[1])
            if lo < hi:
                a0 = sh.base_slot + lo - s0
                block[:, a0 : a0 + (hi - lo)] = sh.reserved[lr, lo:hi]
            v = ((1.0 - block) * caps[:, None]).min(axis=0)
            vals = v if vals is None else np.minimum(vals, v)
        assert vals is not None
        return float(vals.min())

    # -- planning -----------------------------------------------------------
    def plan_transfer(
        self,
        size: float,
        rows: Sequence[int],
        not_before: float = 0.0,
        bandwidth_cap: Optional[float] = None,
        max_slots: int = 1 << 16,
    ) -> TransferPlan:
        """The flat greedy plan over a cross-shard path: per-shard window
        slices are stacked and max-reduced (order-invariant, so the path
        residue per slot is bit-identical to the flat matrix gather), then
        the tail arithmetic is :meth:`TimeSlotLedger.plan_transfer`'s own,
        verbatim."""
        if size <= 0 or not rows:
            return TransferPlan(tuple(rows), not_before, not_before, ())
        idx = list(rows)
        cap = float(self.capacity[idx].min())
        t0 = float(not_before)
        s0 = self.slot_of(t0)
        split = self._split(idx)
        for sh, _ in split:
            if s0 < sh.base_slot:
                raise ValueError(
                    f"plan_transfer: slot {s0} precedes retired origin "
                    f"{sh.base_slot} (not_before={t0})"
                )
        window = 64
        while window <= max_slots:
            booked: Optional[np.ndarray] = None
            for sh, lr in split:
                sh._ensure(s0 + window - 1)
                p0 = s0 - sh.base_slot
                m = sh.reserved[lr, p0 : p0 + window].max(axis=0)
                booked = m if booked is None else np.maximum(booked, m)
            resid_frac = 1.0 - booked
            bw = resid_frac * cap
            if bandwidth_cap is not None:
                bw = np.minimum(bw, bandwidth_cap)
            secs = np.full(window, self.slot_duration)
            secs[0] = (s0 + 1) * self.slot_duration - t0
            deliverable = bw * secs
            cum = np.cumsum(deliverable)
            hit = int(np.searchsorted(cum, size - _EPS))
            if hit >= window:
                window *= 4
                continue
            active = bw > _EPS
            sel = np.nonzero(active[: hit + 1])[0]
            first = int(sel[0])
            start = max(t0, (s0 + first) * self.slot_duration)
            before = float(cum[hit - 1]) if hit > 0 else 0.0
            t_in = max(t0, (s0 + hit) * self.slot_duration)
            end = t_in + (size - before) / float(bw[hit])
            if bandwidth_cap is None:
                fr = resid_frac
            else:
                fr = bw / cap
            fracs = tuple((s0 + int(i), float(fr[i])) for i in sel)
            return TransferPlan(tuple(rows), start, end, fracs)
        raise RuntimeError("transfer does not fit within max_slots horizon")

    def plan_transfer_batch(
        self,
        size: float,
        rows_list: Sequence[Sequence[int]],
        not_before: float = 0.0,
        bandwidth_cap: Optional[float] = None,
        max_slots: int = 1 << 16,
    ) -> List[TransferPlan]:
        """Per-candidate :meth:`plan_transfer` loop.  The flat batch path
        documents element-wise bit-identity with ``plan_transfer``, so a
        loop over the facade matches it exactly; the fused scan stays a
        flat-matrix (and per-shard wavefront) optimization."""
        return [
            self.plan_transfer(size, rows, not_before, bandwidth_cap, max_slots)
            for rows in rows_list
        ]

    # -- mutations ----------------------------------------------------------
    def commit(self, plan: TransferPlan) -> None:
        if not plan.slot_fracs:
            return
        for sh, lr in self._split(plan.links):
            sh.commit(
                TransferPlan(tuple(lr), plan.start, plan.end, plan.slot_fracs)
            )

    def commit_batch(self, plans: Sequence[TransferPlan]) -> None:
        for plan in plans:
            self.commit(plan)

    def occupy(
        self, rows: Sequence[int], start: float, end: float, fraction: float
    ) -> None:
        for sh, lr in self._split(rows):
            sh.occupy(lr, start, end, fraction)

    def release(self, plan: TransferPlan) -> None:
        if not plan.slot_fracs:
            return
        for sh, lr in self._split(plan.links):
            sh.release(
                TransferPlan(tuple(lr), plan.start, plan.end, plan.slot_fracs)
            )

    def release_after(self, plan: TransferPlan, t: float) -> TransferPlan:
        if not plan.slot_fracs or t >= plan.end:
            return plan
        if t <= plan.start:
            cut = plan.slot_fracs[0][0]
        else:
            cut = self.slot_of(t)
        keep = tuple((s, f) for s, f in plan.slot_fracs if s < cut)
        tail = tuple((s, f) for s, f in plan.slot_fracs if s >= cut)
        if tail:
            # Per-shard tail wipe: ``release`` skips already-retired slots,
            # exactly the flat ``wipe = max(cut, base_slot)`` clamp.
            for sh, lr in self._split(plan.links):
                sh.release(TransferPlan(tuple(lr), plan.start, plan.start, tail))
        if not keep:
            return TransferPlan(plan.links, plan.start, plan.start, ())
        new_end = min(plan.end, cut * self.slot_duration)
        return TransferPlan(plan.links, plan.start, new_end, keep)

    def plan_bytes(self, plan: TransferPlan, until: Optional[float] = None) -> float:
        if not plan.slot_fracs:
            return 0.0
        cap = float(self.capacity[list(plan.links)].min())
        t1 = plan.end if until is None else min(float(until), plan.end)
        slots = np.array([s for s, _ in plan.slot_fracs])
        fracs = np.array([f for _, f in plan.slot_fracs])
        lo = np.maximum(plan.start, slots * self.slot_duration)
        hi = np.minimum(t1, (slots + 1) * self.slot_duration)
        return float((fracs * cap * np.clip(hi - lo, 0.0, None)).sum())

    # -- convenience --------------------------------------------------------
    def transfer_time(
        self, size: float, rows: Sequence[int], not_before: float = 0.0
    ) -> float:
        plan = self.plan_transfer(size, rows, not_before)
        return plan.end - plan.start if plan.slot_fracs else 0.0

    def utilization(self) -> float:
        """Mean reserved fraction over the union of the shards' live booked
        windows (same allocation-invariance argument as the flat ledger)."""
        tot = 0.0
        cells = 0
        for sh in self._shard_list:
            res = sh.reserved
            booked = np.flatnonzero(res.any(axis=0))
            if booked.size == 0:
                continue
            n = int(booked[-1]) + 1
            tot += float(res[:, :n].sum())
            cells += res.shape[0] * n
        return tot / cells if cells else 0.0
