"""Time-Slot (TS) bandwidth allocation — paper §IV.A.

Each link's residual bandwidth is disintegrated into equal-duration time slots
``TS_1, TS_2, …``; a task that moves data over a path during ``(t_m, t_n)`` has
the corresponding slots reserved *on every link of that path* in advance, and
the usable bandwidth of a path in a slot is the minimum residual over its
links.  The paper's allocation policy is deliberately simple ("always provide
tasks requiring data movement with the most residue bandwidth, then take it
back after the occupation") — a transfer greedily consumes the full residual
of its path slot-by-slot until the bytes are delivered.

The ledger is a dense ``[n_links, n_slots]`` float matrix of *reserved
fractions* (0 = free, 1 = fully booked), vectorized with numpy so the same
code schedules a 4-node Hadoop testbed and a 4 000-host TPU-fleet DCN (see
``benchmarks/bench_sched_scale.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .topology import Fabric

_EPS = 1e-9


@dataclass(frozen=True)
class TransferPlan:
    """An uncommitted transfer: slot reservations + continuous start/end times."""

    links: Tuple[int, ...]           # ledger row indices
    start: float                     # seconds (continuous)
    end: float                       # seconds (continuous)
    slot_fracs: Tuple[Tuple[int, float], ...]  # (slot index, fraction reserved)

    @property
    def duration(self) -> float:
        return self.end - self.start

    @property
    def slots(self) -> Tuple[int, ...]:
        """1-based slot numbers à la paper (TS_1 covers [0, slot_dur))."""
        return tuple(s + 1 for s, _ in self.slot_fracs)


class TimeSlotLedger:
    """Per-link slotted reservation calendar (the SDN controller's ``SL_rl``)."""

    def __init__(
        self,
        fabric: Fabric,
        slot_duration: float = 1.0,
        horizon_slots: int = 256,
    ) -> None:
        self.fabric = fabric
        self.slot_duration = float(slot_duration)
        names = sorted(fabric.links)
        self._row: Dict[str, int] = {n: i for i, n in enumerate(names)}
        self._names = names
        self.capacity = np.array(
            [fabric.link(n).capacity for n in names], dtype=np.float64
        )
        self.reserved = np.zeros((len(names), horizon_slots), dtype=np.float64)

    # -- plumbing -----------------------------------------------------------
    def rows(self, link_names: Sequence[str]) -> Tuple[int, ...]:
        return tuple(self._row[n] for n in link_names)

    def link_names(self, rows: Sequence[int]) -> Tuple[str, ...]:
        return tuple(self._names[r] for r in rows)

    def _ensure(self, slot: int) -> None:
        n = self.reserved.shape[1]
        if slot >= n:
            grow = max(slot + 1 - n, n)  # at least double
            self.reserved = np.pad(self.reserved, ((0, 0), (0, grow)))

    def slot_of(self, t: float) -> int:
        return int(math.floor(t / self.slot_duration + _EPS))

    # -- queries ------------------------------------------------------------
    def residual_fraction(self, rows: Sequence[int], slot: int) -> float:
        """Min residual fraction over ``rows`` in ``slot`` (path residue)."""
        self._ensure(slot)
        if not rows:
            return 1.0
        return float(1.0 - self.reserved[list(rows), slot].max())

    def path_bandwidth(self, rows: Sequence[int], t: float) -> float:
        """``BW_rl`` of a path at time ``t`` = min over links of residual bw."""
        if not rows:
            return float("inf")
        slot = self.slot_of(t)
        self._ensure(slot)
        idx = list(rows)
        resid = (1.0 - self.reserved[idx, slot]) * self.capacity[idx]
        return float(resid.min())

    def path_bandwidth_batch(
        self, rows_list: Sequence[Sequence[int]], t: float
    ) -> np.ndarray:
        """``BW_rl`` for many candidate paths in one numpy pass.

        Element ``i`` equals ``path_bandwidth(rows_list[i], t)`` exactly —
        the per-candidate min runs over a padded row matrix whose padding
        repeats one of the candidate's own links, so duplicates cannot
        change the minimum.
        """
        n = len(rows_list)
        out = np.full(n, float("inf"))
        live = [i for i in range(n) if rows_list[i]]
        if not live:
            return out
        slot = self.slot_of(t)
        self._ensure(slot)
        pad = self._padded_rows([rows_list[i] for i in live])
        resid = (1.0 - self.reserved[:, slot][pad]) * self.capacity[pad]
        out[live] = resid.min(axis=1)
        return out

    def min_path_bandwidth(self, rows: Sequence[int], t0: float, t1: float) -> float:
        """Worst-case ``BW_rl`` over the continuous window [t0, t1)."""
        if not rows:
            return float("inf")
        s0, s1 = self.slot_of(t0), self.slot_of(max(t0, t1 - _EPS))
        self._ensure(s1)
        idx = list(rows)
        resid = (1.0 - self.reserved[idx, s0 : s1 + 1]) * self.capacity[idx, None]
        return float(resid.min(axis=0).min())

    # -- planning -----------------------------------------------------------
    def plan_transfer(
        self,
        size: float,
        rows: Sequence[int],
        not_before: float = 0.0,
        bandwidth_cap: Optional[float] = None,
        max_slots: int = 1 << 16,
    ) -> TransferPlan:
        """Greedy paper-policy transfer plan: start at the first slot with any
        residue at/after ``not_before`` and consume the path residue (up to
        ``bandwidth_cap``) slot-by-slot until ``size`` is delivered.

        ``size`` is in capacity-units·seconds (e.g. Mbit when capacity is
        Mbps).  Returns a plan; nothing is committed until :meth:`commit`.

        Dedicated single-path fast version of :meth:`plan_transfer_batch`
        (the scheduling hot loop plans one chosen path per remote task);
        the two must stay bit-identical — a property test enforces it.
        """
        if size <= 0 or not rows:
            return TransferPlan(tuple(rows), not_before, not_before, ())
        idx = list(rows)
        cap = float(self.capacity[idx].min())
        t0 = float(not_before)
        s0 = self.slot_of(t0)
        window = 64
        while window <= max_slots:
            self._ensure(s0 + window - 1)
            # Vectorized residue over [s0, s0+window): path residue per slot.
            resid_frac = 1.0 - self.reserved[idx, s0 : s0 + window].max(axis=0)
            bw = resid_frac * cap
            if bandwidth_cap is not None:
                bw = np.minimum(bw, bandwidth_cap)
            # Usable seconds per slot (first slot may be partial).
            secs = np.full(window, self.slot_duration)
            secs[0] = (s0 + 1) * self.slot_duration - t0
            deliverable = bw * secs
            cum = np.cumsum(deliverable)
            hit = int(np.searchsorted(cum, size - _EPS))
            if hit >= window:
                window *= 4
                continue
            active = bw > _EPS
            sel = np.nonzero(active[: hit + 1])[0]
            first = int(sel[0])
            start = max(t0, (s0 + first) * self.slot_duration)
            before = float(cum[hit - 1]) if hit > 0 else 0.0
            t_in = max(t0, (s0 + hit) * self.slot_duration)
            end = t_in + (size - before) / float(bw[hit])
            if bandwidth_cap is None:
                fr = resid_frac
            else:
                fr = bw / cap
            fracs = tuple((s0 + int(i), float(fr[i])) for i in sel)
            return TransferPlan(tuple(rows), start, end, fracs)
        raise RuntimeError("transfer does not fit within max_slots horizon")

    def _padded_rows(self, rows_list: Sequence[Sequence[int]]) -> np.ndarray:
        """Rectangular [n_candidates, max_path_len] row-index matrix; padding
        repeats the candidate's own first link so max/min reductions over the
        link axis are unaffected.  Callers must pass non-empty row lists."""
        width = max(len(r) for r in rows_list)
        pad = np.empty((len(rows_list), width), dtype=np.intp)
        for i, r in enumerate(rows_list):
            pad[i, : len(r)] = r
            pad[i, len(r) :] = r[0]
        return pad

    def plan_transfer_batch(
        self,
        size: float,
        rows_list: Sequence[Sequence[int]],
        not_before: float = 0.0,
        bandwidth_cap: Optional[float] = None,
        max_slots: int = 1 << 16,
    ) -> List[TransferPlan]:
        """Greedy paper-policy plans for *all* candidate paths in one numpy
        pass — the controller scores every (source, destination) option
        without a Python loop per replica.

        Element ``i`` is bit-identical to planning ``rows_list[i]`` alone
        against the current ledger state; nothing is committed.  Window
        escalation is joint: if any candidate cannot fit within
        ``max_slots`` the call raises, matching a ``plan_transfer`` loop
        over the same list.
        """
        n = len(rows_list)
        if n == 0:
            return []
        plans: List[Optional[TransferPlan]] = [None] * n
        live: List[int] = []
        for i, rows in enumerate(rows_list):
            if size <= 0 or not rows:
                plans[i] = TransferPlan(tuple(rows), not_before, not_before, ())
            else:
                live.append(i)
        if not live:
            return plans  # type: ignore[return-value]
        pad = self._padded_rows([rows_list[i] for i in live])
        flat = pad.ravel()
        n_live, width = pad.shape
        caps = self.capacity[pad].min(axis=1)
        t0 = float(not_before)
        s0 = self.slot_of(t0)
        window = 64
        while window <= max_slots:
            self._ensure(s0 + window - 1)
            # Path residue per candidate per slot over [s0, s0+window).
            booked = self.reserved[flat, s0 : s0 + window].reshape(
                n_live, width, window
            )
            resid_frac = 1.0 - booked.max(axis=1)
            bw = resid_frac * caps[:, None]
            if bandwidth_cap is not None:
                bw = np.minimum(bw, bandwidth_cap)
            # Usable seconds per slot (first slot may be partial).
            secs = np.full(window, self.slot_duration)
            secs[0] = (s0 + 1) * self.slot_duration - t0
            cum = np.cumsum(bw * secs, axis=1)
            hits = [int(np.searchsorted(cum[k], size - _EPS)) for k in range(len(live))]
            if max(hits) >= window:
                window *= 4
                continue
            for k, i in enumerate(live):
                hit = hits[k]
                active = bw[k] > _EPS
                sel = np.nonzero(active[: hit + 1])[0]
                first = int(sel[0])
                start = max(t0, (s0 + first) * self.slot_duration)
                before = float(cum[k, hit - 1]) if hit > 0 else 0.0
                t_in = max(t0, (s0 + hit) * self.slot_duration)
                end = t_in + (size - before) / float(bw[k, hit])
                fr = resid_frac[k] if bandwidth_cap is None else bw[k] / caps[k]
                fracs = tuple((s0 + int(j), float(fr[j])) for j in sel)
                plans[i] = TransferPlan(tuple(rows_list[i]), start, end, fracs)
            return plans  # type: ignore[return-value]
        raise RuntimeError("transfer does not fit within max_slots horizon")

    def commit(self, plan: TransferPlan) -> None:
        idx = list(plan.links)
        for slot, frac in plan.slot_fracs:
            self._ensure(slot)
            new = self.reserved[idx, slot] + frac
            if (new > 1.0 + 1e-6).any():
                raise ValueError(
                    f"over-reservation on slot {slot}: {new.max():.6f} > 1"
                )
            self.reserved[idx, slot] = np.minimum(new, 1.0)

    def occupy(
        self, rows: Sequence[int], start: float, end: float, fraction: float
    ) -> None:
        """Book ``fraction`` of every row over the continuous window
        [start, end) — background cross-traffic the controller observes but
        did not plan (saturates at 1.0 instead of raising)."""
        s0 = self.slot_of(start)
        s1 = self.slot_of(max(start, end - _EPS))
        self._ensure(s1)
        idx = list(rows)
        self.reserved[idx, s0 : s1 + 1] = np.minimum(
            self.reserved[idx, s0 : s1 + 1] + fraction, 1.0
        )

    def release(self, plan: TransferPlan) -> None:
        """Exact inverse of :meth:`commit` — cancel a reserved transfer."""
        idx = list(plan.links)
        for slot, frac in plan.slot_fracs:
            self.reserved[idx, slot] = np.maximum(
                self.reserved[idx, slot] - frac, 0.0
            )

    def plan_bytes(self, plan: TransferPlan, until: Optional[float] = None) -> float:
        """Capacity-units·seconds the plan delivers by ``until`` (default:
        the whole plan — i.e. the transfer's total size as booked)."""
        if not plan.slot_fracs:
            return 0.0
        cap = float(self.capacity[list(plan.links)].min())
        t1 = plan.end if until is None else min(float(until), plan.end)
        total = 0.0
        for slot, frac in plan.slot_fracs:
            lo = max(plan.start, slot * self.slot_duration)
            hi = min(t1, (slot + 1) * self.slot_duration)
            if hi > lo:
                total += frac * cap * (hi - lo)
        return total

    def release_after(self, plan: TransferPlan, t: float) -> TransferPlan:
        """Release the unconsumed tail of a committed plan (reroute support).

        Every slot at/after ``t``'s slot is released; slots that completed
        strictly before it stay committed.  The boundary slot — the one
        ``t`` falls inside — is released *whole*: its bytes are forfeited
        and must be retransmitted (see DESIGN.md §4; since controller
        replans always use ``not_before >= t``, the freed past fraction
        can never be double-booked).  Returns the kept (truncated) plan,
        whose :meth:`plan_bytes` is exactly the delivered size.
        """
        if not plan.slot_fracs or t >= plan.end:
            return plan
        if t <= plan.start:
            cut = plan.slot_fracs[0][0]
        else:
            cut = self.slot_of(t)
        keep = tuple((s, f) for s, f in plan.slot_fracs if s < cut)
        idx = list(plan.links)
        for slot, frac in plan.slot_fracs:
            if slot >= cut:
                self.reserved[idx, slot] = np.maximum(
                    self.reserved[idx, slot] - frac, 0.0
                )
        if not keep:
            return TransferPlan(plan.links, plan.start, plan.start, ())
        new_end = min(plan.end, cut * self.slot_duration)
        return TransferPlan(plan.links, plan.start, new_end, keep)

    # -- convenience --------------------------------------------------------
    def transfer_time(
        self, size: float, rows: Sequence[int], not_before: float = 0.0
    ) -> float:
        """Duration the greedy plan would take (no commit) — Eq. (1) with the
        real-time ledger standing in for ``BW_{dataSrc,j}``."""
        plan = self.plan_transfer(size, rows, not_before)
        return plan.end - plan.start if plan.slot_fracs else 0.0

    def earliest_window(
        self,
        rows: Sequence[int],
        size: float,
        not_before: float,
        deadline: float,
    ) -> Optional[TransferPlan]:
        """Earliest greedy plan finishing by ``deadline`` (Pre-BASS prefetch)."""
        plan = self.plan_transfer(size, rows, not_before)
        if plan.end <= deadline + _EPS:
            return plan
        return None

    def utilization(self) -> float:
        used = self.reserved.sum()
        total = self.reserved.size
        return float(used / total) if total else 0.0
