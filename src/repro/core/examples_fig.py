"""The paper's worked Example 1 / Discussion 1 / Example 2 instance (Fig. 2/3).

Fig. 3 is an image; the per-task replica map is not fully written out in
prose, so we derived one consistent with *every* number in §IV (see
DESIGN.md §3): the HDS trace (N1:{2,3,7} N2:{1,6} N3:{4} N4:{5,8,9}, 39 s),
BAR's TK9→N3 move (38 s), BASS's TK1→N1 at ΥC=17 s with slots TS4..TS8 on
Link1+Link2 and makespan 35 s via TK9 on N1, and Pre-BASS's 34 s with TK8
the last finisher.

Units: capacity in Mbps, size in Mbit.  The paper rounds 64 MB @ 100 Mbps
(5.12 s) to TM = 5 s; we use SZ = 500 Mbit so the arithmetic is exact.
"""
from __future__ import annotations

from typing import Dict, Tuple

from .tasks import Instance, Task
from .topology import paper_fig2_fabric

# Replica placement derived in DESIGN.md §3.
REPLICAS: Dict[int, Tuple[str, str]] = {
    1: ("N2", "N3"),
    2: ("N1", "N4"),
    3: ("N1", "N2"),
    4: ("N3", "N1"),
    5: ("N4", "N2"),
    6: ("N2", "N3"),
    7: ("N1", "N3"),
    8: ("N4", "N1"),
    9: ("N3", "N1"),
}

INITIAL_IDLE = {"N1": 3.0, "N2": 9.0, "N3": 20.0, "N4": 7.0}
TP = 9.0          # task computation time (homogeneous nodes), §IV Example 1
SIZE = 500.0      # Mbit → TM = 5 s at 100 Mbps, paper's rounded figure
LINK_MBPS = 100.0
SLOT = 1.0        # "We set each time slot TS_k to be 1s in this paper"


def example1_instance() -> Instance:
    fabric = paper_fig2_fabric(LINK_MBPS)
    tasks = [
        Task(tid=i, size=SIZE, compute=TP, replicas=REPLICAS[i])
        for i in range(1, 10)
    ]
    return Instance(
        fabric=fabric,
        workers=["N1", "N2", "N3", "N4"],
        idle=dict(INITIAL_IDLE),
        tasks=tasks,
        slot_duration=SLOT,
    )


# Ground-truth figures from the paper text (§IV, Fig. 4).
PAPER_MAKESPAN = {"BASS": 35.0, "BAR": 38.0, "HDS": 39.0, "Pre-BASS": 34.0}
PAPER_TK1 = {"node": "N1", "completion": 17.0, "slots": (4, 5, 6, 7, 8)}
PAPER_HDS_ALLOC = {
    "N1": {2, 3, 7},
    "N2": {1, 6},
    "N3": {4},
    "N4": {5, 8, 9},
}
