"""Independent discrete-event replay of a schedule.

The schedulers compute completion times with Eq. (1)–(3) arithmetic; this
module *replays* an emitted :class:`~repro.core.tasks.Schedule` against the
fabric as an event simulation and re-derives every task's timeline from
first principles.  It is the cross-check oracle used by the property tests:

* node exclusivity — a node runs one task at a time;
* causality        — compute starts only after the task's transfer ends and
                     after the node's previous task finishes;
* link capacity    — summed reservations on any link/slot never exceed 1;
* agreement        — replayed finish times equal the scheduler's to 1e-6.

It also provides :func:`evaluate`, the two-phase (map → shuffle → reduce)
MapReduce makespan evaluator used by the Table-I workload benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .tasks import Assignment, Instance, Schedule, Task
from .timeslot import TimeSlotLedger


@dataclass
class ReplayReport:
    makespan: float
    finish: Dict[int, float]
    violations: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations


def _replay_nodes(
    schedule: Schedule,
    tasks: Dict[int, Task],
    idle: Dict[str, float],
    violations: List[str],
    arrival: Optional[Dict[int, float]] = None,
    atol: float = 1e-6,
) -> Dict[int, float]:
    """Per-node sequential replay shared by :func:`replay` (one frozen job)
    and :func:`replay_online` (multi-job arrival streams).

    ``arrival`` maps tid → job submission time; a task can never start (nor
    its transfer be planned) before its job arrived.
    """
    finish: Dict[int, float] = {}
    for node, queue in schedule.by_node().items():
        t = idle.get(node, 0.0)
        for a in queue:
            task = tasks[a.tid]
            ready = a.transfer.end if a.transfer is not None else 0.0
            if arrival is not None:
                ready = max(ready, arrival.get(a.tid, 0.0))
            start = max(t, ready)
            if a.start + atol < start:
                violations.append(
                    f"task {a.tid} on {node} starts at {a.start} before feasible {start}"
                )
            # Schedulers never idle a node: the emitted start must equal the
            # feasible start exactly (prefetch slack is a bug, not a freedom —
            # Pre-BASS recomputes starts as max(node avail, transfer end)).
            if a.start > start + atol:
                violations.append(
                    f"task {a.tid} on {node} idles until {a.start} although "
                    f"feasible at {start}"
                )
            end = a.start + task.compute  # replay honours the schedule's start
            if abs(end - a.finish) > atol:
                violations.append(
                    f"task {a.tid} finish mismatch: schedule {a.finish} replay {end}"
                )
            if a.transfer is not None and a.transfer.end > a.start + atol:
                violations.append(
                    f"task {a.tid} computes at {a.start} before transfer ends "
                    f"at {a.transfer.end}"
                )
            if (
                arrival is not None
                and a.transfer is not None
                and a.transfer.slot_fracs
                and a.transfer.start + atol < arrival.get(a.tid, 0.0)
            ):
                violations.append(
                    f"task {a.tid} transfer starts at {a.transfer.start} "
                    f"before its job arrived at {arrival[a.tid]}"
                )
            if a.start + atol < t:
                violations.append(
                    f"task {a.tid} overlaps previous task on {node}: {a.start} < {t}"
                )
            t = max(t, end)
            finish[a.tid] = end
    return finish


def _check_ledger(schedule: Schedule, violations: List[str]) -> None:
    """Link over-booking (the ledger matrix is the committed state).

    Under the rolling horizon (DESIGN.md §7) the matrix covers only the
    live window — retired columns held delivered history that was subject
    to this same check while it was live, and every replayed plan's
    ``slot_fracs``/times are absolute, so the oracle's causality checks
    below are origin-invariant by construction."""
    res = schedule.ledger.reserved
    if (res > 1.0 + 1e-6).any():
        worst = float(res.max())
        violations.append(f"link over-booked: max reserved fraction {worst:.6f}")


def replay(instance: Instance, schedule: Schedule, atol: float = 1e-6) -> ReplayReport:
    tasks = {t.tid: t for t in instance.tasks}
    violations: List[str] = []
    _check_ledger(schedule, violations)
    finish = _replay_nodes(schedule, tasks, instance.idle, violations, atol=atol)

    missing = set(tasks) - set(finish)
    if missing:
        violations.append(f"unscheduled tasks: {sorted(missing)}")

    mk = max(finish.values()) if finish else 0.0
    return ReplayReport(mk, finish, violations)


def replay_online(
    jobs: Sequence[Tuple[float, Sequence[Task]]],
    schedule: Schedule,
    idle: Dict[str, float],
    atol: float = 1e-6,
) -> ReplayReport:
    """Online cross-check: replay a multi-job stream's combined schedule.

    ``jobs`` is the arrival stream ``[(submit_at, tasks), ...]`` (what was
    fed to :meth:`~repro.core.controller.ClusterController.submit`);
    ``schedule`` is the controller's combined output and ``idle`` the
    cluster's initial ``ΥI_j``.  On top of the offline invariants (node
    exclusivity, transfer-before-compute, no over-booking, no idling past
    the feasible start) it checks *arrival causality*: no task starts — and
    no transfer delivers — before its job was submitted.
    """
    tasks: Dict[int, Task] = {}
    arrival: Dict[int, float] = {}
    violations: List[str] = []
    for submit_at, job_tasks in jobs:
        for t in job_tasks:
            if t.tid in tasks:
                violations.append(f"duplicate tid {t.tid} across jobs")
            tasks[t.tid] = t
            arrival[t.tid] = submit_at

    _check_ledger(schedule, violations)
    finish = _replay_nodes(
        schedule, tasks, idle, violations, arrival=arrival, atol=atol
    )

    missing = set(tasks) - set(finish)
    if missing:
        violations.append(f"unscheduled tasks: {sorted(missing)}")

    mk = max(finish.values()) if finish else 0.0
    return ReplayReport(mk, finish, violations)


# ---------------------------------------------------------------------------
# Two-phase MapReduce evaluation (Table-I-style workloads)
# ---------------------------------------------------------------------------

Scheduler = Callable[[Instance, Optional[TimeSlotLedger]], Schedule]


@dataclass
class JobMetrics:
    """Table-I row: map/reduce/job completion + locality ratio."""

    mt: float
    rt: float
    jt: float
    lr: float
    rerouted: int = 0  # transfers re-planned after link/switch failures
    reexecuted: int = 0     # tasks killed by host crashes and re-placed
    speculative: int = 0    # LATE backup copies launched
    wasted_bytes: float = 0.0  # delivered bytes discarded (kills + spec losers)

    def to_dict(self) -> dict:
        """Plain-dict form for the obs snapshot / JSON artifacts."""
        return {
            "mt": self.mt,
            "rt": self.rt,
            "jt": self.jt,
            "lr": self.lr,
            "rerouted": self.rerouted,
            "reexecuted": self.reexecuted,
            "speculative": self.speculative,
            "wasted_bytes": self.wasted_bytes,
        }


def evaluate_mapreduce(
    map_instance: Instance,
    scheduler: Scheduler,
    reduce_tasks: Sequence[Task],
    shuffle_per_reduce: float,
) -> JobMetrics:
    """Schedule the map phase, then build the reduce phase on the same ledger.

    Reduce tasks start after all maps finish (barrier, as in the paper's JT
    measurements), each shuffles ``shuffle_per_reduce`` units from the map
    nodes (modelled as a transfer from the busiest map node — the shuffle
    bottleneck path) unless the reducer lands there.
    """
    mp = scheduler(map_instance, None)
    ledger = mp.ledger
    mt = mp.makespan

    # Reduce instance: nodes become idle at their last map finish (or their
    # initial idle if they ran nothing), barrier at mt for shuffle start.
    idle = dict(map_instance.idle)
    for a in mp.assignments:
        idle[a.node] = max(idle.get(a.node, 0.0), a.finish)
    for n in idle:
        idle[n] = max(idle[n], mt)

    reduce_instance = Instance(
        fabric=map_instance.fabric,
        workers=list(map_instance.workers),
        idle=idle,
        tasks=list(reduce_tasks),
        slot_duration=map_instance.slot_duration,
    )
    rp = scheduler(reduce_instance, ledger)
    rt = rp.makespan - mt
    jt = max(mp.makespan, rp.makespan)

    n_total = len(mp.assignments) + len(rp.assignments)
    n_local = sum(1 for a in mp.assignments if a.local) + sum(
        1 for a in rp.assignments if a.local
    )
    return JobMetrics(mt=mt, rt=rt, jt=jt, lr=n_local / max(n_total, 1))
