"""Independent discrete-event replay of a schedule.

The schedulers compute completion times with Eq. (1)–(3) arithmetic; this
module *replays* an emitted :class:`~repro.core.tasks.Schedule` against the
fabric as an event simulation and re-derives every task's timeline from
first principles.  It is the cross-check oracle used by the property tests:

* node exclusivity — a node runs one task at a time;
* causality        — compute starts only after the task's transfer ends and
                     after the node's previous task finishes;
* link capacity    — summed reservations on any link/slot never exceed 1;
* agreement        — replayed finish times equal the scheduler's to 1e-6.

It also provides :func:`evaluate`, the two-phase (map → shuffle → reduce)
MapReduce makespan evaluator used by the Table-I workload benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .tasks import Assignment, Instance, Schedule, Task
from .timeslot import TimeSlotLedger


@dataclass
class ReplayReport:
    makespan: float
    finish: Dict[int, float]
    violations: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations


def replay(instance: Instance, schedule: Schedule, atol: float = 1e-6) -> ReplayReport:
    tasks = {t.tid: t for t in instance.tasks}
    violations: List[str] = []

    # 1. Link over-booking (ledger matrix is the committed state).
    res = schedule.ledger.reserved
    if (res > 1.0 + 1e-6).any():
        worst = float(res.max())
        violations.append(f"link over-booked: max reserved fraction {worst:.6f}")

    # 2. Per-node sequential replay.
    finish: Dict[int, float] = {}
    for node, queue in schedule.by_node().items():
        t = instance.idle.get(node, 0.0)
        for a in queue:
            task = tasks[a.tid]
            ready = a.transfer.end if a.transfer is not None else 0.0
            start = max(t, ready)
            end = start + task.compute
            if start + atol < a.start - atol and abs(start - a.start) > atol:
                pass  # prefetch may legally start later than possible; check below
            if a.start + atol < start:
                violations.append(
                    f"task {a.tid} on {node} starts at {a.start} before feasible {start}"
                )
            end = a.start + task.compute  # replay honours the schedule's start
            if abs(end - a.finish) > atol:
                violations.append(
                    f"task {a.tid} finish mismatch: schedule {a.finish} replay {end}"
                )
            if a.transfer is not None and a.transfer.end > a.start + atol:
                violations.append(
                    f"task {a.tid} computes at {a.start} before transfer ends "
                    f"at {a.transfer.end}"
                )
            if a.start + atol < t:
                violations.append(
                    f"task {a.tid} overlaps previous task on {node}: {a.start} < {t}"
                )
            t = max(t, end)
            finish[a.tid] = end

    missing = set(tasks) - set(finish)
    if missing:
        violations.append(f"unscheduled tasks: {sorted(missing)}")

    mk = max(finish.values()) if finish else 0.0
    return ReplayReport(mk, finish, violations)


# ---------------------------------------------------------------------------
# Two-phase MapReduce evaluation (Table-I-style workloads)
# ---------------------------------------------------------------------------

Scheduler = Callable[[Instance, Optional[TimeSlotLedger]], Schedule]


@dataclass
class JobMetrics:
    """Table-I row: map/reduce/job completion + locality ratio."""

    mt: float
    rt: float
    jt: float
    lr: float


def evaluate_mapreduce(
    map_instance: Instance,
    scheduler: Scheduler,
    reduce_tasks: Sequence[Task],
    shuffle_per_reduce: float,
) -> JobMetrics:
    """Schedule the map phase, then build the reduce phase on the same ledger.

    Reduce tasks start after all maps finish (barrier, as in the paper's JT
    measurements), each shuffles ``shuffle_per_reduce`` units from the map
    nodes (modelled as a transfer from the busiest map node — the shuffle
    bottleneck path) unless the reducer lands there.
    """
    mp = scheduler(map_instance, None)
    ledger = mp.ledger
    mt = mp.makespan

    # Reduce instance: nodes become idle at their last map finish (or their
    # initial idle if they ran nothing), barrier at mt for shuffle start.
    idle = dict(map_instance.idle)
    for a in mp.assignments:
        idle[a.node] = max(idle.get(a.node, 0.0), a.finish)
    for n in idle:
        idle[n] = max(idle[n], mt)

    reduce_instance = Instance(
        fabric=map_instance.fabric,
        workers=list(map_instance.workers),
        idle=idle,
        tasks=list(reduce_tasks),
        slot_duration=map_instance.slot_duration,
    )
    rp = scheduler(reduce_instance, ledger)
    rt = rp.makespan - mt
    jt = max(mp.makespan, rp.makespan)

    n_total = len(mp.assignments) + len(rp.assignments)
    n_local = sum(1 for a in mp.assignments if a.local) + sum(
        1 for a in rp.assignments if a.local
    )
    return JobMetrics(mt=mt, rt=rt, jt=jt, lr=n_local / max(n_total, 1))
