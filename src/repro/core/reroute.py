"""Batched failure-reroute engine — the failure-storm fast path.

``ClusterController._reroute_dead`` historically replanned dead in-flight
transfers one at a time: per victim, a ``choose_source_path`` candidate
enumeration plus a ``plan_transfer_batch`` whose windows escalate from the
failure instant through the whole ledger backlog, then an
O(nodes × assignments) ``_retime_nodes`` sweep.  A spine kill with
thousands of in-flight transfers made the controller the outage.  This
module replans the same storm in a handful of fused array passes while
staying **byte-identical** to the sequential loop — same
``reroute_log``, same winner plans, same retimed schedules
(property-tested in ``tests/test_reroute_props.py``; the sequential loop
survives below as :func:`sequential_reroute`, the oracle and the recorded
benchmark baseline).

**Why batching is legal.**  The sequential loop interleaves per victim:
release the dead plan's unconsumed tail, replan the remaining bytes,
commit the winner.  Victim *i*'s plan therefore sees the tails of victims
*j > i* still booked.  The greedy policy books the *path* residue on
every link, so when a plan's links were evenly booked (the fleet norm:
plans land on untouched frontier slots) every cell of the committed plan
is **exactly 1.0** reserved — which means (a) the tails of distinct
victims can never share a (link, slot) cell (a full cell is never
selected by a later plan), and (b) the value victim *i* sequentially
reads at any not-yet-released tail cell is exactly ``1.0``.  The engine
exploits this: it releases *every* tail up front, stamps each released
cell with its victim's index in an ``owner`` matrix, and reconstructs
victim *i*'s exact sequential view as ``max(reserved, 1.0·[owner > i])``
— the *phantom overlay*.  Neither fact is assumed: both are verified at
run time (every tail cell must gather as exactly full before any
release, owner stamps must never collide), and a violation — e.g. plans
placed over background cross-traffic, whose non-bottleneck links keep
residue — aborts to :func:`sequential_reroute` (counted in
``controller.reroute_stats["fallbacks"]``) before any byte can diverge.

**The passes.**

1. *Victim sweep* — one pass over the in-flight index in the sequential
   loop's exact order, marking plans that cross the dead-row set.
2. *Release + stamp* — per victim: ``plan_bytes`` / ``release_after`` /
   remaining-bytes arithmetic (unchanged expressions), tail cells stamped
   into ``owner``.
3. *Candidate grid* — every victim's surviving (replica, path) pairs in
   one :meth:`repro.net.paths.PathEngine.route_batch` pass (dead-set
   incidence filter + cached dead-set Yen detours).
4. *Fused compressed-column score* — the cumulative-deliverable sum only
   grows at slots where no path link is effectively full, so the scan
   enumerates exactly those *joint* slots (chunked AND over a dense
   availability mask, owner post-filter for the victim's phantom view)
   and gathers only their columns into one
   :func:`repro.kernels.ts_plan.plan_scan` pass per escalation round —
   O(plan length) per candidate where the sequential escalation pays
   O(frontier distance), with identical floats (``x + 0.0 == x``).
5. *Commit walk* — victims replay in order, pre-scanned in adaptive
   waves.  A victim consumes its precomputed curves iff no earlier
   commit touched any cell its scan read (per-link dirty-slot map, as in
   the wavefront engine); clean winners flush as one grouped scatter
   (:meth:`~repro.core.timeslot.TimeSlotLedger.commit_batch`), dirty
   victims re-score through the same fused scan against the live ledger,
   and a collapsed hit rate turns waves off entirely.  Flow-table
   reinstall, ``RerouteRecord`` logging and ``_live_jobs`` bookkeeping
   are the sequential loop's, line for line.
6. *Grouped retime* — ``_retime_nodes`` over all touched nodes with one
   grouping pass over the assignment set instead of a scan per node.

See DESIGN.md §6 for the algorithm and the complexity table.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..kernels import ts_plan
from .timeslot import TransferPlan
from .topology import UnroutableError

_EPS = 1e-9
_NEVER = np.iinfo(np.int64).max
_MAX_SLOTS = 1 << 16  # plan_transfer's reach, measured from slot_of(nb)
_EMPTY_COLS = np.empty(0, dtype=np.int64)


class _Victim:
    __slots__ = (
        "jid", "rec", "a", "task", "old_plan", "old_names",
        "total", "delivered", "remaining", "nb", "s0", "cands",
        "colstate", "cols", "bw", "resid", "cum", "hit", "end", "winner",
    )

    def __init__(self) -> None:
        self.colstate = None


class RerouteEngine:
    """One failure event's batched replan.  Build per event; :meth:`run`
    is the only entry point."""

    def __init__(self, ctrl) -> None:
        self.ctrl = ctrl
        self.state = ctrl.state
        self.ledger = ctrl.state.ledger
        self.hits = self.misses = 0

    # -- entry --------------------------------------------------------------
    def run(self, at: float) -> None:
        ctrl = self.ctrl
        ledger = self.ledger
        dead_names = ctrl.dataplane.all_dead_links()
        dead_rows = frozenset(ledger.rows((n,))[0] for n in dead_names)
        victims = self._sweep(at, dead_rows)
        if victims:
            if not self._release_and_stamp(victims, at):
                # Invariant guard tripped (a tail cell was not exactly
                # full — e.g. plans placed over background traffic book
                # unevenly): the ledger is untouched (or restored) and the
                # sequential oracle handles the whole event.  Counted, so
                # an operator can see the fast path disengage.
                ctrl.reroute_stats["fallbacks"] += 1
                sequential_reroute(ctrl, at)
                return
            self._candidate_grid(victims)
            self._walk(victims, at, dead_names)
        st = ctrl.reroute_stats
        st["events"] += 1
        st["victims"] += len(victims)
        st["hits"] += self.hits
        st["misses"] += self.misses
        self._suspend_raw_flows(at, dead_rows)
        if self._touched:
            ctrl._retime_nodes(self._touched, self._rerouted_tids)

    # -- pass 1: victim sweep ----------------------------------------------
    def _sweep(self, at: float, dead_rows) -> List[_Victim]:
        ctrl = self.ctrl
        self._touched: set = set()
        self._rerouted_tids: set = set()
        victims: List[_Victim] = []
        for jid, latest_end in list(ctrl._live_jobs.items()):
            rec = ctrl.jobs.get(jid)
            if rec is None or latest_end <= at + _EPS:
                del ctrl._live_jobs[jid]
                continue
            tasks = None
            for a in rec.assignments:
                plan = a.transfer
                if plan is None or not plan.slot_fracs:
                    continue
                if plan.end <= at + _EPS:
                    continue
                if not any(r in dead_rows for r in plan.links):
                    continue
                if tasks is None:
                    tasks = {tk.tid: tk for tk in rec.tasks}
                v = _Victim()
                v.jid, v.rec, v.a, v.task = jid, rec, a, tasks[a.tid]
                v.old_plan = plan
                victims.append(v)
        return victims

    # -- pass 2: release + phantom stamp -------------------------------------
    def _release_and_stamp(self, victims: List[_Victim], at: float) -> bool:
        """Release every victim's unconsumed tail and stamp the released
        cells with the victim index.  Returns False (before any mutation)
        when the exactly-full invariant does not hold."""
        ledger = self.ledger
        res = ledger.reserved
        # Absolute→physical offset for every matrix access this event;
        # retire() only runs on the controller clock, never mid-event, so
        # the origin is frozen here.  Tail slots sit at/after the failure
        # instant's slot, which the retire guard keeps live.
        base = self._base = ledger.base_slot
        tails: List[Tuple[np.ndarray, np.ndarray]] = []
        for v in victims:
            plan = v.old_plan
            cut = (
                plan.slot_fracs[0][0] if at <= plan.start
                else ledger.slot_of(at)
            )
            tail_slots = np.array(
                [s for s, _ in plan.slot_fracs if s >= cut], dtype=np.int64
            )
            tails.append((np.asarray(plan.links), tail_slots))
        # Invariant: every tail cell is exactly full (greedy plans book the
        # full residue) — checked before any release so a violation can
        # abort cleanly to the sequential oracle.
        for rows, slots in tails:
            if slots.size and not (
                res[rows[:, None], (slots - base)[None, :]] == 1.0
            ).all():
                return False
        self._owner = np.full(res.shape, -1, dtype=np.int32)
        owner = self._owner
        for i, v in enumerate(victims):
            plan = v.old_plan
            v.total = ledger.plan_bytes(plan)
            kept = ledger.release_after(plan, at)
            v.delivered = ledger.plan_bytes(kept)
            v.remaining = max(v.total - v.delivered, 0.0)
            v.nb = max(at, plan.start)
            v.s0 = ledger.slot_of(v.nb)
            v.old_names = ledger.link_names(plan.links)
            rows, slots = tails[i]
            if slots.size:
                cells = owner[rows[:, None], (slots - base)[None, :]]
                if (cells != -1).any():
                    # Tails collided — restore every tail released so far
                    # to its exact pre-release value (1.0, verified above)
                    # and let the sequential oracle run the event.
                    for rr, ss in tails[: i + 1]:
                        if ss.size:
                            ledger.reserved[
                                rr[:, None], (ss - base)[None, :]
                            ] = 1.0
                    ledger.mirror_invalidate()  # direct writes bypass the journal
                    return False
                owner[rows[:, None], (slots - base)[None, :]] = i
        self._tails = tails
        # Frontier evidence: one dense availability mask over the stamped
        # horizon — ``avail[l, s]`` ⟺ cell (l, s) is not exactly full in
        # the *all-tails-released* ledger.  Joint enumeration AND-scans
        # path links over it in chunks and post-filters by the owner
        # stamp, so a candidate's potentially-nonzero slots cost a couple
        # of vector ops instead of per-cell membership tests.  Walk
        # commits clear their cells; cells past the stamped width are
        # free until committed (staleness there only wastes a gathered
        # column — it reads its true, now-zero residue).
        self._avail = ledger.reserved != 1.0
        return True

    def _undo_releases(self, victims: List[_Victim], after: int) -> None:
        """Re-book the tails of victims ``> after`` at their exact
        pre-release value (1.0) — the sequential loop raises with those
        tails still committed."""
        for j in range(after + 1, len(victims)):
            rows, slots = self._tails[j]
            if slots.size:
                self.ledger.reserved[
                    rows[:, None], (slots - self._base)[None, :]
                ] = 1.0
        self.ledger.mirror_invalidate()  # direct writes bypass the journal

    # -- pass 3: candidate grid ----------------------------------------------
    def _candidate_grid(self, victims: List[_Victim]) -> None:
        """Every victim's surviving (replica, path-index, rows, cap, hops)
        candidates, in ``choose_source_path``'s exact enumeration order,
        through one :meth:`PathEngine.route_batch` pass."""
        ledger = self.ledger
        dp = self.ctrl.dataplane
        pairs = []
        for v in victims:
            for rep in v.task.replicas:
                if rep != v.a.node:
                    pairs.append((rep, v.a.node))
        cand_map = dp.candidates_batch(pairs)
        mk_cache: Dict[Tuple[str, str], list] = {}
        capacity = ledger.capacity
        for v in victims:
            cands: list = []
            for rep in v.task.replicas:
                if rep == v.a.node:
                    continue
                key = (rep, v.a.node)
                lst = mk_cache.get(key)
                if lst is None:
                    lst = []
                    for pi, p in enumerate(cand_map[key]):
                        rows = ledger.rows(p)
                        cap = (
                            float(capacity[list(rows)].min())
                            if rows else float("inf")
                        )
                        lst.append((pi, rows, cap, len(rows)))
                    mk_cache[key] = lst
                cands.extend((rep,) + c for c in lst)
            v.cands = cands

    # -- pass 4: fused compressed-column scoring ------------------------------
    #
    # The greedy cumulative-deliverable sum only grows at slots where *no*
    # path link is effectively full — every other slot contributes exactly
    # ``0.0``.  The scan therefore enumerates the *joint* potentially-
    # nonzero slots (chunked AND over the availability mask rows, owner
    # post-filter for the victim's phantom view) and gathers only those
    # columns: O(plan length) work per candidate where the sequential
    # escalation pays O(frontier distance), with identical floats
    # (x + 0.0 == x, and column order is slot order).

    def _extend_columns(self, st: list, need: int) -> None:
        """Grow a candidate's collected joint columns to ≥ ``need`` or
        until its scan position exhausts the plan budget.  ``st`` is
        ``[cols, pos, rows_arr, thresh, budget]``.  A column survives iff
        every path link is available (not exactly full post-release, not
        consumed by a walk commit) and carries no phantom stamp above the
        victim's threshold; slots past the stamped width are free until
        committed (a consumed one reads its true zero residue — wasteful,
        never wrong)."""
        cols, pos, rows_arr, thresh, budget = st
        avail = self._avail
        owner = self._owner
        base = self._base          # cols/pos are absolute; masks physical
        w_abs = base + avail.shape[1]
        parts = [cols]
        total = cols.size
        while total < need and pos < budget:
            hi = min(pos + 4096, budget)
            if pos < w_abs:
                hi = min(hi, w_abs)
                joint = np.flatnonzero(
                    avail[rows_arr, pos - base : hi - base].all(axis=0)
                ) + pos
                if joint.size:
                    ow = owner[rows_arr[:, None], (joint - base)[None, :]]
                    joint = joint[(ow <= thresh).all(axis=0)]
            else:
                joint = np.arange(pos, hi, dtype=np.int64)
            if joint.size:
                parts.append(joint)
                total += joint.size
            pos = hi
        if len(parts) > 1:
            st[0] = np.concatenate(parts)
        st[1] = pos

    def _scan(self, victims: List[_Victim], which: Sequence[int]) -> None:
        """Fused greedy scan for every candidate of the given victims —
        one compressed-column gather + plan_scan pass per escalation
        round (frozen: resolved candidates never re-scan).  Results land
        on the victims (curves, per-candidate ends, the winner index)."""
        ledger = self.ledger
        dur = ledger.slot_duration
        live: List[Tuple[int, int]] = []   # (victim idx, candidate idx)
        colstate: List[list] = []  # [cols, pos, rows, thresh, budget]
        for i in which:
            v = victims[i]
            n = len(v.cands)
            v.cols = [None] * n
            v.bw = [None] * n
            v.resid = [None] * n
            v.cum = [None] * n
            v.hit = np.full(n, -1, dtype=np.int64)
            v.end = np.empty(n)
            if v.remaining <= 0:
                v.end.fill(v.nb)
                continue
            if v.colstate is None:
                # one enumeration per victim for the whole event: a later
                # re-score reuses the collected columns — commits only
                # shrink availability, so the cached set stays a superset
                # of a fresh enumeration and consumed cells gather their
                # true zero residue
                v.colstate = [
                    [_EMPTY_COLS, v.s0, np.asarray(cand[2]), i,
                     v.s0 + _MAX_SLOTS]
                    for cand in v.cands
                ]
            for c in range(len(v.cands)):
                live.append((i, c))
                colstate.append(v.colstate[c])
        if not live:
            self._pick_winners(victims, which)
            return
        n_cand = len(live)
        wl = max(victims[i].cands[c][4] for i, c in live)
        pad = np.empty((n_cand, wl), dtype=np.intp)
        caps = np.empty(n_cand)
        sizes = np.empty(n_cand)
        s0c = np.empty(n_cand, dtype=np.int64)
        t0c = np.empty(n_cand)
        for k, (i, c) in enumerate(live):
            v = victims[i]
            _rep, _pi, rows, cap, ln = v.cands[c]
            pad[k, :ln] = rows
            pad[k, ln:] = rows[0]
            caps[k] = cap
            sizes[k] = v.remaining
            s0c[k] = v.s0
            t0c[k] = v.nb
        m = 64
        unresolved = np.arange(n_cand)
        while True:
            sub = unresolved
            cols = np.empty((len(sub), m), dtype=np.int64)
            secs = np.full((len(sub), m), dur)
            capped = np.zeros(len(sub), dtype=bool)
            for j, k in enumerate(sub):
                st = colstate[k]
                self._extend_columns(st, m)
                row = st[0][:m]
                if row.size < m:
                    # exhausted every potentially-nonzero slot below the
                    # plan_transfer budget: pad with zero-second columns
                    capped[j] = True
                    fill = row[-1] if row.size else int(s0c[k])
                    secs[j, row.size:] = 0.0
                    row = np.concatenate([
                        row, np.full(m - row.size, fill, dtype=np.int64)
                    ])
                cols[j] = row
            ledger._ensure(int(cols.max()))
            # first-slot partiality is a property of slot s0 itself
            first_part = cols[:, 0] == s0c[sub]
            secs[first_part, 0] = (s0c[sub][first_part] + 1) * dur - \
                t0c[sub][first_part]
            resid, bw, cum, hits = ts_plan.col_scan(
                ledger, pad[sub], cols, caps[sub], secs, sizes[sub]
            )
            done = hits < m
            for j in np.nonzero(done)[0]:
                i, c = live[sub[j]]
                v = victims[i]
                hit = int(hits[j])
                v.hit[c] = hit
                v.cols[c] = cols[j]
                v.bw[c] = bw[j]
                v.resid[c] = resid[j]
                v.cum[c] = cum[j]
                before = float(cum[j][hit - 1]) if hit > 0 else 0.0
                t_in = max(v.nb, int(cols[j][hit]) * dur)
                v.end[c] = t_in + (v.remaining - before) / float(bw[j][hit])
            if (~done & capped).any():
                # matches the sequential window escalation running out of
                # its s0 + 2^16-slot horizon with the transfer incomplete
                raise RuntimeError(
                    "transfer does not fit within max_slots horizon"
                )
            unresolved = sub[~done]
            if unresolved.size == 0:
                break
            m *= 4
        self._pick_winners(victims, which)

    def _pick_winners(self, victims: List[_Victim], which: Sequence[int]):
        for i in which:
            v = victims[i]
            if not v.cands:
                v.winner = -1
                continue
            e = v.end
            # choose_source_path's key: (plan end, hops, replica, pair idx)
            v.winner = min(
                range(len(v.cands)),
                key=lambda c: (
                    e[c], v.cands[c][4], v.cands[c][0], v.cands[c][1]
                ),
            )

    # -- pass 5: commit walk --------------------------------------------------
    def _clean(self, v: _Victim, dirty: np.ndarray) -> bool:
        """True iff no commit since the prescan touched any cell this
        victim's decision read (every candidate's scan window up to its
        completion slot — ``choose_source_path`` compares every end)."""
        if not v.cands:
            return True
        if v.remaining <= 0:
            return True  # empty plans read no ledger cells
        for c, (_rep, _pi, rows, _cap, _ln) in enumerate(v.cands):
            limit = int(v.cols[c][int(v.hit[c])])
            for r in rows:
                if dirty[r] <= limit:
                    return False
        return True

    def _materialize(self, v: _Victim) -> TransferPlan:
        """The winner's plan from its compressed-column curve — the exact
        tail arithmetic of ``plan_transfer`` with absolute slots read off
        the column list (non-column slots have zero bandwidth in both, so
        the active-slot sets coincide)."""
        c = v.winner
        rows = v.cands[c][2]
        if v.remaining <= 0:
            return TransferPlan(tuple(rows), v.nb, v.nb, ())
        dur = self.ledger.slot_duration
        cols = v.cols[c]
        bw = v.bw[c]
        hit = int(v.hit[c])
        sel = np.nonzero(bw[: hit + 1] > _EPS)[0]
        start = max(v.nb, int(cols[sel[0]]) * dur)
        cum = v.cum[c]
        before = float(cum[hit - 1]) if hit > 0 else 0.0
        t_in = max(v.nb, int(cols[hit]) * dur)
        end = t_in + (v.remaining - before) / float(bw[hit])
        resid = v.resid[c]
        fracs = tuple((int(cols[j]), float(resid[j])) for j in sel)
        return TransferPlan(tuple(rows), start, end, fracs)

    WAVE = 64            # victims speculatively pre-scanned per wave
    MIN_COVERED = 32     # prescan coverage before the hit-rate gate binds
    MIN_HIT_RATE = 0.15  # below this, waves stop paying — go live-only

    def _walk(self, victims, at: float, dead_names) -> None:
        from ..net.events import RerouteRecord

        ctrl = self.ctrl
        ledger = self.ledger
        n = len(victims)
        dirty = np.full(len(ledger.capacity), _NEVER, dtype=np.int64)
        pending: List[TransferPlan] = []
        self.hits = self.misses = 0
        # Adaptive speculation (the wavefront engine's gate): pre-scan
        # victims in waves, and when commits invalidate nearly every
        # curve (heavily contended storms make consecutive replans
        # genuinely data-dependent) stop pre-scanning and run each victim
        # through the same fused scan live — identical results, no
        # wasted batch passes.
        spec_on = True
        scanned_until = 0
        covered = 0

        avail = self._avail

        def flush() -> None:
            if pending:
                ledger.commit_batch(pending)
                # A commit books the *path* residue on every link, so only
                # cells it saturates to exactly 1.0 stop being available —
                # a non-bottleneck link can keep residue the sequential
                # loop would later book, and must stay enumerable.  (Cells
                # past the stamped width stay implicitly free — harmless,
                # they read their true residue at gather time.)
                base = self._base
                w = avail.shape[1]
                for plan in pending:
                    slots = [
                        s - base for s, _ in plan.slot_fracs if s - base < w
                    ]
                    if slots:
                        rr = np.asarray(plan.links)[:, None]
                        cc = np.asarray(slots)[None, :]
                        avail[rr, cc] &= ledger.reserved[rr, cc] != 1.0
                pending.clear()

        for i, v in enumerate(victims):
            if spec_on and i >= scanned_until:
                if covered >= self.MIN_COVERED and (
                    self.hits < self.MIN_HIT_RATE * covered
                ):
                    spec_on = False
                else:
                    flush()
                    dirty.fill(_NEVER)
                    hi = min(n, i + self.WAVE)
                    try:
                        self._scan(victims, range(i, hi))
                        scanned_until = hi
                        covered += hi - i
                    except RuntimeError:
                        # Some wave victim cannot fit the plan horizon —
                        # drop to live-only so the raise lands at that
                        # victim's exact turn, like the sequential loop.
                        spec_on = False
            if not v.cands:
                flush()
                self._undo_releases(victims, i)
                raise UnroutableError(
                    f"task {v.task.tid}: no replica has a surviving "
                    f"path to {v.a.node!r}"
                )
            if spec_on and i < scanned_until and self._clean(v, dirty):
                self.hits += 1
            else:
                self.misses += 1
                flush()
                try:
                    self._scan(victims, [i])
                except RuntimeError:
                    self._undo_releases(victims, i)
                    raise
            src = v.cands[v.winner][0]
            new_plan = self._materialize(v)
            pending.append(new_plan)
            if new_plan.slot_fracs:
                first = new_plan.slot_fracs[0][0]
                for r in new_plan.links:
                    if first < dirty[r]:
                        dirty[r] = first
            cookie = ("job", v.jid, v.a.tid)
            ctrl.dataplane.tables.uninstall(cookie)
            ctrl._install(cookie, src, v.a.node, new_plan)
            ctrl.reroute_log.append(RerouteRecord(
                at=at, flow=cookie, dead_links=tuple(sorted(
                    dead_names & set(v.old_names))),
                src=src, dst=v.a.node,
                old_path=v.old_names,
                new_path=ledger.link_names(new_plan.links),
                delivered=v.delivered, remaining=v.remaining,
                old_end=v.old_plan.end, new_end=new_plan.end,
            ))
            v.a.source, v.a.transfer = src, new_plan
            v.rec.rerouted += 1
            self._rerouted_tids.add(v.a.tid)
            self._touched.add(v.a.node)
            ctrl._live_jobs[v.jid] = max(
                ctrl._live_jobs.get(v.jid, 0.0), new_plan.end
            )
        flush()

    # -- raw flows ------------------------------------------------------------
    def _suspend_raw_flows(self, at: float, dead_rows) -> None:
        ctrl = self.ctrl
        ledger = self.ledger
        for tag, plan in list(ctrl.flows.items()):
            if not plan.slot_fracs or plan.end <= at + _EPS:
                continue
            if not any(r in dead_rows for r in plan.links):
                continue
            total = ledger.plan_bytes(plan)
            kept = ledger.release_after(plan, at)
            delivered = ledger.plan_bytes(kept)
            ctrl.flows[tag] = kept
            ctrl._suspended.append(
                (tag, ledger.link_names(plan.links), total - delivered)
            )


def sequential_reroute(ctrl, at: float) -> None:
    """The historical per-victim reroute loop — the byte-exactness oracle
    the engine is property-tested against, and the recorded baseline of
    ``benchmarks/bench_failover_scale.py``.  Semantics: DESIGN.md §4."""
    from ..net.events import RerouteRecord

    state = ctrl.state
    ledger = state.ledger
    dead_names = ctrl.dataplane.all_dead_links()
    dead_rows = {ledger.rows((n,))[0] for n in dead_names}
    touched_nodes = set()
    rerouted_tids = set()

    for jid, latest_end in list(ctrl._live_jobs.items()):
        rec = ctrl.jobs.get(jid)
        if rec is None or latest_end <= at + _EPS:
            del ctrl._live_jobs[jid]
            continue
        tasks = None
        for a in rec.assignments:
            plan = a.transfer
            if plan is None or not plan.slot_fracs:
                continue
            if plan.end <= at + _EPS or not (set(plan.links) & dead_rows):
                continue
            if tasks is None:
                tasks = {tk.tid: tk for tk in rec.tasks}
            task = tasks[a.tid]
            old_names = ledger.link_names(plan.links)
            # Remaining bytes come from the *current* plan, not task.size —
            # after an earlier reroute the plan already carries only the
            # then-remaining bytes.
            total = ledger.plan_bytes(plan)
            kept = ledger.release_after(plan, at)
            delivered = ledger.plan_bytes(kept)
            remaining = max(total - delivered, 0.0)
            # A transfer that had not started yet keeps its queue position
            # (its original start), it does not jump to the failure
            # instant — rerouting must never act as prefetch.
            nb = max(at, plan.start)
            src, _rows, new_plan = state.choose_source_path(
                task, a.node, nb, size=remaining
            )
            ledger.commit(new_plan)
            cookie = ("job", rec.jid, a.tid)
            ctrl.dataplane.tables.uninstall(cookie)
            ctrl._install(cookie, src, a.node, new_plan)
            ctrl.reroute_log.append(RerouteRecord(
                at=at, flow=cookie, dead_links=tuple(sorted(
                    dead_names & set(old_names))),
                src=src, dst=a.node,
                old_path=old_names,
                new_path=ledger.link_names(new_plan.links),
                delivered=delivered, remaining=remaining,
                old_end=plan.end, new_end=new_plan.end,
            ))
            a.source, a.transfer = src, new_plan
            rec.rerouted += 1
            rerouted_tids.add(a.tid)
            touched_nodes.add(a.node)
            ctrl._live_jobs[jid] = max(
                ctrl._live_jobs.get(jid, 0.0), new_plan.end
            )

    # Raw flows (explicit-link reservations, e.g. grad sync) cannot
    # detour — suspend their remainder until the links recover.
    for tag, plan in list(ctrl.flows.items()):
        if not plan.slot_fracs or plan.end <= at + _EPS:
            continue
        if not (set(plan.links) & dead_rows):
            continue
        total = ledger.plan_bytes(plan)
        kept = ledger.release_after(plan, at)
        delivered = ledger.plan_bytes(kept)
        ctrl.flows[tag] = kept
        ctrl._suspended.append(
            (tag, ledger.link_names(plan.links), total - delivered)
        )

    if touched_nodes:
        ctrl._retime_nodes(touched_nodes, rerouted_tids)
