"""Fabric topology model — the SDN controller's global network view.

The paper's OpenFlow controller knows every link and its real-time residual
bandwidth.  ``Fabric`` is that view: a graph of nodes (compute hosts, switches,
routers) and directed-capacity links, with shortest-path routing resolved once
and cached.  Builders are provided for

* the paper's Fig. 2 testbed (4 workers, 2 OpenFlow switches, 1 router),
* generic two-tier leaf/spine clusters (Table-I-scale experiments), and
* TPU-fleet DCN fabrics (hosts per pod, pods per fleet) used by the training
  control plane — ICI inside a pod is compiler-scheduled and is *not* modelled
  here (see DESIGN.md §2).

Bandwidths are in Mbps for the Hadoop experiments (paper units) but the class
is unit-agnostic: ``bytes/sec`` works equally for the DCN builders.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class UnroutableError(ValueError):
    """No (surviving) path exists between two endpoints.

    Raised by ``Fabric.path`` on disconnected pairs and by the routing
    layer — ``repro.net.paths`` and the controller's failure-aware
    rerouting — when every candidate path is down.  Subclasses
    ``ValueError`` (the historical ``Fabric.path`` exception) so existing
    callers keep working.  Defined here so ``core`` can raise/catch it
    without importing ``net``.
    """


@dataclass(frozen=True)
class Link:
    """An undirected link with a symmetric capacity (paper's model)."""

    name: str
    a: str
    b: str
    capacity: float  # bandwidth units (Mbps in the paper)

    def other(self, node: str) -> str:
        return self.b if node == self.a else self.a


class Fabric:
    """Graph of nodes + links with cached shortest paths (hop-count metric).

    The SDN controller's view: every link is known, and a path between any two
    nodes resolves to the ordered list of link names whose time-slot calendars
    must be reserved together (paper §IV.A: path residue = min over links).
    """

    #: Node roles: ``host`` (compute/storage endpoint — schedulable),
    #: ``switch`` (forwarding only), ``infra`` (master/controller — carries
    #: no data traffic and must never join the worker set).
    ROLES = ("host", "switch", "infra")

    def __init__(self) -> None:
        self._links: Dict[str, Link] = {}
        self._adj: Dict[str, List[str]] = {}
        self._roles: Dict[str, str] = {}
        self._path_cache: Dict[Tuple[str, str], Tuple[str, ...]] = {}
        self._parent: Dict[str, Tuple[str, str]] = {}  # child -> (parent, link)
        self._nontree_links: set = set()  # links added outside add_uplink
        self._version = 0

    # -- construction -----------------------------------------------------
    def add_node(self, name: str, role: Optional[str] = None) -> None:
        """Register a node.  ``role`` tags it explicitly (``host`` |
        ``switch`` | ``infra``); new nodes default to ``host``, and passing
        a role re-tags an existing node (builders promote switches that were
        first seen as uplink parents)."""
        if role is not None and role not in self.ROLES:
            raise ValueError(f"unknown node role {role!r} (want one of {self.ROLES})")
        if name not in self._adj:
            self._adj[name] = []
            self._roles[name] = role or "host"
        elif role is not None:
            self._roles[name] = role

    def add_link(self, name: str, a: str, b: str, capacity: float) -> None:
        if name in self._links:
            raise ValueError(f"duplicate link {name!r}")
        self.add_node(a)
        self.add_node(b)
        self._links[name] = Link(name, a, b, capacity)
        self._adj[a].append(name)
        self._adj[b].append(name)
        # Mutation invalidates every cached routing artifact: the Dijkstra
        # path cache AND the tree-LCA shortcut — a cross link can make tree
        # walks non-minimal, so any non-uplink edge disables them for good
        # (``add_uplink`` re-registers its edge as a tree edge below).
        self._path_cache.clear()
        self._nontree_links.add(name)
        self._version += 1

    def add_uplink(
        self,
        name: str,
        child: str,
        parent: str,
        capacity: float,
        role: Optional[str] = None,
    ) -> None:
        """Tree edge: enables O(depth) LCA routing (all builders are trees).

        Paths between tree members avoid per-pair Dijkstra — essential at
        4 000+ hosts where the controller routes tens of thousands of flows.

        ``role`` tags the *child* (default ``host``; a child already tagged,
        e.g. a switch first seen as some other uplink's parent, keeps its
        tag).  The parent is tagged ``switch`` when first seen — uplink
        parents forward traffic by construction.
        """
        self.add_node(parent, "switch" if parent not in self._adj else None)
        self.add_node(child, role)
        self.add_link(name, child, parent, capacity)
        self._nontree_links.discard(name)
        self._parent[child] = (parent, name)

    # -- queries -----------------------------------------------------------
    @property
    def version(self) -> int:
        """Monotonic mutation counter — path engines key their caches on it."""
        return self._version

    @property
    def links(self) -> Dict[str, Link]:
        return dict(self._links)

    @property
    def nodes(self) -> List[str]:
        return list(self._adj)

    def role(self, name: str) -> str:
        """The node's explicit role tag (``host`` | ``switch`` | ``infra``)."""
        return self._roles[name]

    def nodes_with_role(self, role: str) -> List[str]:
        return [n for n in self._adj if self._roles[n] == role]

    def link(self, name: str) -> Link:
        return self._links[name]

    def has_node(self, name: str) -> bool:
        return name in self._adj

    def incident_links(self, node: str) -> Tuple[str, ...]:
        """Names of every link touching ``node`` (insertion order)."""
        return tuple(self._adj[node])

    def neighbors(self, node: str) -> Tuple[str, ...]:
        return tuple(self._links[l].other(node) for l in self._adj[node])

    def path_nodes(self, src: str, links: Sequence[str]) -> Tuple[str, ...]:
        """Node sequence visited by walking ``links`` (a path) from ``src``."""
        out = [src]
        cur = src
        for name in links:
            cur = self._links[name].other(cur)
            out.append(cur)
        return tuple(out)

    def path(self, src: str, dst: str) -> Tuple[str, ...]:
        """Ordered link names on the min-hop path src→dst.

        Tree members resolve via an LCA walk in O(depth); general graphs
        fall back to hop-count Dijkstra with a path cache.
        """
        if src == dst:
            return ()
        tree = self._tree_path(src, dst)
        if tree is not None:
            return tree
        key = (src, dst)
        cached = self._path_cache.get(key)
        if cached is not None:
            return cached
        # Dijkstra with hop-count metric; deterministic tie-break on node name.
        dist: Dict[str, int] = {src: 0}
        prev: Dict[str, Tuple[str, str]] = {}  # node -> (prev node, via link)
        pq: List[Tuple[int, str]] = [(0, src)]
        while pq:
            d, u = heapq.heappop(pq)
            if u == dst:
                break
            if d > dist.get(u, 1 << 30):
                continue
            for lname in sorted(self._adj[u]):
                link = self._links[lname]
                v = link.other(u)
                nd = d + 1
                if nd < dist.get(v, 1 << 30):
                    dist[v] = nd
                    prev[v] = (u, lname)
                    heapq.heappush(pq, (nd, v))
        if dst not in prev and dst != src:
            raise UnroutableError(f"no path {src!r} -> {dst!r}")
        rev: List[str] = []
        node = dst
        while node != src:
            pnode, via = prev[node]
            rev.append(via)
            node = pnode
        out = tuple(reversed(rev))
        self._path_cache[key] = out
        return out

    def _tree_path(self, src: str, dst: str) -> Optional[Tuple[str, ...]]:
        """LCA path when both endpoints live in the builder's tree.

        Declines (→ Dijkstra fallback) as soon as any non-uplink edge
        exists: a cross link can shorten paths the tree walk would miss
        (the ``add_link``-after-``path()`` staleness bug).
        """
        par = self._parent
        if not par or self._nontree_links:
            return None
        # Ancestor chains (node, link-to-parent) up to the root.
        def chain(n: str) -> Optional[List[Tuple[str, str]]]:
            out = []
            seen = {n}
            while n in par:
                p, l = par[n]
                out.append((p, l))
                if p in seen:
                    return None  # defensive: not a tree
                seen.add(p)
                n = p
            return out

        ca, cb = chain(src), chain(dst)
        if ca is None or cb is None:
            return None
        roots_a = {src} | {p for p, _ in ca}
        roots_b = {dst} | {p for p, _ in cb}
        if (ca and cb and ca[-1][0] != cb[-1][0]) and not (
            dst in roots_a or src in roots_b
        ):
            return None  # different trees
        if dst in roots_a:
            up = []
            n = src
            while n != dst:
                p, l = par[n]
                up.append(l)
                n = p
            return tuple(up)
        if src in roots_b:
            down = []
            n = dst
            while n != src:
                p, l = par[n]
                down.append(l)
                n = p
            return tuple(reversed(down))
        anc_b = {dst: 0}
        for i, (p, _) in enumerate(cb):
            anc_b[p] = i + 1
        up = []
        n = src
        while n not in anc_b:
            if n not in par:
                return None
            p, l = par[n]
            up.append(l)
            n = p
        down = [l for _, l in cb[: anc_b[n]]]
        return tuple(up + list(reversed(down)))

    def tree_routing_ok(self) -> bool:
        """True when LCA tree walks are valid routing (every link is an
        uplink) — the precondition under which :meth:`parent_chain` lets a
        caller resolve min-hop paths without touching Dijkstra.  Mirrors
        the gate inside :meth:`_tree_path`, so external fast-path routers
        (``core.wavefront``) agree with :meth:`path` on when the shortcut
        applies."""
        return bool(self._parent) and not self._nontree_links

    def parent_chain(self, node: str) -> Tuple[Tuple[str, str], ...]:
        """``((parent, uplink-name), …)`` from ``node`` up to its tree
        root (empty for a root).  With :meth:`tree_routing_ok`, the
        min-hop path ``a→b`` is ``a``'s chain up to the lowest common
        ancestor followed by ``b``'s chain below it, reversed — exactly
        what :meth:`path` computes."""
        out = []
        n = node
        seen = {n}
        while n in self._parent:
            p, link = self._parent[n]
            out.append((p, link))
            if p in seen:  # defensive: parent links form a cycle
                raise ValueError(f"parent chain of {node!r} is not a tree")
            seen.add(p)
            n = p
        return tuple(out)

    def path_capacity(self, src: str, dst: str) -> float:
        """Static bottleneck capacity of the src→dst path."""
        names = self.path(src, dst)
        if not names:
            return float("inf")
        return min(self._links[n].capacity for n in names)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def paper_fig2_fabric(link_mbps: float = 100.0) -> Fabric:
    """The Fig. 2 testbed: 4 worker nodes, 2 OpenFlow switches, a router.

    Link naming follows the paper: Link1..Link4 are node uplinks, Link7/Link8
    the switch→router trunks ("we may also choose ND3 … Link 1, Link 7, Link 8
    and Link 3"), Link5/Link6 the master/controller uplinks (no data traffic).
    """
    f = Fabric()
    f.add_uplink("Link1", "N1", "SwA", link_mbps)
    f.add_uplink("Link2", "N2", "SwA", link_mbps)
    f.add_uplink("Link3", "N3", "SwB", link_mbps)
    f.add_uplink("Link4", "N4", "SwB", link_mbps)
    f.add_uplink("Link5", "Master", "Router", link_mbps, role="infra")
    f.add_uplink("Link6", "Controller", "Router", link_mbps, role="infra")
    f.add_uplink("Link7", "SwA", "Router", link_mbps)
    f.add_uplink("Link8", "SwB", "Router", link_mbps)
    return f


def two_tier_fabric(
    n_leaves: int,
    hosts_per_leaf: int,
    host_mbps: float = 100.0,
    trunk_mbps: float = 1000.0,
) -> Fabric:
    """Generic leaf/spine: hosts ``H<i>`` under leaves ``Sw<j>`` under one spine."""
    f = Fabric()
    for j in range(n_leaves):
        f.add_uplink(f"Trunk{j}", f"Sw{j}", "Spine", trunk_mbps, role="switch")
        for i in range(hosts_per_leaf):
            h = j * hosts_per_leaf + i
            f.add_uplink(f"Up{h}", f"H{h}", f"Sw{j}", host_mbps)
    return f


def tpu_dcn_fabric(
    n_pods: int,
    hosts_per_pod: int,
    nic_gbytes: float = 25e9,
    pod_trunk_gbytes: float = 400e9,
) -> Fabric:
    """TPU-fleet DCN view: hosts ``pod<p>/host<h>`` behind per-pod aggregation.

    Capacities in bytes/s (defaults: 25 GB/s NIC, 400 GB/s pod trunk), so
    transfer sizes are plain bytes.  ICI inside a pod is *not* modelled here (XLA's job);
    this fabric carries input shards, cross-pod grad sync, KV migration and
    checkpoint traffic — the flows BASS actually controls.
    """
    f = Fabric()
    for p in range(n_pods):
        agg = f"pod{p}/agg"
        f.add_uplink(f"pod{p}/trunk", agg, "dcn-core", pod_trunk_gbytes, role="switch")
        for h in range(hosts_per_pod):
            name = f"pod{p}/host{h}"
            f.add_uplink(f"pod{p}/nic{h}", name, agg, nic_gbytes)
    return f


def storage_hosts(fabric: Fabric) -> List[str]:
    """Compute/storage endpoints — nodes explicitly tagged ``role="host"``.

    The role tag is set at construction (``add_node``/``add_uplink``), so
    new builders cannot silently leak switches or infra nodes into the
    worker set the way the old name-prefix filter could.
    """
    return fabric.nodes_with_role("host")
