"""Write-ahead journal + full-fidelity snapshots for the controller
(control-plane crash-recovery, DESIGN.md §11).

The paper's centralized SDN controller is a single point of failure: every
scheduling decision, ledger booking and flow rule lives in one process.
This module makes that state *durable* the way real control planes do —
with a write-ahead log of externally-visible mutations plus periodic full
snapshots:

* :class:`Journal` — an append-only log of :class:`JournalRecord` entries.
  ``ClusterController`` appends one record per public entry-point call
  (``submit``, ``inject_flow``, ``fail_*``/``recover_*``, ``straggle``,
  ``reserve_transfer_at``, ``fail_controller``/``recover_controller``,
  ``attach_telemetry``/``attach_heartbeats``, ``run_until``/``run``) with
  the call's *resolved* arguments (``at=None`` defaults are materialized,
  auto-assigned job ids are recorded), so replaying the log through the
  same entry points is a pure function of the records.
* :class:`ControllerSnapshot` — a complete serialization of a controller
  at journal position ``lsn``: event queue + sequence counter, jobs +
  assignments + live speculations (deep-copied together so the
  primary/backup identity links survive), the rolling ledger window,
  dataplane liveness, flow tables + expiry heap, retry/blacklist state,
  telemetry estimator + belief, heartbeat state and the behavioral obs
  counters.  ``ClusterController.snapshot()`` produces one;
  ``ClusterController.recover_from(fabric, snapshot, journal)`` restores
  it and replays ``journal.since(snapshot.lsn)`` — byte-identical to a
  controller that never crashed (property-tested in
  ``tests/test_recovery.py``).

Both containers round-trip through :meth:`to_bytes`/:meth:`from_bytes`
(pickle) so they can be written to disk like a real WAL segment — nothing
here holds a live reference to the fabric, the registry or any callable.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class JournalRecord:
    """One journaled entry-point call: ``op`` names the controller method,
    ``args`` are its resolved positional arguments (plain picklable data).
    ``lsn`` is the record's 0-based log sequence number."""

    lsn: int
    op: str
    args: Tuple = ()


@dataclass
class Journal:
    """Append-only write-ahead log of controller entry-point calls."""

    records: List[JournalRecord] = field(default_factory=list)

    @property
    def lsn(self) -> int:
        """The next record's sequence number (== records written so far)."""
        return len(self.records)

    def append(self, op: str, *args) -> JournalRecord:
        rec = JournalRecord(lsn=len(self.records), op=op, args=args)
        self.records.append(rec)
        return rec

    def since(self, lsn: int) -> List[JournalRecord]:
        """Records with sequence number >= ``lsn`` (the replay suffix for a
        snapshot taken at ``lsn``)."""
        return self.records[lsn:]

    def to_bytes(self) -> bytes:
        return pickle.dumps(self.records, protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def from_bytes(cls, data: bytes) -> "Journal":
        return cls(records=pickle.loads(data))

    def __len__(self) -> int:
        return len(self.records)


@dataclass
class ShardedJournal:
    """Per-shard WAL segments under one global sequence (DESIGN.md §12).

    The hierarchical controller journals each entry-point call into the
    *segment* of the shard it touches (a job bound for one pod lands in
    that pod's segment; clock advances and cross-pod placements land in
    the root segment), while ``lsn`` assignment stays global — so each
    segment can be written/shipped independently like a real per-shard WAL
    file, and :meth:`merged` restores the exact total order replay needs.
    """

    #: segment name -> append-ordered records (lsn-increasing within each).
    segments: dict = field(default_factory=dict)
    _next_lsn: int = 0

    ROOT = "__root__"

    @property
    def lsn(self) -> int:
        return self._next_lsn

    def append(self, op: str, *args, shard: str = ROOT) -> JournalRecord:
        rec = JournalRecord(lsn=self._next_lsn, op=op, args=args)
        self._next_lsn += 1
        self.segments.setdefault(shard, []).append(rec)
        return rec

    def segment(self, shard: str) -> List[JournalRecord]:
        return self.segments.get(shard, [])

    def merged(self) -> List[JournalRecord]:
        """All records across segments in global ``lsn`` order — the replay
        stream.  Each segment is already lsn-sorted, so this is a k-way
        merge; sorting the concatenation is equivalent and simpler."""
        out = [r for seg in self.segments.values() for r in seg]
        out.sort(key=lambda r: r.lsn)
        return out

    def since(self, lsn: int) -> List[JournalRecord]:
        return [r for r in self.merged() if r.lsn >= lsn]

    def to_bytes(self) -> bytes:
        return pickle.dumps((self.segments, self._next_lsn),
                            protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def from_bytes(cls, data: bytes) -> "ShardedJournal":
        segments, next_lsn = pickle.loads(data)
        return cls(segments=segments, _next_lsn=next_lsn)

    def __len__(self) -> int:
        return self._next_lsn


@dataclass
class ControllerSnapshot:
    """A full-fidelity controller serialization at journal position ``lsn``.

    ``payload`` is a plain-data dict assembled by
    ``ClusterController.snapshot()`` (see its docstring for the coverage
    matrix); treat it as opaque — the only supported consumers are
    ``ClusterController.recover_from`` and the byte round-trip below.
    """

    lsn: int
    payload: dict

    def to_bytes(self) -> bytes:
        return pickle.dumps((self.lsn, self.payload),
                            protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def from_bytes(cls, data: bytes) -> "ControllerSnapshot":
        lsn, payload = pickle.loads(data)
        return cls(lsn=lsn, payload=payload)
